"""Grouped prefix-shared decode sweep: group size x prefix length ->
decode tick time + prefix KV bytes read per step.

The bandwidth story behind grouped decode attention: N requests decoding
behind the same k-token shared prefix re-read the prefix KV N times per
step with per-row attention, but only ONCE per step when stage 1 runs
per (group, kv head) and the FlashDecoding++ unified-max merge folds the
shared partial into each member's private tail — so the prefix KV bytes
streamed per decode step drop ~Nx for N-way sharing.

This sweep runs the same shared-header decode workload with the paged
cache + prefix sharing, toggling only the plan's ``decode_group`` knob,
and reports per (prefix length, group size) cell:

  * wall seconds per decode tick, grouped vs per-row (CPU timings are
    directional only — the HBM effect this models needs an accelerator),
  * prefix KV bytes read per decode step in each mode, derived from the
    engine's own group-plan accounting (``prefix_kv_bytes_saved`` over
    observed grouped ticks), and
  * the dedup factor ``read_off / read_on`` (~N for N-way sharing).

Greedy outputs are asserted bit-identical between the two runs — the
sweep measures an optimization, not a different model.

Writes ``BENCH_group.json`` at the repo root so later PRs can track the
trajectory (schema: {"rows": [...], "config": {...}}).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_group.json")

PAGE_SIZE = 16
TAIL_LEN = 8          # private per-request suffix tokens
MAX_NEW = 12


def _run_engine(cfg, params, prompts, *, grouped: bool):
    """Admit everything, then time steady-state decode ticks."""
    plan = make_plan(decode_group="grouped" if grouped else "off",
                     group_threshold=1)
    eng = Engine(cfg, params, num_slots=len(prompts), max_seq=256,
                 cache_kind="paged", page_size=PAGE_SIZE,
                 prefill_chunk=PAGE_SIZE, prefix_sharing=True,
                 plan=plan, seed=0)
    rids = [eng.submit(p, SamplingParams(max_new_tokens=MAX_NEW))
            for p in prompts]
    # admission + prefill + first decode tick: compile outside the timer
    for _ in range(3):
        eng.step()
    ticks = 0
    t0 = time.perf_counter()
    while not all(eng.requests[r].finished for r in rids):
        eng.step()
        ticks += 1
    dt = (time.perf_counter() - t0) / max(ticks, 1)
    outs = {r: list(eng.requests[r].tokens) for r in rids}
    return eng, outs, dt


def run(quick: bool = False) -> dict:
    print("\n== group_decode: group size x shared-prefix length ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    prefix_lens = (32,) if quick else (32, 64, 128)
    group_sizes = (2, 3) if quick else (2, 4, 8)

    rng = np.random.default_rng(0)
    widths = [8, 8, 11, 11, 13, 13, 8]
    print(fmt_row("prefix", "group", "tick_off_s", "tick_on_s",
                  "kv_read_off", "kv_read_on", "dedup", widths=widths))
    rows = []
    for k in prefix_lens:
        header = rng.integers(1, cfg.vocab_size, size=k).astype(np.int32)
        for n in group_sizes:
            prompts = [np.concatenate([header, rng.integers(
                1, cfg.vocab_size, size=TAIL_LEN).astype(np.int32)])
                for _ in range(n)]

            off_eng, off_outs, off_dt = _run_engine(
                cfg, params, prompts, grouped=False)
            on_eng, on_outs, on_dt = _run_engine(
                cfg, params, prompts, grouped=True)
            identical = on_outs == off_outs
            assert identical, \
                "grouped decode changed greedy outputs — correctness bug"
            assert on_eng.stats.grouped_requests > 0, \
                "grouped plan never engaged — sweep measured nothing"

            # prefix KV bytes per decode step, from the engine's own
            # group-plan accounting: per grouped tick the plan deduped
            # (members-1) * prefix_pages pages worth of KV reads
            prefix_pages = k // PAGE_SIZE
            page_bytes = on_eng._kv_bytes_per_page
            grouped_ticks = on_eng.stats.grouped_requests / n
            saved_per_step = (on_eng.stats.prefix_kv_bytes_saved
                              / grouped_ticks)
            read_off = n * prefix_pages * page_bytes
            read_on = read_off - saved_per_step
            row = dict(
                prefix_len=k, group_n=n, page_size=PAGE_SIZE,
                tail_len=TAIL_LEN, max_new=MAX_NEW,
                decode_tick_s_off=off_dt, decode_tick_s_on=on_dt,
                prefix_kv_read_off=int(read_off),
                prefix_kv_read_on=int(read_on),
                dedup_x=read_off / max(read_on, 1),
                grouped_requests=on_eng.stats.grouped_requests,
                prefix_kv_bytes_saved=on_eng.stats.prefix_kv_bytes_saved,
                bit_identical=identical,
            )
            rows.append(row)
            print(fmt_row(k, n, f"{off_dt:.4f}", f"{on_dt:.4f}",
                          row["prefix_kv_read_off"],
                          row["prefix_kv_read_on"],
                          f"{row['dedup_x']:.1f}x", widths=widths))

    result = {
        "config": dict(arch=cfg.name, page_size=PAGE_SIZE,
                       tail_len=TAIL_LEN, max_new=MAX_NEW,
                       prefix_lens=list(prefix_lens),
                       group_sizes=list(group_sizes)),
        "rows": rows,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"wrote {os.path.normpath(path)}")
    return result


if __name__ == "__main__":
    run()
