"""Paper §3 — cost of the synchronized partial-softmax update.

The paper profiles 18.8 % attention overhead from the synchronized update
on an A100. This container has no TPU, so we report the claim through two
channels:

  1. **wall-clock (CPU, XLA)** — jitted decode attention, unified-max vs
     synchronized (online-max) scheme, across KV lengths. Directional only.
  2. **structural** — (a) HLO op counts: the sync scheme's extra max/rescale
     chain is visible as `maximum`/`multiply`-chain ops that the async
     scheme simply does not emit; (b) the per-chunk serial-dependency count
     of the Pallas kernels (ops on the carried accumulator per KV chunk):
     sync = 5 (max-merge, 2 rescale-multiplies, 2 adds),
     async = 2 (2 adds) — order-independent, pipelinable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, hlo_op_counts, time_jitted
from repro.kernels import ref


def run(quick: bool = False) -> list[dict]:
    b, hq, hk, d = 4, 8, 2, 64
    rows = []
    kvs = (1024, 4096) if quick else (1024, 4096, 16384)
    print("\n== attention_softmax: sync vs unified-max decode (paper §3) ==")
    print(fmt_row("kv_len", "sync_us", "async_us", "sync_overhead",
                  widths=[10, 12, 12, 14]))
    for kv in kvs:
        ks = jax.random.split(jax.random.PRNGKey(kv), 3)
        q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
        kc = jax.random.normal(ks[1], (b, kv, hk, d), jnp.float32)
        vc = jax.random.normal(ks[2], (b, kv, hk, d), jnp.float32)
        lengths = jnp.full((b,), kv, jnp.int32)

        sync = jax.jit(lambda q, k, v, l: ref.attention_decode_ref(q, k, v, l))
        asyn = jax.jit(lambda q, k, v, l: ref.attention_decode_unified_max_ref(
            q, k, v, l, phi=0.0)[0])
        t_sync = time_jitted(sync, q, kc, vc, lengths)
        t_async = time_jitted(asyn, q, kc, vc, lengths)
        over = (t_sync - t_async) / t_sync * 100
        print(fmt_row(kv, f"{t_sync*1e6:.0f}", f"{t_async*1e6:.0f}",
                      f"{over:+.1f}%", widths=[10, 12, 12, 14]))
        rows.append(dict(kv=kv, sync_us=t_sync * 1e6,
                         async_us=t_async * 1e6, overhead_pct=over))

    # structural channel: op counts in the compiled HLO
    kv = kvs[0]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, kv, hk, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, kv, hk, d), jnp.float32)
    lengths = jnp.full((b,), kv, jnp.int32)
    c_sync, _ = hlo_op_counts(
        lambda q, k, v, l: ref.attention_decode_ref(q, k, v, l),
        q, kc, vc, lengths)
    c_async, _ = hlo_op_counts(
        lambda q, k, v, l: ref.attention_decode_unified_max_ref(
            q, k, v, l, phi=0.0)[0],
        q, kc, vc, lengths)
    print(f"  HLO ops  sync={c_sync}  async={c_async}")
    print("  per-KV-chunk serial accumulator ops (Pallas kernels): "
          "sync=5 (max-merge + 2 rescales + 2 adds), async=2 (2 adds)")
    rows.append(dict(hlo_sync=c_sync, hlo_async=c_async,
                     chunk_ops_sync=5, chunk_ops_async=2))
    return rows


if __name__ == "__main__":
    run()
