"""Tiered KV hierarchy: session-cache TTFT for returning conversations,
and the swap-vs-re-prefill crossover behind ``PagedPlan.swap_threshold``.

The capacity story behind demote-don't-discard: a finished conversation's
KV pages move device → host (→ disk) instead of dying, and the prefix
index keeps their chain-hash keys matchable across tiers — so when the
conversation returns, the engine promotes the persisted pages back (one
bulk host→device copy) and prefills only the final chunk, instead of
recomputing the whole prompt. This benchmark measures both halves:

  * **warm vs cold TTFT** — the same prompt re-submitted against (a) an
    engine whose session cache holds the conversation's pages host-side
    (flushed, so the rerun *must* promote) and (b) an engine that
    discarded them (full re-prefill). Both reruns hit compiled code; the
    delta is the prefill compute the promotion skipped.
  * **resume bit-identity** — a preemption-heavy workload run four ways
    (big pool / tight pool without tiers / tight pool with tiers / dense
    cache) must produce byte-identical greedy outputs: demoted bytes are
    the originally computed bytes, so swapping KV through the hierarchy
    is invisible to the math. Asserted, not just reported.
  * **analytical crossover** — the roofline pair behind the tuned
    ``swap_threshold`` knob (:func:`repro.core.dispatch.predict_swap_time`
    vs :func:`~repro.core.dispatch.predict_reprefill_time`) swept over
    demoted-span sizes for full-size configs, plus the host-link
    bandwidth sweep showing where re-prefill would win instead.

Writes ``BENCH_tiers.json`` at the repo root (schema:
{"ttft": [...], "identity": {...}, "crossover": [...], "config": {...}}).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs, hardware
from repro.core import dispatch
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_tiers.json")

PAGE_SIZE = 16
MAX_NEW = 4


def _mk_engine(cfg, params, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_seq", 512)
    kw.setdefault("cache_kind", "paged")
    kw.setdefault("page_size", PAGE_SIZE)
    kw.setdefault("prefill_chunk", PAGE_SIZE)
    kw.setdefault("prefix_sharing", True)
    kw.setdefault("plan", make_plan("xla"))
    kw.setdefault("seed", 0)
    return Engine(cfg, params, **kw)


def _ttft(eng, prompt) -> tuple[float, list]:
    """Submit one request, drive it to completion, return (TTFT, tokens)."""
    rid = eng.submit(prompt, SamplingParams(max_new_tokens=MAX_NEW))
    state = eng.requests[rid]
    while not state.finished:
        eng.step()
    return state.first_token_time - state.submit_time, list(state.tokens)


def _ttft_sweep(cfg, params, prompt_lens) -> list:
    """Warm (promote from host) vs cold (re-prefill) returning-turn TTFT."""
    rng = np.random.default_rng(0)
    widths = [8, 10, 10, 8, 10, 10]
    print(fmt_row("prompt", "cold_ms", "warm_ms", "speedup", "promoted",
                  "saved_tk", widths=widths))
    rows = []
    for plen in prompt_lens:
        prompt = rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32)

        # cold: no tiers — the first run compiles, KV dies on retire, so
        # each rerun pays the full re-prefill on warm jit caches
        cold = _mk_engine(cfg, params)
        for _ in range(2):
            _ttft(cold, prompt)
            cold.evict_finished()
        t_cold, toks_cold = _ttft(cold, prompt)

        # warm: session cache flushed host-ward, so the rerun must
        # promote its pages (not just re-map resident tier-0 copies);
        # one un-timed flush+rerun cycle first compiles the gather /
        # promote-scatter shapes — TTFT should measure the copies, not
        # one-time jit compiles neither steady state pays
        warm = _mk_engine(cfg, params, host_pages=256)
        _ttft(warm, prompt)
        warm.evict_finished(flush=True)
        _ttft(warm, prompt)
        warm.evict_finished(flush=True)
        assert warm.tiers.host_used > 0, "flush left nothing host-side"
        base_saved = warm.stats.saved_prefill_tokens
        t_warm, toks_warm = _ttft(warm, prompt)

        assert toks_warm == toks_cold, \
            "session-cache resume changed greedy outputs"
        assert warm.stats.promoted_pages > 0, "rerun did not promote"
        row = dict(
            prompt_len=plen,
            ttft_cold_s=t_cold, ttft_warm_s=t_warm,
            speedup=t_cold / max(t_warm, 1e-9),
            promoted_pages=warm.stats.promoted_pages,
            demoted_pages=warm.stats.demoted_pages,
            session_hits=warm.stats.session_hits,
            saved_prefill_tokens=warm.stats.saved_prefill_tokens
            - base_saved,
        )
        rows.append(row)
        print(fmt_row(plen, f"{t_cold*1e3:.1f}", f"{t_warm*1e3:.1f}",
                      f"{row['speedup']:.2f}x", row["promoted_pages"],
                      row["saved_prefill_tokens"], widths=widths))
    return rows


def _resume_identity(cfg, params) -> dict:
    """Preemption-heavy workload, four ways, byte-identical outputs."""
    rng = np.random.default_rng(1)
    sp = SamplingParams(max_new_tokens=40)
    reqs = [(rng.integers(1, cfg.vocab_size, size=40).astype(np.int32), sp)
            for _ in range(4)]

    def run(**kw):
        eng = _mk_engine(cfg, params, **kw)
        out = eng.run([(p.copy(), s) for p, s in reqs], max_ticks=2000)
        return eng, list(out.values())

    _, big = run(num_pages=64)
    tight, out_tight = run(num_pages=9)
    tiers, out_tiers = run(num_pages=9, host_pages=64)
    dense_eng = Engine(cfg, params, num_slots=4, max_seq=512,
                       cache_kind="dense", prefill_chunk=PAGE_SIZE,
                       plan=make_plan("xla"), seed=0)
    out_dense = list(dense_eng.run(
        [(p.copy(), s) for p, s in reqs], max_ticks=2000).values())

    assert out_tight == big, "re-prefill resume diverged from big pool"
    assert out_tiers == big, "tiered resume diverged from big pool"
    assert out_dense == big, "dense outputs diverged from paged"
    tiers.slots.check()
    info = dict(
        preemptions_no_tiers=tight.stats.preemptions,
        preemptions_tiers=tiers.stats.preemptions,
        demoted_pages=tiers.stats.demoted_pages,
        promoted_pages=tiers.stats.promoted_pages,
        session_hits=tiers.stats.session_hits,
        saved_prefill_tokens=tiers.stats.saved_prefill_tokens,
        identical=True,
    )
    print(f"  resume identity: big==tight==tiers==dense "
          f"({info['preemptions_tiers']} preemptions, "
          f"{info['demoted_pages']} demoted, "
          f"{info['promoted_pages']} promoted)")
    return info


def _crossover(arch_names, page_counts) -> list:
    """Analytical swap-vs-re-prefill curves + tuned threshold per arch."""
    spec = hardware.TPU_V5E
    widths = [12, 10, 12, 12, 12]
    print(fmt_row("arch", "pages", "swap_us", "reprefill_us", "winner",
                  widths=widths))
    rows = []
    for name in arch_names:
        cfg = configs.get(name)
        page_bytes = dispatch.kv_page_bytes(cfg, page_size=64)
        thr = dispatch.find_swap_threshold(cfg, page_size=64, spec=spec)
        curve = []
        for pages in page_counts:
            t_swap = dispatch.predict_swap_time(pages, page_bytes, spec=spec)
            t_pre = dispatch.predict_reprefill_time(
                cfg, pages * 64, page_size=64, spec=spec)
            curve.append(dict(pages=pages, swap_s=t_swap, reprefill_s=t_pre))
            print(fmt_row(name, pages, f"{t_swap*1e6:.1f}",
                          f"{t_pre*1e6:.1f}",
                          "swap" if t_swap < t_pre else "reprefill",
                          widths=widths))
        # host-link sweep: at PCIe-class bandwidth the copy wins from one
        # page; a disk-class link flips the decision to re-prefill (the
        # sentinel max_pages+1 = "never swap"), with the intermediate
        # regime crossing somewhere in between
        links = []
        for bw in (2e8, 5e8, 1e9, 2e9, 16e9, 64e9):
            s = dataclasses.replace(spec, host_bw=bw, name=f"link-{bw:.0e}")
            links.append(dict(host_bw=bw,
                              threshold=dispatch.find_swap_threshold(
                                  cfg, page_size=64, spec=s)))
        rows.append(dict(arch=name, page_bytes=page_bytes,
                         swap_threshold=thr, curve=curve,
                         link_sweep=links))
        sweep = [(d["host_bw"], d["threshold"]) for d in links]
        print(f"  {name}: tuned swap_threshold = {thr} page(s), "
              f"link sweep {sweep}")
    return rows


def run(quick: bool = False) -> dict:
    print("\n== kv_tiers: session-cache TTFT + swap-vs-re-prefill ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    prompt_lens = (48,) if quick else (48, 96, 192)
    page_counts = (1, 4, 16) if quick else (1, 2, 4, 8, 16, 32, 64)
    archs = ("qwen2-0.5b",) if quick else ("qwen2-0.5b", "llama2-7b")

    ttft = _ttft_sweep(cfg, params, prompt_lens)
    identity = _resume_identity(cfg, params)
    crossover = _crossover(archs, page_counts)

    result = {
        "config": dict(arch=cfg.name, page_size=PAGE_SIZE, max_new=MAX_NEW,
                       prompt_lens=list(prompt_lens),
                       crossover_page_size=64,
                       host_bw=hardware.TPU_V5E.host_bw),
        "ttft": ttft,
        "identity": identity,
        "crossover": crossover,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [kv_tiers -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    t0 = time.time()
    run()
    print(f"[{time.time()-t0:.1f}s]")
