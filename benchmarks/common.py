"""Shared timing/measurement helpers for the benchmark harness."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_jitted(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall seconds per call of an already-jitted fn (CPU)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def hlo_op_counts(fn, *args, ops=("exponential", "maximum", "divide")):
    """Count occurrences of HLO opcodes in the compiled module text —
    the structural (hardware-independent) comparison channel."""
    compiled = jax.jit(fn).lower(*args).compile()
    text = compiled.as_text()
    return {op: text.count(f" {op}(") for op in ops}, compiled


def fmt_row(*cols, widths=None) -> str:
    widths = widths or [16] * len(cols)
    return "".join(str(c).ljust(w) for c, w in zip(cols, widths))
