"""Shared timing/measurement helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def artifact_path(out_path: str, quick: bool = False) -> str:
    """Quick runs write ``BENCH_x.quick.json`` next to ``BENCH_x.json``."""
    if not quick:
        return out_path
    base, ext = os.path.splitext(out_path)
    return base + ".quick" + ext


def write_artifact(out_path: str, result: dict, quick: bool = False) -> str:
    """Stamp ``result["mode"]`` and write the benchmark artifact.

    ``--quick`` runs trim sweeps, so their numbers must never overwrite
    the committed full-mode artifacts: quick mode redirects the write to
    ``BENCH_*.quick.json`` and stamps ``"mode": "quick"`` so a clobbered
    artifact is detectable after the fact.
    """
    result["mode"] = "quick" if quick else "full"
    path = artifact_path(out_path, quick)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return path


def time_jitted(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall seconds per call of an already-jitted fn (CPU)."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def hlo_op_counts(fn, *args, ops=("exponential", "maximum", "divide")):
    """Count occurrences of HLO opcodes in the compiled module text —
    the structural (hardware-independent) comparison channel."""
    compiled = jax.jit(fn).lower(*args).compile()
    text = compiled.as_text()
    return {op: text.count(f" {op}(") for op in ops}, compiled


def fmt_row(*cols, widths=None) -> str:
    widths = widths or [16] * len(cols)
    return "".join(str(c).ljust(w) for c, w in zip(cols, widths))
