"""Decode-phase engine benchmark (paper Fig. 1 / 10 / 12 / 13).

End-to-end ``serve_step`` per-token latency on the smoke-scale model, with
the paper's three techniques toggled:

  * baseline       — synchronized softmax (T1 off), static XLA matmuls
  * +T1            — unified-max softmax (async decode attention)
  * +T1+T3         — heuristic dataflow table routing matmuls (interpret-
                     mode Pallas kernels are *not* timed here — they run
                     Python per element; the T2 kernel's effect is measured
                     structurally in flat_gemm_sweep)

CPU wall numbers are directional; the cross-engine claims in the paper map
to the roofline report on TPU terms.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, time_jitted
from repro import configs
from repro.config import SoftmaxPhiConfig
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx


def _serve_fn(cfg, api, ctx):
    def step(params, toks, cache, lengths):
        return api.decode_step(ctx, params, toks, cache, lengths)
    return jax.jit(step, donate_argnums=(2,))


def run(quick: bool = False) -> list[dict]:
    print("\n== decode_engine: per-token serve_step latency ==")
    rows = []
    archs = ["qwen2-0.5b"] if quick else ["qwen2-0.5b", "rwkv6-1.6b",
                                          "dbrx-132b"]
    print(fmt_row("arch", "batch", "baseline_us", "+T1_us", "speedup",
                  widths=[14, 7, 13, 12, 9]))
    for arch in archs:
        cfg = configs.smoke(configs.get(arch))
        api = get_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        b, s = 8, 1024
        toks = jnp.arange(b, dtype=jnp.int32) + 1
        lengths = jnp.full((b,), s - 1, jnp.int32)

        def bench(phi_active):
            phi_cfg = (SoftmaxPhiConfig(phi=0.0)
                       if phi_active else SoftmaxPhiConfig(enabled=False))
            c = dataclasses.replace(cfg, softmax_phi=phi_cfg)
            api_c = get_model(c)
            ctx = LayerCtx(cfg=c, plan=make_plan(fallback=False))
            fn = _serve_fn(c, api_c, ctx)
            layout = DenseLayout(b, s)
            t = time_jitted(
                lambda p, tk, le: fn(p, tk, api_c.init_cache(layout), le),
                params, toks, lengths, warmup=1, iters=5)
            return t

        t_base = bench(False)
        t_t1 = bench(True)
        print(fmt_row(arch, b, f"{t_base*1e6:.0f}", f"{t_t1*1e6:.0f}",
                      f"{t_base/t_t1:.2f}x", widths=[14, 7, 13, 12, 9]))
        rows.append(dict(arch=arch, baseline_us=t_base * 1e6,
                         t1_us=t_t1 * 1e6, speedup=t_base / t_t1))
    return rows


if __name__ == "__main__":
    run()
