"""Plan-tuning sweep: the heuristic dataflow generalized to every op.

For each assigned architecture this runs the offline :func:`repro.core.
plan.tune` flow (paper Fig. 9 for GEMM, plus the decode ``block_k`` and
prefill chunk-threshold decision flows) on the v5e analytical backend —
the real-TPU wallclock backend plugs into the same flow — printing the
[K, N] inflection points M1 (ImplA->ImplB) and M2 (ImplB->ImplC) and the
per-op decisions, and asserting the serialization round-trip is identity.

Writes ``BENCH_dispatch.json`` at the repo root so later PRs can track
the trajectory (schema: {"rows": [...], "plans": {...}, "config": {...}},
matching BENCH_paged/BENCH_sched).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import fmt_row, write_artifact
from repro import configs, hardware
from repro.core import dispatch as dsp
from repro.core import plan as plan_mod

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_dispatch.json")


def run(quick: bool = False) -> dict:
    print("\n== dispatch_table: plan tuning sweep (T3, Fig. 9) ==")
    rows = []
    plans = {}
    archs = ["llama2-7b"] if quick else [
        "llama2-7b", "qwen2-0.5b", "dbrx-132b", "rwkv6-1.6b"]
    for arch in archs:
        cfg = configs.get(arch)
        plan = plan_mod.tune(cfg)
        # serialization must be identity — a tuned plan is an artifact
        assert plan_mod.ExecutionPlan.from_json(plan.to_json()) == plan
        print(f"  {arch}: {plan.describe()}")
        print(fmt_row("    workload", "[K, N]", "M1(A->B)", "M2(B->C)",
                      widths=[18, 18, 10, 10]))
        seen = set()
        for gs in dsp.model_gemm_shapes(cfg):
            if (gs.k, gs.n) in seen:
                continue
            seen.add((gs.k, gs.n))
            e = plan.matmul.entries[(gs.k, gs.n)]
            print(fmt_row(f"    {gs.name}", f"[{gs.k}, {gs.n}]", e.m1, e.m2,
                          widths=[18, 18, 10, 10]))
            rows.append(dict(arch=arch, name=gs.name, k=gs.k, n=gs.n,
                             m1=e.m1, m2=e.m2))
        plans[arch] = dict(
            default_m1=plan.matmul.default_m1,
            default_m2=plan.matmul.default_m2,
            decode_scheme=plan.attention_decode.scheme,
            decode_block_k=plan.attention_decode.block_k,
            prefill_chunk_threshold=plan.attention_prefill.chunk_threshold,
            fused_ffn=plan.fused_ffn.fused,
            provenance=plan.provenance.config,
        )

    result = {
        "config": dict(spec=hardware.DEFAULT.name,
                       hardware=plan_mod.hardware_hash(hardware.DEFAULT),
                       measure="analytical", archs=archs),
        "rows": rows,
        "plans": plans,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [dispatch_table -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    run()
