"""Heuristic-dataflow inflection points (paper Fig. 9).

Builds the offline dispatch table for Llama2-7B (the paper's example: four
[K, N] shapes) and for each assigned architecture, printing M1 (ImplA->
ImplB) and M2 (ImplB->ImplC) per [K, N] from the v5e analytical backend
(the real-TPU wallclock backend plugs into the same decision flow)."""
from __future__ import annotations

from benchmarks.common import fmt_row
from repro import configs
from repro.core import dispatch as dsp


def run(quick: bool = False) -> list[dict]:
    print("\n== dispatch_table: T3 inflection points (Fig. 9) ==")
    rows = []
    archs = ["llama2-7b"] if quick else [
        "llama2-7b", "qwen2-0.5b", "dbrx-132b", "rwkv6-1.6b"]
    for arch in archs:
        cfg = configs.get(arch)
        table = dsp.tune_table(cfg)
        print(f"  {arch}:")
        print(fmt_row("    workload", "[K, N]", "M1(A->B)", "M2(B->C)",
                      widths=[18, 18, 10, 10]))
        seen = set()
        for gs in dsp.model_gemm_shapes(cfg):
            if (gs.k, gs.n) in seen:
                continue
            seen.add((gs.k, gs.n))
            e = table.entries[(gs.k, gs.n)]
            print(fmt_row(f"    {gs.name}", f"[{gs.k}, {gs.n}]", e.m1, e.m2,
                          widths=[18, 18, 10, 10]))
            rows.append(dict(arch=arch, name=gs.name, k=gs.k, n=gs.n,
                             m1=e.m1, m2=e.m2))
    return rows


if __name__ == "__main__":
    run()
