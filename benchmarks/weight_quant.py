"""Quantized GEMM weights: bytes per decode tick, footprint, accuracy.

After paging, grouping, tiering, and KV quantization, the decode tick's
dominant HBM stream is the layer weight slab — read once per tick at
M = batch <= ~8, squarely in the paper's memory-bound flat-GEMM regime.
This benchmark measures the three claims behind
``MatmulPlan.weight_dtype``:

  * **weight bytes per decode tick** — the same greedy workload served
    by engines that differ only in ``weight_dtype``;
    ``EngineStats.weight_bytes_decode_read`` counts the true stored
    bytes (int8/fp8 codes *plus* the per-output-channel f32 scales)
    behind every tick's GEMM reads, so the int8-vs-bf16 ratio is the
    measured, not theoretical, bandwidth saving. Asserted >= 1.9x.
  * **resident param footprint at a fixed HBM budget** — for full-size
    configs, :func:`repro.core.dispatch.param_bytes` (scale-inclusive)
    per precision, and the KV pages the shrink frees under a fixed
    device budget. Asserted >= 1.9x smaller for int8.
  * **accuracy under the guard** — max |Δlogits| vs the bf16 baseline
    over a teacher-forced greedy decode, asserted under the
    dtype-derived tolerance from
    :func:`repro.kernels.quant.logits_guard_tol` (the same guard the
    kv_dtype axis enforces).

Writes ``BENCH_wquant.json`` at the repo root (schema:
{"bytes": [...], "footprint": [...], "accuracy": [...],
 "weight_bytes_per_tick": {...}, "byte_reduction": {...},
 "footprint_reduction": {...}, "max_abs_dlogits": {...},
 "guard_atol": {...}, "config": {...}, "mode": ...}).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.core import dispatch
from repro.core.plan import make_plan
from repro.kernels import quant
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_wquant.json")

MAX_NEW = 8


def _dtypes() -> list:
    out = ["bf16", "int8"]
    if quant.fp8_supported():
        out.append("fp8")
    return out


def _bytes_sweep(cfg, params, dtypes) -> list:
    """Same workload, engines differing only in weight_dtype: measured
    GEMM weight bytes behind the decode ticks."""
    rng = np.random.default_rng(3)
    sp = SamplingParams(max_new_tokens=MAX_NEW)
    reqs = [(rng.integers(1, cfg.vocab_size, size=40).astype(np.int32), sp)
            for _ in range(4)]

    widths = [8, 12, 16, 10]
    print(fmt_row("w", "B/tick", "decode_W_B", "bytes_x", widths=widths))
    rows, base = [], None
    for wd in dtypes:
        eng = Engine(cfg, params, num_slots=4, max_seq=256,
                     plan=make_plan("xla"), weight_dtype=wd, seed=0)
        eng.run([(p.copy(), s) for p, s in reqs])
        row = dict(weight_dtype=wd,
                   weight_bytes_per_tick=eng._weight_bytes_per_tick,
                   weight_bytes_decode_read=(
                       eng.stats.weight_bytes_decode_read),
                   decode_ticks=eng.ticks)
        if wd == "bf16":
            base = row
        row["bytes_per_tick_ratio"] = (base["weight_bytes_decode_read"]
                                       / row["weight_bytes_decode_read"])
        assert row["decode_ticks"] == base["decode_ticks"], \
            "weight_dtype changed the tick count — workloads not comparable"
        rows.append(row)
        print(fmt_row(wd, row["weight_bytes_per_tick"],
                      row["weight_bytes_decode_read"],
                      f"{row['bytes_per_tick_ratio']:.2f}x", widths=widths))
    for row in rows:
        if row["weight_dtype"] != "bf16":
            assert row["bytes_per_tick_ratio"] >= 1.9, row
    return rows


def _footprint(arch_names, dtypes, budget_bytes) -> list:
    """Scale-inclusive resident param bytes per precision, and the KV
    pages the shrink frees under a fixed device budget."""
    widths = [12, 6, 14, 10, 12]
    print(fmt_row("arch", "w", "param_B", "params_x", "freed_kv_pages",
                  widths=widths))
    rows = []
    for name in arch_names:
        cfg = configs.get(name)
        kv_pb = dispatch.kv_page_bytes(cfg, page_size=64, kv_dtype="bf16")
        base = None
        for wd in dtypes:
            pb = dispatch.param_bytes(cfg, wd)
            if wd == "bf16":
                base = pb
            freed_pages = max(budget_bytes - pb, 0) // kv_pb \
                - max(budget_bytes - base, 0) // kv_pb
            row = dict(arch=name, weight_dtype=wd, param_bytes=pb,
                       footprint_ratio=base / pb,
                       freed_kv_pages=int(freed_pages))
            rows.append(row)
            print(fmt_row(name, wd, pb, f"{row['footprint_ratio']:.2f}x",
                          row["freed_kv_pages"], widths=widths))
            if wd == "int8":
                assert row["footprint_ratio"] >= 1.9, row
    return rows


def _accuracy(cfg, params, dtypes, steps) -> list:
    """Teacher-forced decode: max |Δlogits| vs bf16 under the guard.

    Every engine sees the identical token stream (no sampling feedback),
    so the logit deltas isolate the weight representation."""
    api = get_model(cfg)
    num_slots = 2
    rng = np.random.default_rng(5)
    toks = rng.integers(1, cfg.vocab_size,
                        size=(steps, num_slots)).astype(np.int32)

    from repro.models.layers import LayerCtx

    per_dtype = {}
    for wd in dtypes:
        eng = Engine(cfg, params, num_slots=num_slots,
                     max_seq=steps + 8, plan=make_plan("xla"),
                     weight_dtype=wd, seed=0)
        ctx = LayerCtx(cfg=cfg, plan=eng.plan)
        cache = eng.cache
        lengths = jnp.zeros((num_slots,), jnp.int32)
        trace = []
        for t in range(steps):
            logits, cache = api.decode_step(
                ctx, eng.params, jnp.asarray(toks[t]), cache, lengths)
            lengths = lengths + 1
            trace.append(np.asarray(logits, np.float32))
        per_dtype[wd] = np.stack(trace)

    scale = float(np.abs(per_dtype["bf16"]).max())
    widths = [8, 14, 14, 8]
    print(fmt_row("w", "max_dlogits", "guard_atol", "pass", widths=widths))
    rows = []
    for wd in dtypes:
        if wd == "bf16":
            continue
        dl = float(np.abs(per_dtype[wd] - per_dtype["bf16"]).max())
        atol = quant.logits_guard_tol(quant.spec_for(wd)) * max(scale, 1.0)
        ok = dl <= atol
        rows.append(dict(weight_dtype=wd, max_dlogits=dl, guard_atol=atol,
                         logit_scale=scale, within_guard=ok))
        print(fmt_row(wd, f"{dl:.4f}", f"{atol:.4f}", ok, widths=widths))
        assert ok, f"{wd} decode logits exceed the accuracy guard"
    return rows


def run(quick: bool = False) -> dict:
    print("\n== weight_quant: weight bytes / footprint / accuracy "
          "per weight_dtype ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    dtypes = _dtypes()
    archs = ("qwen2-0.5b",) if quick else ("qwen2-0.5b", "llama2-7b")
    steps = 8 if quick else 16
    budget = 4 << 30   # 4 GiB device budget (params + KV pages)

    rows_bytes = _bytes_sweep(cfg, params, dtypes)
    rows_fp = _footprint(archs, dtypes, budget)
    rows_acc = _accuracy(cfg, params, dtypes, steps)

    result = {
        "config": dict(arch=cfg.name, max_new=MAX_NEW, dtypes=dtypes,
                       budget_bytes=budget, teacher_forced_steps=steps,
                       fp8_supported=quant.fp8_supported()),
        "bytes": rows_bytes,
        "footprint": rows_fp,
        "accuracy": rows_acc,
        # flat summaries, keyed by dtype (the acceptance-criteria view)
        "weight_bytes_per_tick": {
            r["weight_dtype"]: r["weight_bytes_per_tick"]
            for r in rows_bytes},
        "byte_reduction": {r["weight_dtype"]: r["bytes_per_tick_ratio"]
                           for r in rows_bytes},
        "footprint_reduction": {r["weight_dtype"]: r["footprint_ratio"]
                                for r in rows_fp
                                if r["arch"] == archs[0]},
        "max_abs_dlogits": {r["weight_dtype"]: r["max_dlogits"]
                            for r in rows_acc},
        "guard_atol": {r["weight_dtype"]: r["guard_atol"]
                       for r in rows_acc},
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [weight_quant -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    t0 = time.time()
    run()
    print(f"[{time.time()-t0:.1f}s]")
