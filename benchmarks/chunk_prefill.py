"""Chunked-prefill admission sweep: dense-gather vs fused chunk attention.

The admission path every paged prefill (and every re-prefill after
preemption) runs streams the prompt through ``prefill_chunk`` in
fixed-size chunks. In the ``gather_chunk="dense"`` mode each chunk step
materializes the full ``(B, NB*PS)`` KV view per layer — O(max table
width) bytes regardless of how little is resident. The ``"fused"`` mode
(PR 5) reads pages in place: the fused Pallas chunk kernel on TPU, a
resident-bounded table (bucketed O(resident pages) gather, bitwise
identical) on the XLA backend this container measures.

Per (prompt length x batch x mode) the sweep reports:

  * TTFT — submit-to-first-token wall clock through the real engine
    (second wave of identical shapes, so compiles are excluded; CPU wall,
    directional — the Pallas kernel path on TPU skips the gather
    entirely), and
  * KV bytes materialized per chunk step — the gather traffic the mode
    pays per layer (zero for the in-place kernel; the sweep also reports
    the kernel's in-place page reads for the roofline story).

Greedy outputs are asserted bit-identical across dense / gather / fused
before any number is reported. Writes ``BENCH_chunk.json`` at the repo
root (schema: {"rows": [...], "config": {...}}).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.models.kvlayout import pages_for, pow2_bucket
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_chunk.json")

PAGE_SIZE = 64
CHUNK = 64


def _chunk_bytes(mode: str, prompt: int, max_seq: int, kv_bytes_per_pos: int,
                 num_layers: int):
    """(total, per-step avg) KV bytes materialized across one admission,
    plus in-place page-read bytes for the fused kernel path."""
    steps = -(-prompt // CHUNK)
    full_pages = pages_for(max_seq, PAGE_SIZE)
    per_layer_step = []
    inplace = []
    for i in range(steps):
        resident = min((i + 1) * CHUNK, prompt)
        pages = pages_for(resident, PAGE_SIZE)
        if mode == "dense":
            per_layer_step.append(full_pages * PAGE_SIZE * kv_bytes_per_pos)
        else:
            per_layer_step.append(
                pow2_bucket(pages, hi=full_pages) * PAGE_SIZE
                * kv_bytes_per_pos)
        inplace.append(pages * PAGE_SIZE * kv_bytes_per_pos)
    total = sum(per_layer_step) * num_layers
    return total, total / (steps * num_layers), sum(inplace) * num_layers


def _run_wave(eng, prompts, max_new):
    rids = [eng.submit(p, SamplingParams(max_new_tokens=max_new))
            for p in prompts]
    t0 = time.perf_counter()
    while any(not eng.requests[r].finished for r in rids):
        eng.step()
    _ = time.perf_counter() - t0
    ttft = max(eng.requests[r].first_token_time - eng.requests[r].submit_time
               for r in rids)
    out = {r: list(eng.requests[r].tokens) for r in rids}
    for r in rids:
        eng.evict(r)
    return ttft, list(out.values())


def run(quick: bool = False) -> dict:
    print("\n== chunk_prefill: dense-gather vs fused chunk attention ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    max_seq = 512 if quick else 1024
    prompt_lens = [128, 256] if quick else [128, 256, 512]
    batches = [2] if quick else [1, 4]
    max_new = 2
    kv_bytes_per_pos = (2 * cfg.num_kv_heads * cfg.head_dim
                        * np.dtype(cfg.activation_dtype).itemsize)

    plans = {
        "gather": make_plan(gather_chunk="dense"),
        "fused": make_plan(gather_chunk="fused", fused_threshold=CHUNK),
    }

    widths = [8, 6, 8, 12, 12, 16]
    print(fmt_row("prompt", "B", "mode", "ttft_ms", "MB/chunk",
                  "speedup_vs_dense", widths=widths))
    rows = []
    rng = np.random.default_rng(0)
    for batch in batches:
        for p_len in prompt_lens:
            prompts = [rng.integers(1, cfg.vocab_size, size=p_len)
                       .astype(np.int32) for _ in range(batch)]
            outs = {}
            ttfts = {}
            # dense slot-cache engine: the identity baseline
            eng = Engine(cfg, params, num_slots=batch, max_seq=max_seq,
                         cache_kind="dense", prefill_chunk=CHUNK)
            _run_wave(eng, prompts, max_new)          # compile warmup
            _, outs["dense"] = _run_wave(eng, prompts, max_new)
            for mode, plan in plans.items():
                eng = Engine(cfg, params, num_slots=batch, max_seq=max_seq,
                             cache_kind="paged", page_size=PAGE_SIZE,
                             prefill_chunk=CHUNK, plan=plan)
                _run_wave(eng, prompts, max_new)      # compile warmup
                ttfts[mode], outs[mode] = _run_wave(eng, prompts, max_new)
            assert outs["dense"] == outs["gather"] == outs["fused"], \
                "greedy outputs diverged across chunk modes"
            for mode in plans:
                total, per_step, inplace = _chunk_bytes(
                    "dense" if mode == "gather" else "fused",
                    p_len, max_seq, kv_bytes_per_pos, cfg.num_layers)
                speedup = ttfts["gather"] / ttfts[mode]
                print(fmt_row(p_len, batch, mode,
                              f"{ttfts[mode]*1e3:.1f}",
                              f"{per_step/2**20:.2f}",
                              f"{speedup:.2f}x", widths=widths))
                rows.append(dict(
                    prompt_len=p_len, batch=batch, mode=mode,
                    ttft_s=ttfts[mode],
                    kv_bytes_materialized_total=total,
                    kv_bytes_materialized_per_chunk=per_step,
                    kv_bytes_read_in_place=inplace,
                    speedup_vs_dense_gather=speedup,
                    bit_identical=True,
                ))

    result = {
        "config": dict(arch=cfg.name, max_seq=max_seq, page_size=PAGE_SIZE,
                       chunk=CHUNK, num_layers=cfg.num_layers,
                       backend=jax.default_backend()),
        "rows": rows,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [chunk_prefill -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    run()
