"""Decode-fusion granularity benchmark (DecodeFusionPlan, kernel looping).

Two channels, split vs fused vs looped on the decode hot path:

  * **Host-visible dispatch count** (structural, deterministic): the
    number of op dispatches one decode tick issues, counted on the
    Pallas-backend plan's jaxpr via :func:`count_dispatches`. Each
    jaxpr equation that materializes a result is one dispatch; a
    ``scan`` body is weighted by its trip count (the runtime re-issues
    the body per layer even though the host dispatches the loop once —
    this is deliberately *conservative* toward the looped mode: its
    real host-visible count is the loop itself). Pure layout/metadata
    ops (``reshape``, ``broadcast_in_dim``, ``convert_element_type``,
    ``squeeze``, ``transpose``, ``slice``) are excluded — they move no
    data through a kernel of their own under XLA; everything else,
    including the masking/padding glue around the attention kernels,
    is counted. Counting happens at trace time (``jax.make_jaxpr``),
    so the full model depth is measured without executing
    interpret-mode kernels.
  * **Per-tick decode latency** (wall clock): the jitted decode step
    on the XLA backend at batch {1, 4, 8}. On XLA the fused stages
    dispatch bit-identical oracle compositions, so this channel checks
    the refactor costs nothing where the fused kernels cannot run
    (split and looped trace identical scan bodies; fused python-unrolls
    the depth).

The committed ``BENCH_fusion.json`` is the acceptance artifact: the
fused/looped granularities must cut the batch-1 dispatch count >= 2x
vs split, with per-tick latency no worse at every measured batch.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fusion.json")

GRANULARITIES = ("split", "fused", "looped")

# metadata-only primitives: no kernel of their own under XLA (layout
# changes and dtype reinterpretation fuse into their consumers)
_LAYOUT_OPS = frozenset({
    "reshape", "broadcast_in_dim", "convert_element_type", "squeeze",
    "transpose", "slice", "stop_gradient", "copy",
})

# call-like primitives to recurse through (inlined at compile time)
_INLINE_OPS = frozenset({
    "pjit", "closed_call", "remat", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr",
})


def _count(jaxpr, weight: int = 1) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            n += _count(eqn.params["jaxpr"].jaxpr,
                        weight * eqn.params.get("length", 1))
        elif prim in _INLINE_OPS:
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if hasattr(inner, "jaxpr"):
                inner = inner.jaxpr
            n += _count(inner, weight)
        elif prim == "cond":
            n += max(_count(br.jaxpr, weight)
                     for br in eqn.params["branches"])
        elif prim in _LAYOUT_OPS:
            pass
        else:
            n += weight
    return n


def count_dispatches(cfg, granularity: str, batch: int = 1) -> int:
    """Op dispatches in one decode tick on the Pallas-backend plan."""
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    layout = DenseLayout(num_slots=batch, max_seq=32)
    tokens = jnp.zeros((batch,), jnp.int32)
    lengths = jnp.ones((batch,), jnp.int32)
    plan = make_plan(backend="pallas", decode_fusion=granularity,
                     fallback=False)
    ctx = LayerCtx(cfg=cfg, plan=plan)
    cache = api.init_cache(layout)
    jaxpr = jax.make_jaxpr(
        lambda p, t, c, le, po: api.decode_step(ctx, p, t, c, le,
                                                positions=po)
    )(params, tokens, cache, lengths, lengths)
    return _count(jaxpr.jaxpr)


def time_ticks(cfg, batch: int, *, warmup: int, iters: int) -> dict:
    """Min wall seconds per jitted decode tick (XLA backend), all
    granularities at once.

    The three step functions are timed *interleaved* (round-robin, one
    tick each per iteration) and reduced with min-of-N: on XLA the
    split and looped granularities compile the *same* program
    (identical scan bodies — the bit-identity guarantee), so any
    sequential-measurement spread between them is host scheduler /
    clock drift, which interleaving cancels and the minimum discards.
    """
    import time as _time

    import numpy as np

    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    s = 64
    layout = DenseLayout(num_slots=batch, max_seq=s)
    tokens = jnp.arange(batch, dtype=jnp.int32) + 1
    lengths = jnp.full((batch,), s // 2, jnp.int32)

    ticks = {}
    for g in GRANULARITIES:
        plan = make_plan(decode_fusion=g, fallback=False)
        ctx = LayerCtx(cfg=cfg, plan=plan)
        step = jax.jit(
            lambda p, t, c, le, po, _api=api, _ctx=ctx: _api.decode_step(
                _ctx, p, t, c, le, positions=po),
            donate_argnums=(2,))
        ticks[g] = (lambda _step=step: _step(
            params, tokens, api.init_cache(layout), lengths, lengths))

    for _ in range(warmup):
        for tick in ticks.values():
            out = tick()
    jax.block_until_ready(out)
    times = {g: [] for g in GRANULARITIES}
    for _ in range(iters):
        for g, tick in ticks.items():
            t0 = _time.perf_counter()
            out = tick()
            jax.block_until_ready(out)
            times[g].append(_time.perf_counter() - t0)
    return {g: float(np.min(ts)) for g, ts in times.items()}


def run(quick: bool = False) -> dict:
    print("\n== decode_fusion: dispatch count + per-tick latency, "
          "split vs fused vs looped ==")
    arch = "qwen2-0.5b"
    smoke = configs.smoke(configs.get(arch))
    # dispatch counting is trace-only, so it can afford the real depth
    # (the smoke config keeps widths tiny); quick trims it
    depth = 8 if quick else configs.get(arch).num_layers
    deep = dataclasses.replace(smoke, num_layers=depth)

    counts = {g: count_dispatches(deep, g, batch=1) for g in GRANULARITIES}
    ratio = {g: counts["split"] / counts[g] for g in GRANULARITIES}
    print(fmt_row("granularity", "dispatches/tick", "vs split",
                  widths=[13, 17, 10]))
    for g in GRANULARITIES:
        print(fmt_row(g, counts[g], f"{ratio[g]:.2f}x",
                      widths=[13, 17, 10]))

    batches = [1, 4] if quick else [1, 4, 8]
    warmup, iters = (1, 5) if quick else (5, 100)
    lat = []
    print(fmt_row("batch", *GRANULARITIES, "looped/split",
                  widths=[7, 12, 12, 12, 13]))
    for b in batches:
        t = time_ticks(smoke, b, warmup=warmup, iters=iters)
        lat.append(dict(batch=b,
                        **{f"{g}_us": t[g] * 1e6 for g in GRANULARITIES},
                        looped_over_split=t["looped"] / t["split"]))
        print(fmt_row(b, *(f"{t[g]*1e6:.0f}us" for g in GRANULARITIES),
                      f"{t['looped']/t['split']:.2f}",
                      widths=[7, 12, 12, 12, 13]))

    result = dict(
        arch=arch, depth=depth, batch=1,
        dispatches_per_tick=counts,
        dispatch_reduction_vs_split={g: ratio[g] for g in GRANULARITIES},
        latency=lat,
    )
    path = write_artifact(OUT_PATH, result, quick)
    print(f"wrote {os.path.relpath(path)}")
    return result


if __name__ == "__main__":
    run()
