"""Run every benchmark: ``PYTHONPATH=src python -m benchmarks.run``.

``--quick`` trims sweep sizes (used by CI-style smoke checks). Quick runs
write ``BENCH_*.quick.json`` sidecars and an ``artifacts/
bench_results.quick.json`` aggregate — they never overwrite the committed
full-mode ``BENCH_*.json`` artifacts, and every artifact carries a
``"mode"`` field recording which sweep produced it.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="aggregate results path (default: artifacts/"
                         "bench_results.json, or the .quick.json sidecar "
                         "under --quick)")
    args = ap.parse_args()

    from benchmarks.common import artifact_path

    # --quick always lands in a .quick.json sidecar, even for an explicit
    # --out: quick aggregates must never clobber a committed full artifact
    out = artifact_path(args.out or "artifacts/bench_results.json",
                        args.quick)

    from benchmarks import (attention_softmax, chunk_prefill, decode_engine,
                            decode_fusion, dispatch_table, flat_gemm_sweep,
                            group_decode, kv_quant, kv_tiers, paged_decode,
                            prefill_engine, prefix_sharing, roofline_report,
                            scheduler_sweep, weight_quant)

    results = {}
    for name, mod in [
        ("attention_softmax", attention_softmax),
        ("flat_gemm_sweep", flat_gemm_sweep),
        ("dispatch_table", dispatch_table),
        ("decode_engine", decode_engine),
        ("decode_fusion", decode_fusion),
        ("paged_decode", paged_decode),
        ("chunk_prefill", chunk_prefill),
        ("scheduler_sweep", scheduler_sweep),
        ("prefix_sharing", prefix_sharing),
        ("group_decode", group_decode),
        ("kv_tiers", kv_tiers),
        ("kv_quant", kv_quant),
        ("weight_quant", weight_quant),
        ("prefill_engine", prefill_engine),
        ("roofline_report", roofline_report),
    ]:
        t0 = time.time()
        try:
            results[name] = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"[FAIL] {name}: {e!r}")
            results[name] = {"error": repr(e)}
        print(f"  [{name} done in {time.time()-t0:.1f}s]")

    results["mode"] = "quick" if args.quick else "full"
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(results, f, indent=2, default=str)
    print(f"\nall benchmarks done -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
