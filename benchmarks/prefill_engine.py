"""Prefill-phase benchmark (paper Fig. 11): time-to-first-token of the
``prefill`` step with T1 on/off, across prompt lengths."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row, time_jitted
from repro import configs
from repro.config import SoftmaxPhiConfig
from repro.core.plan import make_plan
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx


def run(quick: bool = False) -> list[dict]:
    print("\n== prefill_engine: time-to-first-token ==")
    rows = []
    cfg0 = configs.smoke(configs.get("qwen2-0.5b"))
    lens = (256,) if quick else (256, 1024)
    print(fmt_row("arch", "prompt", "baseline_ms", "+T1_ms", "speedup",
                  widths=[14, 8, 13, 10, 9]))
    for plen in lens:
        b = 4

        def bench(phi_active):
            phi_cfg = (SoftmaxPhiConfig(phi=0.0)
                       if phi_active else SoftmaxPhiConfig(enabled=False))
            c = dataclasses.replace(cfg0, softmax_phi=phi_cfg)
            api = get_model(c)
            params = api.init_params(jax.random.PRNGKey(0))
            ctx = LayerCtx(cfg=c, plan=make_plan(fallback=False))
            toks = jnp.ones((b, plen), jnp.int32)
            lengths = jnp.full((b,), plen, jnp.int32)
            cache = api.init_cache(DenseLayout(b, plen))

            fn = jax.jit(lambda p, t, l, c_: api.prefill(ctx, p, t, l, c_))
            return time_jitted(fn, params, toks, lengths, cache,
                               warmup=1, iters=5)

        t_base = bench(False)
        t_t1 = bench(True)
        print(fmt_row("qwen2-0.5b", plen, f"{t_base*1e3:.1f}",
                      f"{t_t1*1e3:.1f}", f"{t_base/t_t1:.2f}x",
                      widths=[14, 8, 13, 10, 9]))
        rows.append(dict(prompt=plen, baseline_ms=t_base * 1e3,
                         t1_ms=t_t1 * 1e3, speedup=t_base / t_t1))
    return rows


if __name__ == "__main__":
    run()
