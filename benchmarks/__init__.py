"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).

  * attention_softmax — §3's synchronized-update overhead (paper: 18.8 %)
  * decode_engine     — decode-phase engine comparison (Fig. 1/10/12/13)
  * prefill_engine    — prefill-phase comparison (Fig. 11)
  * flat_gemm_sweep   — flat-GEMM B_N trade-off (Fig. 7, Eq. 5)
  * dispatch_table    — plan-tuning sweep: per-op decisions + Fig. 9 inflections
  * roofline_report   — §Roofline terms from the dry-run artifacts

``python -m benchmarks.run`` executes all of them.
"""
