"""Prefix sharing sweep: shared-prefix length x batch size -> pages,
prefill work, admission capacity.

The capacity story behind refcounted copy-on-write pages: N requests
sharing a k-token system prompt should charge the pool ~``k/page_size``
pages ONCE plus a private tail per request, instead of
``N * k/page_size`` duplicates — and skip re-prefilling the shared
positions entirely. This sweep runs the same shared-header workload
through the streaming engine with ``prefix_sharing`` on and off and
reports, per (prefix length, batch size) cell:

  * peak physical pages used, on vs off (the collapse the refcounts buy),
  * prompt positions admission skipped (prefill compute saved),
  * COW forks (writes that had to privatize a shared page), and
  * derived admission capacity: how many such requests a pool provisioned
    at the sharing-off peak could host in each mode.

Greedy outputs are asserted bit-identical between the two runs — the
sweep measures an optimization, not a different model.

Writes ``BENCH_prefix.json`` at the repo root so later PRs can track the
trajectory (schema: {"rows": [...], "config": {...}}).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import pages_for
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_prefix.json")

PAGE_SIZE = 16
TAIL_LEN = 8          # private per-request suffix tokens
MAX_NEW = 4


def run(quick: bool = False) -> dict:
    print("\n== prefix_sharing: shared-prefix length x batch size ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    prefix_lens = (32,) if quick else (32, 64, 128)
    batch_sizes = (2, 4) if quick else (2, 4, 8)
    max_seq = 256

    rng = np.random.default_rng(0)
    widths = [8, 6, 10, 10, 9, 9, 8, 8]
    print(fmt_row("prefix", "batch", "pages_off", "pages_on", "saved_tk",
                  "forks", "cap_off", "cap_on", widths=widths))
    rows = []
    for k in prefix_lens:
        header = rng.integers(1, cfg.vocab_size, size=k).astype(np.int32)
        for n in batch_sizes:
            prompts = [np.concatenate([header, rng.integers(
                1, cfg.vocab_size, size=TAIL_LEN).astype(np.int32)])
                for _ in range(n)]

            def reqs():
                return [(p, SamplingParams(max_new_tokens=MAX_NEW))
                        for p in prompts]

            outs = {}
            engines = {}
            for sharing in (False, True):
                eng = Engine(cfg, params, num_slots=n, max_seq=max_seq,
                             cache_kind="paged", page_size=PAGE_SIZE,
                             prefill_chunk=PAGE_SIZE,
                             prefix_sharing=sharing, seed=0)
                outs[sharing] = eng.run(reqs())
                engines[sharing] = eng
            assert outs[True] == outs[False], \
                "sharing changed greedy outputs — correctness bug"

            off, on = engines[False], engines[True]
            # admission capacity for a pool provisioned at the off-peak:
            # every request reserves its admission footprint (prefill
            # pages + one growth page, capped at the true total) without
            # sharing; with sharing the header is charged once and each
            # request adds only its private tail pages
            budget = off.stats.peak_pages_used
            per_req = min(pages_for(k + TAIL_LEN, PAGE_SIZE) + 1,
                          pages_for(k + TAIL_LEN + MAX_NEW, PAGE_SIZE))
            shared_pages = k // PAGE_SIZE
            per_tail = max(per_req - shared_pages, 1)
            cap_off = budget // per_req
            cap_on = max((budget - shared_pages) // per_tail, 0)
            row = dict(
                prefix_len=k, batch=n, page_size=PAGE_SIZE,
                tail_len=TAIL_LEN, max_new=MAX_NEW,
                pages_off=off.stats.peak_pages_used,
                pages_on=on.stats.peak_pages_used,
                page_savings=1.0 - on.stats.peak_pages_used
                / max(off.stats.peak_pages_used, 1),
                shared_prefix_pages=on.stats.shared_prefix_pages,
                saved_prefill_tokens=on.stats.saved_prefill_tokens,
                cow_forks=on.stats.cow_forks,
                capacity_off=cap_off, capacity_on=cap_on,
            )
            rows.append(row)
            print(fmt_row(k, n, row["pages_off"], row["pages_on"],
                          row["saved_prefill_tokens"], row["cow_forks"],
                          cap_off, cap_on, widths=widths))

    result = {
        "config": dict(arch=cfg.name, page_size=PAGE_SIZE,
                       tail_len=TAIL_LEN, max_new=MAX_NEW, max_seq=max_seq,
                       prefix_lens=list(prefix_lens),
                       batch_sizes=list(batch_sizes)),
        "rows": rows,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [prefix_sharing -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    run()
