"""Scheduler-policy × pool-overcommit sweep over the streaming engine.

The redesign's claim is that admission/preemption policy is a first-class
performance lever once KV pages are lazy: an overcommitted pool trades
preemption rework for resident batch size, and the right victim/admission
order decides whether that trade wins. This sweep runs the same synthetic
ragged workload through every built-in policy at several overcommit
ratios and reports, per cell:

  * decode throughput (tok/s, CPU wall — directional),
  * preemption count + peak page utilization, and
  * p50/p99 time-to-first-token (queueing + prefill latency, the number
    admission order actually moves).

Writes ``BENCH_sched.json`` at the repo root so later PRs can track the
trajectory (schema: {"rows": [...], "config": {...}}).
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import pages_for
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sched.json")

POLICIES = ("fcfs", "sjf", "pagefair")


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def run(quick: bool = False) -> dict:
    print("\n== scheduler_sweep: policy x overcommit ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    num_slots = 2
    max_seq = 128
    page_size = 16
    chunk = 16
    n_requests = 6 if quick else 10
    max_new = 8 if quick else 12
    # quick keeps one (interesting) overcommit cell per policy so the CI
    # smoke test stays inside the fast lane's budget
    overcommits = (0.5,) if quick else (1.0, 0.5, 0.25)

    rng = np.random.default_rng(0)
    prompt_lens = rng.integers(5, 60, size=n_requests)
    prompts = [rng.integers(1, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in prompt_lens]

    widths = [10, 6, 9, 9, 11, 10, 10]
    print(fmt_row("policy", "over", "tok/s", "preempt", "peak_pages",
                  "ttft_p50", "ttft_p99", widths=widths))
    rows = []
    worst = num_slots * pages_for(max_seq, page_size)
    for policy in POLICIES:
        for over in overcommits:
            num_pages = max(int(worst * over), 3)
            eng = Engine(cfg, params, num_slots=num_slots, max_seq=max_seq,
                         cache_kind="paged", page_size=page_size,
                         num_pages=num_pages, prefill_chunk=chunk,
                         scheduler=policy, seed=0)
            reqs = [(p, SamplingParams(max_new_tokens=max_new))
                    for p in prompts]
            t0 = time.perf_counter()
            out = eng.run(reqs)
            dt = time.perf_counter() - t0
            tokens = sum(len(v) for v in out.values())
            ttfts = [eng.requests[r].first_token_time
                     - eng.requests[r].submit_time for r in out
                     if eng.requests[r].first_token_time is not None]
            row = dict(
                policy=policy, overcommit=over, num_pages=num_pages,
                tok_s=tokens / dt, preemptions=eng.stats.preemptions,
                peak_pages_used=eng.stats.peak_pages_used,
                page_utilization=eng.stats.peak_pages_used / num_pages,
                ttft_p50_ms=_percentile(ttfts, 50) * 1e3,
                ttft_p99_ms=_percentile(ttfts, 99) * 1e3,
                ticks=eng.ticks, tokens=tokens,
            )
            rows.append(row)
            print(fmt_row(policy, over, f"{row['tok_s']:.1f}",
                          row["preemptions"],
                          f"{row['peak_pages_used']}/{num_pages}",
                          f"{row['ttft_p50_ms']:.0f}ms",
                          f"{row['ttft_p99_ms']:.0f}ms", widths=widths))

    result = {
        "config": dict(arch=cfg.name, num_slots=num_slots, max_seq=max_seq,
                       page_size=page_size, prefill_chunk=chunk,
                       n_requests=n_requests, max_new=max_new),
        "rows": rows,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [scheduler_sweep -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    run()
