"""Dense vs block-paged decode sweep over slot occupancy.

The dense slot cache provisions ``num_slots x max_seq`` KV positions no
matter what is resident; the paged pool provisions pages for the tokens
that exist. This sweep decodes one tick over a batch whose sequences fill
a varying fraction of ``max_seq`` and reports, per occupancy:

  * per-tick decode latency for both cache kinds (CPU wall, directional —
    the XLA paged path pays a gather; the Pallas kernel path on TPU reads
    only owned pages via scalar-prefetched block tables), and
  * provisioned KV bytes for both kinds — the capacity story that decides
    how many sequences a fixed HBM budget can admit.

Writes ``BENCH_paged.json`` at the repo root so later PRs can track the
trajectory (schema: {"rows": [...], "config": {...}}).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, time_jitted, write_artifact
from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout, PagedLayout, pages_for
from repro.models.layers import LayerCtx
from repro.serving.blockpool import BlockPool, PagedSlotManager

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_paged.json")


def _kv_bytes(cache) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.tree.leaves(cache))


def run(quick: bool = False) -> dict:
    print("\n== paged_decode: dense vs block-paged decode tick ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    ctx = LayerCtx(cfg=cfg)

    num_slots = 4 if quick else 8
    max_seq = 512 if quick else 1024
    page_size = 64
    occupancies = [0.25, 1.0] if quick else [0.125, 0.25, 0.5, 1.0]

    # one decode_step surface for both layouts: the block-table operand
    # (None for dense) selects the addressing discipline
    step_fn = jax.jit(
        lambda p, t, c, bt, l: api.decode_step(
            ctx, p, t, c, l, block_tables=bt),
        donate_argnums=(2,))

    widths = [6, 10, 12, 12, 14, 14]
    print(fmt_row("occ", "len", "dense_us", "paged_us", "dense_KV_MiB",
                  "paged_KV_MiB", widths=widths))
    rows = []
    toks = jnp.arange(num_slots, dtype=jnp.int32) + 1
    dense_layout = DenseLayout(num_slots, max_seq)
    dense_bytes = _kv_bytes(api.cache_spec(dense_layout))
    for occ in occupancies:
        seq = max(int(max_seq * occ) - 1, 1)
        lengths = jnp.full((num_slots,), seq, jnp.int32)

        t_dense = time_jitted(
            lambda p, tk, le: step_fn(
                p, tk, api.init_cache(dense_layout), None, le),
            params, toks, lengths, warmup=1, iters=5)

        # pool sized to what this occupancy actually needs (+1 growth page
        # per sequence) — the capacity a paged deployment would provision
        pool = BlockPool(num_slots * pages_for(seq + 1, page_size),
                         page_size)
        mgr = PagedSlotManager(num_slots, max_seq, pool)
        for i in range(num_slots):
            idx = mgr.try_assign(i, seq, 1)
            assert idx is not None and mgr.ensure(idx, seq + 1)
        bt = jnp.asarray(mgr.block_tables())
        paged_layout = PagedLayout(pool.num_pages, page_size)
        paged_bytes = _kv_bytes(api.cache_spec(paged_layout))

        t_paged = time_jitted(
            lambda p, tk, le: step_fn(
                p, tk, api.init_cache(paged_layout), bt, le),
            params, toks, lengths, warmup=1, iters=5)

        print(fmt_row(occ, seq, f"{t_dense*1e6:.0f}", f"{t_paged*1e6:.0f}",
                      f"{dense_bytes/2**20:.1f}",
                      f"{paged_bytes/2**20:.1f}", widths=widths))
        rows.append(dict(
            occupancy=occ, seq_len=seq,
            dense_us=t_dense * 1e6, paged_us=t_paged * 1e6,
            dense_kv_bytes=dense_bytes, paged_kv_bytes=paged_bytes,
            kv_savings=1.0 - paged_bytes / dense_bytes,
        ))

    result = {
        "config": dict(arch=cfg.name, num_slots=num_slots, max_seq=max_seq,
                       page_size=page_size),
        "rows": rows,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [paged_decode -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    run()
