"""Quantized KV pages: bytes per decode step, resident capacity, accuracy.

The decode tick is KV-bandwidth-bound (the premise behind the paper's
asynchronized softmax) and capacity-bound at serving scale, so shrinking
the stored page is the highest-leverage lever left after paging, grouping,
and tiering. This benchmark measures the three claims behind
``PagedPlan.kv_dtype``:

  * **bytes per decode step** — the same greedy workload served by
    engines that differ only in ``kv_dtype``; ``EngineStats`` counts the
    real bytes behind every decode tick's attention reads (page slabs +
    scale rows), so the int8-vs-bf16 ratio is the measured, not
    theoretical, bandwidth saving. Asserted >= 1.9x for int8.
  * **resident capacity at a fixed budget** — for full-size configs, how
    many KV tokens fit in a fixed HBM page budget per precision (via
    :func:`repro.core.dispatch.kv_page_bytes`, which includes the f32
    scale rows quantization adds). Asserted >= 1.9x for int8.
  * **accuracy under the guard** — max |Δlogits| vs the bf16 baseline
    over a teacher-forced greedy decode, asserted under the dtype-derived
    tolerance from :func:`repro.kernels.quant.logits_guard_tol` (the same
    guard the plan-level scheme-swap test enforces).

Writes ``BENCH_quant.json`` at the repo root (schema: {"bytes": [...],
"capacity": [...], "accuracy": [...], "config": {...}, "mode": ...}).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row, write_artifact
from repro import configs
from repro.core import dispatch
from repro.core.plan import make_plan
from repro.kernels import quant
from repro.models.api import get_model
from repro.models.kvlayout import PagedLayout, pages_for
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_quant.json")

PAGE_SIZE = 16
MAX_NEW = 8


def _dtypes() -> list:
    out = ["bf16", "int8"]
    if quant.fp8_supported():
        out.append("fp8")
    return out


def _bytes_sweep(cfg, params, dtypes) -> list:
    """Same workload, engines differing only in kv_dtype: measured KV
    bytes behind the decode ticks."""
    rng = np.random.default_rng(3)
    sp = SamplingParams(max_new_tokens=MAX_NEW)
    reqs = [(rng.integers(1, cfg.vocab_size, size=40).astype(np.int32), sp)
            for _ in range(4)]

    widths = [8, 12, 16, 10, 10]
    print(fmt_row("kv", "B/page", "decode_KV_B", "bytes_x", "capacity_x",
                  widths=widths))
    rows, base = [], None
    for kd in dtypes:
        eng = Engine(cfg, params, num_slots=4, max_seq=256,
                     cache_kind="paged", page_size=PAGE_SIZE,
                     prefill_chunk=PAGE_SIZE, plan=make_plan("xla"),
                     kv_dtype=kd, seed=0)
        eng.run([(p.copy(), s) for p, s in reqs])
        row = dict(kv_dtype=kd,
                   kv_page_bytes=eng.stats.kv_page_bytes,
                   kv_bytes_decode_read=eng.stats.kv_bytes_decode_read,
                   decode_ticks=eng.ticks)
        if kd == "bf16":
            base = row
        row["bytes_per_step_ratio"] = (base["kv_bytes_decode_read"]
                                       / row["kv_bytes_decode_read"])
        row["capacity_ratio"] = (base["kv_page_bytes"]
                                 / row["kv_page_bytes"])
        assert row["decode_ticks"] == base["decode_ticks"], \
            "kv_dtype changed the tick count — workloads not comparable"
        rows.append(row)
        print(fmt_row(kd, row["kv_page_bytes"],
                      row["kv_bytes_decode_read"],
                      f"{row['bytes_per_step_ratio']:.2f}x",
                      f"{row['capacity_ratio']:.2f}x", widths=widths))
    for row in rows:
        if row["kv_dtype"] != "bf16":
            assert row["bytes_per_step_ratio"] >= 1.9, row
            assert row["capacity_ratio"] >= 1.9, row
    return rows


def _capacity(arch_names, dtypes, budget_bytes) -> list:
    """Resident KV tokens at a fixed HBM page budget, per precision."""
    widths = [12, 8, 12, 10, 12]
    print(fmt_row("arch", "kv", "B/page", "pages", "tokens",
                  widths=widths))
    rows = []
    for name in arch_names:
        cfg = configs.get(name)
        base_tokens = None
        for kd in dtypes:
            pb = dispatch.kv_page_bytes(cfg, page_size=64, kv_dtype=kd)
            pages = budget_bytes // pb
            tokens = pages * 64
            if kd == "bf16":
                base_tokens = tokens
            row = dict(arch=name, kv_dtype=kd, page_bytes=pb,
                       resident_pages=pages, resident_tokens=tokens,
                       capacity_ratio=tokens / base_tokens)
            rows.append(row)
            print(fmt_row(name, kd, pb, pages, tokens, widths=widths))
            if kd == "int8":
                assert row["capacity_ratio"] >= 1.9, row
    return rows


def _accuracy(cfg, params, dtypes, steps) -> list:
    """Teacher-forced decode: max |Δlogits| vs bf16 under the guard."""
    api = get_model(cfg)
    num_slots = 2
    max_seq = pages_for(steps + 1, PAGE_SIZE) * PAGE_SIZE
    rng = np.random.default_rng(5)
    toks = rng.integers(1, cfg.vocab_size,
                        size=(steps, num_slots)).astype(np.int32)

    from repro.models.layers import LayerCtx
    ctx = LayerCtx(cfg=cfg, plan=make_plan("xla"))

    per_dtype = {}
    for kd in dtypes:
        pool = BlockPool(num_slots * pages_for(max_seq, PAGE_SIZE),
                         PAGE_SIZE)
        mgr = PagedSlotManager(num_slots, max_seq, pool)
        for i in range(num_slots):
            assert mgr.try_assign(i, steps, 1) is not None
        bt = mgr.block_tables()
        cache = api.init_cache(
            PagedLayout(pool.num_pages, PAGE_SIZE, kd))
        lengths = jnp.zeros((num_slots,), jnp.int32)
        trace = []
        for t in range(steps):
            logits, cache = api.decode_step(
                ctx, params, jnp.asarray(toks[t]), cache, lengths,
                block_tables=bt)
            lengths = lengths + 1
            trace.append(np.asarray(logits, np.float32))
        per_dtype[kd] = np.stack(trace)

    scale = float(np.abs(per_dtype["bf16"]).max())
    widths = [8, 14, 14, 8]
    print(fmt_row("kv", "max_dlogits", "guard_atol", "pass",
                  widths=widths))
    rows = []
    for kd in dtypes:
        if kd == "bf16":
            continue
        dl = float(np.abs(per_dtype[kd] - per_dtype["bf16"]).max())
        atol = quant.logits_guard_tol(quant.spec_for(kd)) * max(scale, 1.0)
        ok = dl <= atol
        rows.append(dict(kv_dtype=kd, max_dlogits=dl, guard_atol=atol,
                         logit_scale=scale, within_guard=ok))
        print(fmt_row(kd, f"{dl:.4f}", f"{atol:.4f}", ok, widths=widths))
        assert ok, f"{kd} decode logits exceed the accuracy guard"
    return rows


def run(quick: bool = False) -> dict:
    print("\n== kv_quant: KV bytes / capacity / accuracy per kv_dtype ==")
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    dtypes = _dtypes()
    archs = ("qwen2-0.5b",) if quick else ("qwen2-0.5b", "llama2-7b")
    steps = 12 if quick else 24
    budget = 1 << 30   # 1 GiB of KV pages

    rows_bytes = _bytes_sweep(cfg, params, dtypes)
    rows_cap = _capacity(archs, dtypes, budget)
    rows_acc = _accuracy(cfg, params, dtypes, steps)

    result = {
        "config": dict(arch=cfg.name, page_size=PAGE_SIZE, max_new=MAX_NEW,
                       dtypes=dtypes, budget_bytes=budget,
                       teacher_forced_steps=steps,
                       fp8_supported=quant.fp8_supported()),
        "bytes": rows_bytes,
        "capacity": rows_cap,
        "accuracy": rows_acc,
    }
    path = write_artifact(OUT_PATH, result, quick)
    print(f"  [kv_quant -> {os.path.normpath(path)}]")
    return result


if __name__ == "__main__":
    t0 = time.time()
    run()
    print(f"[{time.time()-t0:.1f}s]")
