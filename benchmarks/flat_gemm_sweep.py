"""Flat-GEMM B_N trade-off (paper Fig. 7 + Eq. 5), on TPU-v5e terms.

For M=8 and the paper's spread of N sizes, sweep the N-tile B_N and report
the Eq.-5 compute/memory ratio, the grid parallelism N/B_N, the kernel's
double-buffered VMEM claim, and the roofline-model time. The Fig.-7 shape
reproduces: small N is parallelism-bound (best B_N keeps N/B_N near the
pipeline depth), large N becomes memory-bound (bigger B_N amortizes the
A-tile reload until VMEM caps it). The chosen tile of `pick_bn` is marked.
"""
from __future__ import annotations

from benchmarks.common import fmt_row
from repro import hardware
from repro.kernels.flat_gemm import pick_bk, pick_bn

SPEC = hardware.DEFAULT


def eq5_ratio(m: int, k: int, bn: int) -> float:
    """Paper Eq. 5: compute/memory ratio of the tiled flat GEMM."""
    return 2.0 * m * k / (k + m * k / bn + m)


def model_time(m: int, n: int, k: int, bn: int, bk: int,
               dtype_bytes: int = 2) -> float:
    """HBM-roofline time of one flat GEMM with tiles (bn, bk) + pipeline
    fill bubble per N-stripe (the Mosaic grid analogue of Fig. 7)."""
    m_pad = max(8, -(-m // 8) * 8)
    bytes_moved = (m * k + k * n + m * n) * dtype_bytes
    mem = bytes_moved / SPEC.hbm_bw
    compute = 2 * m_pad * n * k / SPEC.peak_flops_bf16
    n_stripes = max(n // bn, 1)
    bubble = 2e-6 * max(1.0, 8.0 / n_stripes)  # under-filled pipeline
    return max(mem, compute) + bubble


def run(quick: bool = False) -> list[dict]:
    print("\n== flat_gemm_sweep: Eq.-5 trade-off, M=8, K=4096 (Fig. 7) ==")
    rows = []
    m, k = 8, 4096
    ns = (4096, 11008) if quick else (1024, 4096, 11008, 28672)
    bns = (128, 256, 512, 1024, 2048)
    hdr = ["N \\ B_N"] + [str(b) for b in bns] + ["pick_bn"]
    print(fmt_row(*hdr, widths=[10] + [11] * len(bns) + [9]))
    for n in ns:
        cells = []
        for bn in bns:
            if n % bn:
                cells.append("-")
                continue
            bk = pick_bk(m, bn, k)
            t = model_time(m, n, k, bn, bk)
            vmem = (2 * (8 * bk + bk * bn) * 2 + 8 * bn * 4) / 2**20
            cells.append(f"{t*1e6:.1f}us/{vmem:.0f}M")
            rows.append(dict(n=n, bn=bn, bk=bk, time_us=t * 1e6,
                             vmem_mb=vmem, ratio=eq5_ratio(m, k, bn)))
        chosen = pick_bn(m, n, k)
        print(fmt_row(n, *cells, chosen, widths=[10] + [11] * len(bns) + [9]))
    print("  (cell = modeled time / double-buffered VMEM claim; "
          "'-' = B_N does not divide N)")

    # the "pad to 8 not 64" accounting (the headline T2 claim)
    print("\n  M-padding waste, M=8 flat GEMM:")
    for pad_to in (8, 64, 128):
        waste = (pad_to - m) / pad_to * 100
        print(f"    pad M->{pad_to:<4} wasted MXU issue slots: {waste:.0f}%")
    rows.append(dict(pad8_waste=0.0, pad64_waste=87.5, pad128_waste=93.75))
    return rows


if __name__ == "__main__":
    run()
