"""repro — FlashDecoding++ on TPU: a JAX + Pallas training/inference framework."""
__version__ = "0.1.0"
