"""repro — FlashDecoding++ on TPU: a JAX + Pallas training/inference framework."""
from repro.distributed import shardmap_compat  # noqa: F401  (jax.shard_map alias)

__version__ = "0.1.0"
