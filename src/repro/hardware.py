"""Hardware descriptions used by the roofline model and the heuristic dataflow.

The TARGET platform is TPU v5e; this container executes on CPU (kernels are
validated with ``interpret=True``), so every performance decision in the
framework is driven by these constants rather than wall-clock measurements.
A real-hardware timing hook exists in :mod:`repro.core.dispatch` for when the
framework runs on actual TPUs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware description.

    Attributes:
      peak_flops_bf16: peak bf16 FLOP/s of the MXU.
      peak_flops_vpu_f32: peak f32 FLOP/s of the vector unit (used by the
        GEMV/ImplA cost model — the VPU path does not touch the MXU).
      hbm_bw: HBM bandwidth, bytes/s.
      host_bw: host↔device link bandwidth, bytes/s (PCIe-class; what a
        KV page pays per direction to move between the device pool and
        the host tier of the KV hierarchy — the swap-vs-re-prefill
        roofline's denominator).
      ici_bw_per_link: per-link ICI bandwidth, bytes/s.
      ici_links: number of ICI links per chip taking part in a 2D torus.
      hbm_bytes: HBM capacity per chip.
      vmem_bytes: VMEM (on-chip vector memory) capacity per core.
      mxu_dim: systolic array dimension (128 for all current TPUs).
      lane: vector lane count (last-dim tiling atom).
      sublane_f32 / sublane_bf16: second-minor tiling atom per dtype.
    """

    name: str
    peak_flops_bf16: float
    peak_flops_vpu_f32: float
    hbm_bw: float
    host_bw: float
    ici_bw_per_link: float
    ici_links: int
    hbm_bytes: int
    vmem_bytes: int
    mxu_dim: int = 128
    lane: int = 128
    sublane_f32: int = 8
    sublane_bf16: int = 16

    def sublane(self, dtype_bytes: int) -> int:
        return {4: self.sublane_f32, 2: self.sublane_bf16, 1: 32}.get(dtype_bytes, 8)


# Roofline constants mandated by the assignment: 197 TFLOP/s bf16 per chip,
# 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_vpu_f32=197e12 / 32,  # VPU is ~1/32 of MXU throughput at f32
    hbm_bw=819e9,
    host_bw=16e9,  # PCIe-gen4-class effective host link, per direction
    ici_bw_per_link=50e9,
    ici_links=4,  # 2D torus: 4 links (x+, x-, y+, y-)
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

DEFAULT = TPU_V5E


def matmul_flops(m: int, n: int, k: int) -> int:
    return 2 * m * n * k


def bytes_of(shape: tuple[int, ...], dtype_bytes: int = 2) -> int:
    n = dtype_bytes
    for s in shape:
        n *= s
    return n
