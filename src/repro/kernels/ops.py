"""Jit'd public wrappers around the Pallas kernels, dispatched by plan.

``matmul`` is the single GEMM entry point used by the model zoo: it routes
a (M, K) × (K, N) workload to ImplA/ImplB/ImplC per the plan's tuned
[K, N] inflection entries (or an explicit ``impl=``). The attention front
doors wrap the fused kernels with the T1 overflow fallback.

Every wrapper takes exactly one ``plan=`` operand — an
:class:`~repro.core.plan.ExecutionPlan` (``None`` = the untuned
``DEFAULT_PLAN``) deciding backend (``"pallas"`` kernels vs. the XLA
reference math in ``ref.py`` — the CPU container cannot lower Mosaic, so
the default plan is XLA and kernels are validated with
``interpret=True``), softmax scheme, decode ``block_k``, the chunked
prefill threshold, and whether the ``lax.cond`` overflow-recompute branch
is emitted. Plans choose *which* implementation runs, never the math.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import SoftmaxPhiConfig
from repro.core.dispatch import Impl
from repro.core.plan import DEFAULT_PLAN, ExecutionPlan
from repro.kernels import ref
from repro.kernels.chunk_attention import (
    paged_chunk_attention_sync,
    paged_chunk_attention_unified_max,
)
from repro.kernels.decode_attention import (
    decode_attention_sync,
    decode_attention_unified_max,
    paged_decode_attention_sync,
    paged_decode_attention_unified_max,
)
from repro.kernels.flat_gemm import flat_gemm
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.gemv import gemv
from repro.kernels.group_attention import (
    grouped_paged_decode_attention_unified_max,
)

_INTERPRET = jax.default_backend() == "cpu"


def _unified(phi_cfg: SoftmaxPhiConfig, scheme: str) -> bool:
    """T1 unified-max runs only when the model has a calibrated φ *and*
    the plan asks for it; either veto falls back to the sync scheme."""
    return phi_cfg.active and scheme == "unified_max"


def _wparts(w):
    """Split a GEMM weight operand into ``(array, per-output-channel
    scale-or-None)``. Quantized weights arrive as the ``{"codes",
    "scale"}`` dict the engine's quantize-at-load pass produces
    (models/wquant.py); full-precision weights are plain arrays. The
    dict form is the single structural signal that threads dequant
    scales into the kernels — model call sites never change, and the
    plain-array path stays expression-identical (the bitwise bf16
    contract)."""
    if isinstance(w, dict):
        return w["codes"], w["scale"]
    return w, None


# ---------------------------------------------------------------------------
# GEMM front door (T3)
# ---------------------------------------------------------------------------


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    plan: Optional[ExecutionPlan] = None,
    impl: Optional[Impl] = None,
) -> jax.Array:
    """Plan-dispatched GEMM. x: (..., K), w: (K, N) array or quantized
    ``{"codes", "scale"}`` leaf."""
    mp = (plan or DEFAULT_PLAN).matmul
    w, w_scale = _wparts(w)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w.shape[-1]
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)

    if impl is None:
        impl = mp.pick(m, k, n)

    if mp.backend != "pallas" or impl is Impl.XLA_DOT:
        out = ref.flat_gemm_ref(x2, w, w_scale=w_scale)
    elif impl is Impl.GEMV:
        out = gemv(x2, w, w_scale=w_scale, interpret=_INTERPRET)
    else:
        out = flat_gemm(x2, w, w_scale=w_scale, interpret=_INTERPRET)
    return out.reshape(*lead, n)


def fused_ffn(
    x: jax.Array,        # (..., K)
    w_gate: jax.Array,   # (K, N)
    w_up: jax.Array,     # (K, N)
    *,
    activation: str = "swiglu",
    plan: Optional[ExecutionPlan] = None,
) -> jax.Array:
    """act(x @ w_gate) * (x @ w_up) — the single fused epilogue kernel
    when the plan's ``fused_ffn`` entry says ``fused`` on the Pallas
    backend (kernels/fused_ffn.py), oracle math otherwise."""
    fp = (plan or DEFAULT_PLAN).fused_ffn
    w_gate, wg_scale = _wparts(w_gate)
    w_up, wu_scale = _wparts(w_up)
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = w_gate.shape[-1]
    x2 = x.reshape(-1, k)
    if fp.fused and fp.backend == "pallas":
        from repro.kernels.fused_ffn import fused_ffn_up
        out = fused_ffn_up(x2, w_gate, w_up, activation=activation,
                           wg_scale=wg_scale, wu_scale=wu_scale,
                           interpret=_INTERPRET)
    else:
        out = ref.fused_ffn_up_ref(x2, w_gate, w_up, activation=activation,
                                   wg_scale=wg_scale, wu_scale=wu_scale)
    return out.reshape(*lead, n)


def decode_ingest(
    x: jax.Array,             # (B, 1, D) residual-stream input
    norm_scale: jax.Array,    # (D,)
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    positions: jax.Array,     # (B,) int32
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    eps: float = 1e-6,
    use_rope: bool = True,
    bq: jax.Array | None = None,
    bk: jax.Array | None = None,
    bv: jax.Array | None = None,
    plan: Optional[ExecutionPlan] = None,
):
    """Fused decode-ingest stage: rmsnorm → QKV → bias → rope in one
    seam (kernels/decode_fuse.py on the Pallas backend, the bit-exact
    split-chain composition in ``ref.py`` otherwise). Returns
    q (B,1,HQ,Dh), k/v (B,1,HK,Dh)."""
    fp = (plan or DEFAULT_PLAN).decode_fusion
    wq, wq_scale = _wparts(wq)
    wk, wk_scale = _wparts(wk)
    wv, wv_scale = _wparts(wv)
    if fp.backend == "pallas":
        from repro.kernels.decode_fuse import decode_ingest_fused
        b, s, d = x.shape
        q, k, v = decode_ingest_fused(
            x.reshape(b * s, d), norm_scale, wq, wk, wv, positions,
            num_heads=num_heads, num_kv_heads=num_kv_heads,
            head_dim=head_dim, rope_theta=rope_theta, eps=eps,
            use_rope=use_rope, bq=bq, bk_bias=bk, bv=bv,
            wq_scale=wq_scale, wk_scale=wk_scale, wv_scale=wv_scale,
            interpret=_INTERPRET,
        )
        return (q.reshape(b, s, num_heads, head_dim),
                k.reshape(b, s, num_kv_heads, head_dim),
                v.reshape(b, s, num_kv_heads, head_dim))
    return ref.decode_ingest_ref(
        x, norm_scale, wq, wk, wv, positions,
        num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
        rope_theta=rope_theta, eps=eps, use_rope=use_rope,
        bq=bq, bk=bk, bv=bv,
        wq_scale=wq_scale, wk_scale=wk_scale, wv_scale=wv_scale,
    )


def oproj_residual(
    o: jax.Array,       # (B, 1, HQ*Dh) attention outputs
    wo: jax.Array,      # (HQ*Dh, D)
    resid: jax.Array,   # (B, 1, D)
    *,
    plan: Optional[ExecutionPlan] = None,
) -> jax.Array:
    """Fused attention epilogue ``resid + o @ wo`` (the o_proj GEMM with
    the residual add riding its epilogue on the Pallas backend; the
    bit-exact split composition otherwise)."""
    fp = (plan or DEFAULT_PLAN).decode_fusion
    wo, wo_scale = _wparts(wo)
    if fp.backend == "pallas":
        from repro.kernels.decode_fuse import oproj_residual_fused
        b, s, qd = o.shape
        out = oproj_residual_fused(
            o.reshape(b * s, qd), wo, resid.reshape(b * s, -1),
            w_scale=wo_scale, interpret=_INTERPRET,
        )
        return out.reshape(resid.shape)
    return ref.oproj_residual_ref(o, wo, resid, w_scale=wo_scale)


def ffn_norm(
    x: jax.Array,           # (B, 1, D) residual-stream input (un-normed)
    norm_scale: jax.Array,  # (D,)
    w_gate: jax.Array,      # (D, F)
    w_up: jax.Array,        # (D, F)
    *,
    activation: str = "swiglu",
    eps: float = 1e-6,
    plan: Optional[ExecutionPlan] = None,
) -> jax.Array:
    """Fused mlp-ingest stage: rmsnorm → gate/up GEMMs → act(g)*u in one
    seam (kernels/decode_fuse.py on the Pallas backend; on XLA the
    oracle composes whichever split chain the plan's ``fused_ffn`` knob
    selects, so the fused granularities stay bitwise). Returns (B, 1, F)
    — feed to :func:`oproj_residual` with ``w_down`` for the full seam."""
    p = plan or DEFAULT_PLAN
    fp = p.decode_fusion
    w_gate, wg_scale = _wparts(w_gate)
    w_up, wu_scale = _wparts(w_up)
    if fp.backend == "pallas":
        from repro.kernels.decode_fuse import ffn_norm_fused
        b, s, d = x.shape
        out = ffn_norm_fused(
            x.reshape(b * s, d), norm_scale, w_gate, w_up,
            activation=activation, eps=eps,
            wg_scale=wg_scale, wu_scale=wu_scale, interpret=_INTERPRET,
        )
        return out.reshape(b, s, -1)
    return ref.ffn_norm_ref(x, norm_scale, w_gate, w_up,
                            activation=activation, eps=eps,
                            fused=p.fused_ffn.fused,
                            wg_scale=wg_scale, wu_scale=wu_scale)


# ---------------------------------------------------------------------------
# Attention front doors (T1)
# ---------------------------------------------------------------------------


def attention_prefill(
    q: jax.Array,   # (B, Sq, HQ, D)
    k: jax.Array,   # (B, Sk, HK, D)
    v: jax.Array,
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    causal: bool = True,
    sliding_window: int = 0,
    plan: Optional[ExecutionPlan] = None,
) -> jax.Array:
    """Prefill attention with T1 + overflow recomputation fallback.

    The plan's ``attention_prefill`` entry decides: the softmax scheme
    (``unified_max`` needs an active φ config), the chunking threshold —
    quadratic (B,H,S,S) scores are only materialized on the XLA path below
    it; above, the blockwise T1 scheme keeps live memory ≈ (B,H,block_q,S),
    mandatory for the 32k dry-run cells — and whether the ``lax.cond``
    recompute branch is emitted (``fallback=False`` is dry-run hygiene so
    cost_analysis doesn't double-count; the calibrated φ band makes the
    branch probability ≈ 0 — paper §3).
    """
    ap = (plan or DEFAULT_PLAN).attention_prefill
    unified = _unified(phi_cfg, ap.scheme)
    if ap.backend != "pallas":
        if q.shape[1] * k.shape[1] >= ap.chunk_threshold ** 2:
            return ref.attention_prefill_chunked(
                q, k, v, causal=causal, sliding_window=sliding_window,
                phi=phi_cfg.phi if unified else None,
            )
        return ref.attention_prefill_ref(
            q, k, v, causal=causal, sliding_window=sliding_window
        )
    if not unified:
        return flash_prefill(
            q, k, v, causal=causal, unified_max=False,
            sliding_window=sliding_window, interpret=_INTERPRET,
        )
    out, stat = flash_prefill(
        q, k, v, causal=causal, unified_max=True, phi=phi_cfg.phi,
        sliding_window=sliding_window, interpret=_INTERPRET,
    )
    if not ap.fallback:
        return out
    overflow = jnp.any(stat > phi_cfg.band[1])

    def recompute(_):
        # paper §3 "Recomputation": rerun with the synchronized scheme
        return flash_prefill(
            q, k, v, causal=causal, unified_max=False,
            sliding_window=sliding_window, interpret=_INTERPRET,
        )

    return jax.lax.cond(overflow, recompute, lambda _: out, operand=None)


def attention_decode(
    q: jax.Array,        # (B, HQ, D) — one new token per sequence
    k_cache: jax.Array,  # (B, S, HK, D)
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,)
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    plan: Optional[ExecutionPlan] = None,
    shard=None,
) -> jax.Array:
    """Decode attention with T1 + overflow recomputation fallback.

    The plan's ``attention_decode`` entry decides scheme, the KV grid
    ``block_k``, and the recompute branch. ``shard`` (optional, a
    LayerCtx.shard) pins the split-KV dataflow on the XLA path: scores
    stay sequence-sharded and GSPMD combines the per-shard (num, den)
    partials with a single additive all-reduce — the pod-scale payoff of
    the unified-max softmax.
    """
    dp = (plan or DEFAULT_PLAN).attention_decode
    unified = _unified(phi_cfg, dp.scheme)
    if dp.backend != "pallas":
        if not unified:
            return ref.attention_decode_ref(
                q, k_cache, v_cache, lengths, shard=shard)
        out, stat = ref.attention_decode_unified_max_ref(
            q, k_cache, v_cache, lengths, phi=phi_cfg.phi, shard=shard
        )
        if not dp.fallback:
            return out
        overflow = jnp.any(stat > phi_cfg.band[1])
        safe = functools.partial(
            ref.attention_decode_ref, q, k_cache, v_cache, lengths,
            shard=shard,
        )
        return jax.lax.cond(overflow, lambda _: safe(), lambda _: out, None)

    # kernel layout: (B, HK, S, D)
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    if not unified:
        return decode_attention_sync(
            q, kt, vt, lengths, block_k=dp.block_k, interpret=_INTERPRET
        )
    out, stat = decode_attention_unified_max(
        q, kt, vt, lengths, phi=phi_cfg.phi, block_k=dp.block_k,
        interpret=_INTERPRET,
    )
    if not dp.fallback:
        return out
    overflow = jnp.any(stat > phi_cfg.band[1])

    def recompute(_):
        return decode_attention_sync(
            q, kt, vt, lengths, block_k=dp.block_k, interpret=_INTERPRET
        )

    return jax.lax.cond(overflow, recompute, lambda _: out, operand=None)


def attention_decode_paged(
    q: jax.Array,             # (B, HQ, D) — one new token per sequence
    k_pool: jax.Array,        # (NP, PS, HK, D) — shared block pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32 — logical block -> physical page
    lengths: jax.Array,       # (B,)
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    plan: Optional[ExecutionPlan] = None,
    shard=None,
    groups=None,
    k_scale: jax.Array | None = None,   # (NP, HK) f32 — quantized pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Decode attention over a block-paged KV cache (T1 + overflow fallback).

    Paged twin of :func:`attention_decode`, governed by the plan's
    ``paged`` entry: the KV cache is a flat page pool shared by all
    sequences and each sequence's pages are named by its block table. On
    the XLA backend the pages are gathered into a dense per-sequence view
    (bitwise identical to the dense path when NB*PS == max_seq); on the
    Pallas backend the block table is scalar-prefetched so the kernel
    DMAs exactly the pages each sequence owns.

    ``groups`` (a :class:`~repro.kernels.group_attention.DecodeGroups`)
    activates the prefix-shared grouped path: the shared-prefix pages are
    read once per group and merged with each request's private tail via
    the unified-max combine. On the XLA backend the dense view is
    reconstructed *through* the group plan
    (:func:`~repro.kernels.ref.gather_grouped_kv`) and fed to the
    identical ref math — grouped outputs are bitwise-equal to ungrouped by
    construction. On the Pallas backend the two-stage group kernel runs
    for the unified-max scheme (the sync scheme and the overflow
    recompute fall back to the ungrouped sync kernel).

    ``k_scale``/``v_scale`` mark the pools as quantized codes (the
    kv_dtype subsystem, :mod:`repro.serving.kvquant`). The XLA backend
    takes a pool-level f32 dequant view up front — gather commutes with
    the per-(page, head) scale multiply, so every ref below sees exactly
    the values the Pallas kernels reconstruct per page in VMEM.
    """
    pp = (plan or DEFAULT_PLAN).paged
    unified = _unified(phi_cfg, pp.scheme)
    if pp.backend != "pallas":
        if k_scale is not None:
            k_pool = ref.dequantize_pool_ref(k_pool, k_scale)
            v_pool = ref.dequantize_pool_ref(v_pool, v_scale)
        if not unified:
            if groups is not None:
                return ref.attention_decode_grouped_ref(
                    q, k_pool, v_pool, block_tables, lengths, groups,
                    shard=shard)
            return ref.attention_decode_paged_ref(
                q, k_pool, v_pool, block_tables, lengths, shard=shard)
        if groups is not None:
            out, stat = ref.attention_decode_grouped_unified_max_ref(
                q, k_pool, v_pool, block_tables, lengths, groups,
                phi=phi_cfg.phi, shard=shard,
            )
            safe = functools.partial(
                ref.attention_decode_grouped_ref, q, k_pool, v_pool,
                block_tables, lengths, groups, shard=shard,
            )
        else:
            out, stat = ref.attention_decode_paged_unified_max_ref(
                q, k_pool, v_pool, block_tables, lengths, phi=phi_cfg.phi,
                shard=shard,
            )
            safe = functools.partial(
                ref.attention_decode_paged_ref, q, k_pool, v_pool,
                block_tables, lengths, shard=shard,
            )
        if not pp.fallback:
            return out
        overflow = jnp.any(stat > phi_cfg.band[1])
        return jax.lax.cond(overflow, lambda _: safe(), lambda _: out, None)

    if not unified:
        # grouped sync has no kernel — the ungrouped sync kernel is exact
        return paged_decode_attention_sync(
            q, k_pool, v_pool, block_tables, lengths,
            k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET
        )
    if groups is not None:
        out, stat = grouped_paged_decode_attention_unified_max(
            q, k_pool, v_pool, block_tables, lengths, groups,
            phi=phi_cfg.phi, k_scale=k_scale, v_scale=v_scale,
            interpret=_INTERPRET,
        )
    else:
        out, stat = paged_decode_attention_unified_max(
            q, k_pool, v_pool, block_tables, lengths, phi=phi_cfg.phi,
            k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET,
        )
    if not pp.fallback:
        return out
    overflow = jnp.any(stat > phi_cfg.band[1])

    def recompute(_):
        return paged_decode_attention_sync(
            q, k_pool, v_pool, block_tables, lengths,
            k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET
        )

    return jax.lax.cond(overflow, recompute, lambda _: out, operand=None)


def attention_chunk(
    q: jax.Array,        # (B, C, HQ, D) — a chunk of new tokens
    k_cache: jax.Array,  # (B, S, HK, D) — chunk KV already scattered in
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,) lengths before the chunk
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    plan: Optional[ExecutionPlan] = None,
) -> jax.Array:
    """Chunked-prefill attention: C tokens attend to prefix + chunk.

    The decode-shaped admission path: long prompts stream through this in
    fixed-size chunks instead of compiling one prefill per prompt bucket.
    Runs the ref math on both backends today (the chunk GEMMs are
    MXU-shaped already; a fused kernel is a ROADMAP follow-on), with the
    scheme and safe-softmax recompute fallback taken from the plan's
    ``attention_prefill`` entry (this is a prefill-phase op).
    """
    ap = (plan or DEFAULT_PLAN).attention_prefill
    if not _unified(phi_cfg, ap.scheme):
        return ref.attention_chunk_ref(q, k_cache, v_cache, lengths, phi=None)
    out, stat = ref.attention_chunk_unified_max_ref(
        q, k_cache, v_cache, lengths, phi=phi_cfg.phi)
    if not ap.fallback:
        return out
    overflow = jnp.any(stat > phi_cfg.band[1])
    safe = functools.partial(
        ref.attention_chunk_ref, q, k_cache, v_cache, lengths, phi=None)
    return jax.lax.cond(overflow, lambda _: safe(), lambda _: out, None)


def attention_chunk_paged(
    q: jax.Array,
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB)
    lengths: jax.Array,
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    plan: Optional[ExecutionPlan] = None,
    k_scale: jax.Array | None = None,   # (NP, HK) f32 — quantized pools
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Paged twin of :func:`attention_chunk`, governed by the plan's
    ``paged`` entry (scheme, fallback, and ``gather_chunk`` mode).

    ``gather_chunk="fused"`` on the Pallas backend runs the fused chunk
    kernel (:mod:`repro.kernels.chunk_attention`): K/V pages are read in
    place through scalar-prefetched block tables — no dense ``(B, NB*PS)``
    view is ever materialized — with the T1 unified-max scheme and the
    sync-kernel overflow recompute, exactly the decode kernel's contract.
    Quantized pools (``k_scale``/``v_scale``) dequantize per page in VMEM
    on this path; the gather path below takes the pool-level dequant view
    first (elementwise-identical, see :func:`attention_decode_paged`).

    Every other combination gathers the *caller-supplied* table into a
    dense view and reuses :func:`attention_chunk`: on the XLA backend the
    fused mode's win is realized upstream — ``Engine._prefill_chunked``
    bounds the table to O(resident pages), and because trailing masked
    pages contribute exact zeros, the bounded gather is bitwise identical
    to the full one (so greedy outputs match across modes by
    construction).
    """
    pp = (plan or DEFAULT_PLAN).paged
    if pp.backend == "pallas" and pp.gather_chunk == "fused":
        unified = _unified(phi_cfg, pp.scheme)
        if not unified:
            return paged_chunk_attention_sync(
                q, k_pool, v_pool, block_tables, lengths,
                k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET)
        out, stat = paged_chunk_attention_unified_max(
            q, k_pool, v_pool, block_tables, lengths, phi=phi_cfg.phi,
            k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET)
        if not pp.fallback:
            return out
        overflow = jnp.any(stat > phi_cfg.band[1])

        def recompute(_):
            return paged_chunk_attention_sync(
                q, k_pool, v_pool, block_tables, lengths,
                k_scale=k_scale, v_scale=v_scale, interpret=_INTERPRET)

        return jax.lax.cond(overflow, recompute, lambda _: out, operand=None)

    if k_scale is not None:
        k_pool = ref.dequantize_pool_ref(k_pool, k_scale)
        v_pool = ref.dequantize_pool_ref(v_pool, v_scale)
    k = ref.gather_paged_kv(k_pool, block_tables)
    v = ref.gather_paged_kv(v_pool, block_tables)
    return attention_chunk(q, k, v, lengths, phi_cfg=phi_cfg, plan=plan)
