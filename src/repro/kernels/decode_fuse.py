"""Fused decode-layer stage kernels (the DecodeFusionPlan seams).

Per-token decode runs a long chain of small memory-bound ops per layer;
each op boundary pays a dispatch bubble and an HBM round-trip of its
(M, ·) activation. These kernels collapse the two attention-side seams
the fused-FFN kernel does not cover:

  * :func:`decode_ingest_fused` — rmsnorm → QKV projections → bias →
    rope in one pass. The (M, D) residual-stream tile stays resident in
    VMEM: the norm runs once into a normalized-x scratch, the three
    weight streams share it across the K grid, and the rope rotation is
    applied to the q/k accumulators in the epilogue while they are still
    in VMEM — the normed activations and the pre-rope q/k never touch
    HBM.
  * :func:`oproj_residual_fused` — attention epilogue ``resid + o @ wo``:
    the residual add rides the GEMM epilogue, saving the (M, D)
    attention-output round-trip and one launch. The same kernel serves
    the FFN down-projection seam (``resid + h @ w_down``) — both are
    "GEMM into the residual stream" shapes.
  * :func:`ffn_norm_fused` — mlp_norm → gate/up projections →
    activation in one pass: the fused-FFN kernel's epilogue with the
    rmsnorm pulled inside, so the normed (M, D) activations never
    round-trip HBM between the norm and the GEMM pair.

Decode M is tiny (the batch), so everything is flat-GEMM shaped: M pads
to the 8-sublane atom and the K dimension streams (same discipline as
``kernels/flat_gemm.py`` / ``kernels/fused_ffn.py``). The K-streamed f32
tile accumulation reassociates the dot relative to the single-dot
oracles in ``ref.py``, so kernel-vs-oracle equality is dtype-eps bounded
(like every other Pallas GEMM here), while the XLA fused path dispatches
the oracles themselves and stays bit-identical to the split chain.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

from repro.kernels.flat_gemm import pick_bk, pick_bn, round_up


def _rope_pairs(t, cos, sin, n_heads: int, head_dim: int):
    """Rotate-half rope on a flat (M, n_heads*head_dim + pad) tile.

    Static per-head slices (no in-kernel reshape): head h's first half
    pairs with its second half, exactly ``models.layers.rope``'s
    ``[x1*cos - x2*sin, x2*cos + x1*sin]`` layout. Pad columns past the
    real heads pass through untouched.
    """
    half = head_dim // 2
    parts = []
    for h in range(n_heads):
        x1 = t[:, h * head_dim:h * head_dim + half]
        x2 = t[:, h * head_dim + half:(h + 1) * head_dim]
        parts.append(x1 * cos - x2 * sin)
        parts.append(x2 * cos + x1 * sin)
    if t.shape[1] > n_heads * head_dim:
        parts.append(t[:, n_heads * head_dim:])
    return jnp.concatenate(parts, axis=1)


def _ingest_kernel(x_ref, scale_ref, wq_ref, wk_ref, wv_ref,
                   bq_ref, bk_ref, bv_ref, pos_ref, *refs,
                   d_real: int, bk: int, num_heads: int,
                   num_kv_heads: int, head_dim: int, theta: float,
                   eps: float, use_rope: bool, quantized: bool = False):
    # The quantized variant appends three per-output-channel step operands
    # ((1, NQ)/(1, NK) f32, full-width like the biases) after ``pos``; the
    # branches are trace-time, so the bf16 kernel's jaxpr is unchanged.
    if quantized:
        (sq_ref, sk_ref, sv_ref,
         outq_ref, outk_ref, outv_ref,
         xn_ref, accq_ref, acck_ref, accv_ref) = refs
    else:
        (outq_ref, outk_ref, outv_ref,
         xn_ref, accq_ref, acck_ref, accv_ref) = refs
    ki = pl.program_id(0)
    n_k = pl.num_programs(0)

    @pl.when(ki == 0)
    def _init():
        # rmsnorm once into the resident normed-x scratch (cast back to
        # the activation dtype before the dot, like the split chain);
        # zero K-pad columns keep the sum exact, the divisor is real D
        xf = x_ref[...].astype(jnp.float32)
        var = jnp.sum(xf * xf, axis=-1, keepdims=True) / d_real
        xn = xf * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(
            jnp.float32)
        xn_ref[...] = xn.astype(xn_ref.dtype)
        accq_ref[...] = jnp.zeros_like(accq_ref)
        acck_ref[...] = jnp.zeros_like(acck_ref)
        accv_ref[...] = jnp.zeros_like(accv_ref)

    xt = xn_ref[:, pl.ds(ki * bk, bk)]
    dims = (((1,), (0,)), ((), ()))
    wq_t = wq_ref[...].astype(xt.dtype) if quantized else wq_ref[...]
    wk_t = wk_ref[...].astype(xt.dtype) if quantized else wk_ref[...]
    wv_t = wv_ref[...].astype(xt.dtype) if quantized else wv_ref[...]
    accq_ref[...] += jax.lax.dot_general(
        xt, wq_t, dims, preferred_element_type=jnp.float32)
    acck_ref[...] += jax.lax.dot_general(
        xt, wk_t, dims, preferred_element_type=jnp.float32)
    accv_ref[...] += jax.lax.dot_general(
        xt, wv_t, dims, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _fin():
        # round the f32 accumulators to the activation dtype *before* the
        # bias add and rope, mirroring the split chain's rounding points
        # (matmul output cast, bf16 bias add, rope promoting to f32);
        # weight steps dequantize on the f32 accumulators first
        accq, acck, accv = accq_ref[...], acck_ref[...], accv_ref[...]
        if quantized:
            accq = accq * sq_ref[...]
            acck = acck * sk_ref[...]
            accv = accv * sv_ref[...]
        q = accq.astype(outq_ref.dtype) + bq_ref[...]
        k = acck.astype(outk_ref.dtype) + bk_ref[...]
        v = accv.astype(outv_ref.dtype) + bv_ref[...]
        if use_rope:
            half = head_dim // 2
            ih = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
            freq = theta ** (-ih / half)
            ang = pos_ref[...] * freq            # (M, half)
            cos, sin = jnp.cos(ang), jnp.sin(ang)
            q = _rope_pairs(q, cos, sin, num_heads, head_dim)
            k = _rope_pairs(k, cos, sin, num_kv_heads, head_dim)
        outq_ref[...] = q.astype(outq_ref.dtype)
        outk_ref[...] = k.astype(outk_ref.dtype)
        outv_ref[...] = v.astype(outv_ref.dtype)


def decode_ingest_fused(
    x: jax.Array,             # (M, D) residual-stream rows
    norm_scale: jax.Array,    # (D,)
    wq: jax.Array,            # (D, HQ*Dh)
    wk: jax.Array,            # (D, HK*Dh)
    wv: jax.Array,
    positions: jax.Array,     # (M,) int32
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    eps: float = 1e-6,
    use_rope: bool = True,
    bq: jax.Array | None = None,
    bk_bias: jax.Array | None = None,
    bv: jax.Array | None = None,
    wq_scale: jax.Array | None = None,   # (HQ*Dh,) f32 -> wq is codes
    wk_scale: jax.Array | None = None,   # (HK*Dh,) f32 -> wk is codes
    wv_scale: jax.Array | None = None,
    block_k: int = 0,
    interpret: bool = False,
):
    """Fused rmsnorm → QKV → bias → rope. Returns flat q (M, HQ*Dh) and
    k/v (M, HK*Dh) in x.dtype (the caller owns the head reshape)."""
    assert (wq_scale is None) == (wk_scale is None) == (wv_scale is None), \
        "qkv weights quantize together"
    m, d = x.shape
    nq, nk = wq.shape[1], wk.shape[1]
    assert nq == num_heads * head_dim and nk == num_kv_heads * head_dim
    dtype_bytes = jnp.dtype(x.dtype).itemsize

    m_pad = round_up(max(m, 1), 8)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
        positions = jnp.pad(positions, (0, m_pad - m))
    pos = positions.astype(jnp.float32)[:, None]     # (m_pad, 1)

    # absent biases ride as zeros: x + 0 is exact in f32, and one kernel
    # signature serves both bias conventions
    bq = jnp.zeros((nq,), x.dtype) if bq is None else bq
    bk_bias = jnp.zeros((nk,), x.dtype) if bk_bias is None else bk_bias
    bv = jnp.zeros((nk,), x.dtype) if bv is None else bv

    nqp, nkp = round_up(nq, 128), round_up(nk, 128)
    if nqp != nq:
        wq = jnp.pad(wq, ((0, 0), (0, nqp - nq)))
        bq = jnp.pad(bq, (0, nqp - nq))
    if nkp != nk:
        wk = jnp.pad(wk, ((0, 0), (0, nkp - nk)))
        wv = jnp.pad(wv, ((0, 0), (0, nkp - nk)))
        bk_bias = jnp.pad(bk_bias, (0, nkp - nk))
        bv = jnp.pad(bv, (0, nkp - nk))

    bk = block_k or pick_bk(m_pad, nqp + 2 * nkp, d,
                            dtype_bytes=dtype_bytes)
    # the working set holds three double-buffered weight streams, the
    # resident x + normed-x scratch, and three f32 accumulators — halve
    # B_K until it fits the same budget the single-GEMM picker assumed
    from repro import hardware
    budget = hardware.DEFAULT.vmem_bytes // 4
    kp = round_up(d, bk)
    while bk > 128 and (
            2 * bk * (nqp + 2 * nkp) * dtype_bytes
            + 2 * m_pad * kp * dtype_bytes
            + m_pad * (nqp + 2 * nkp) * 4) > budget:
        bk //= 2
        kp = round_up(d, bk)
    if kp != d:
        x = jnp.pad(x, ((0, 0), (0, kp - d)))
        norm_scale = jnp.pad(norm_scale, (0, kp - d))
        wq = jnp.pad(wq, ((0, kp - d), (0, 0)))
        wk = jnp.pad(wk, ((0, kp - d), (0, 0)))
        wv = jnp.pad(wv, ((0, kp - d), (0, 0)))

    quantized = wq_scale is not None
    operands = [x, norm_scale[None, :], wq, wk, wv,
                bq[None, :], bk_bias[None, :], bv[None, :], pos]
    in_specs = [
        pl.BlockSpec((m_pad, kp), lambda k_: (0, 0)),
        pl.BlockSpec((1, kp), lambda k_: (0, 0)),
        pl.BlockSpec((bk, nqp), lambda k_: (k_, 0)),
        pl.BlockSpec((bk, nkp), lambda k_: (k_, 0)),
        pl.BlockSpec((bk, nkp), lambda k_: (k_, 0)),
        pl.BlockSpec((1, nqp), lambda k_: (0, 0)),
        pl.BlockSpec((1, nkp), lambda k_: (0, 0)),
        pl.BlockSpec((1, nkp), lambda k_: (0, 0)),
        pl.BlockSpec((m_pad, 1), lambda k_: (0, 0)),
    ]
    if quantized:
        for s, width in ((wq_scale, nqp), (wk_scale, nkp), (wv_scale, nkp)):
            s = s.astype(jnp.float32).reshape(1, -1)
            if s.shape[1] != width:
                s = jnp.pad(s, ((0, 0), (0, width - s.shape[1])))
            operands.append(s)
            in_specs.append(pl.BlockSpec((1, width), lambda k_: (0, 0)))

    outq, outk, outv = pl.pallas_call(
        functools.partial(
            _ingest_kernel, d_real=d, bk=bk, num_heads=num_heads,
            num_kv_heads=num_kv_heads, head_dim=head_dim,
            theta=rope_theta, eps=eps, use_rope=use_rope,
            quantized=quantized),
        grid=(kp // bk,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((m_pad, nqp), lambda k_: (0, 0)),
            pl.BlockSpec((m_pad, nkp), lambda k_: (0, 0)),
            pl.BlockSpec((m_pad, nkp), lambda k_: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, nqp), x.dtype),
            jax.ShapeDtypeStruct((m_pad, nkp), x.dtype),
            jax.ShapeDtypeStruct((m_pad, nkp), x.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((m_pad, kp), x.dtype),
            pltpu.VMEM((m_pad, nqp), jnp.float32),
            pltpu.VMEM((m_pad, nkp), jnp.float32),
            pltpu.VMEM((m_pad, nkp), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(*operands)
    return outq[:m, :nq], outk[:m, :nk], outv[:m, :nk]


def _oproj_kernel(o_ref, wo_ref, resid_ref, *refs,
                  quantized: bool = False):
    # quantized appends one (1, B_N) f32 step operand after ``resid``;
    # trace-time branch, bf16 jaxpr unchanged
    if quantized:
        scale_ref, out_ref, acc_ref = refs
    else:
        out_ref, acc_ref = refs
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wo_t = wo_ref[...].astype(o_ref.dtype) if quantized else wo_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        o_ref[...], wo_t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _fin():
        # cast before the add, mirroring the split chain's
        # `x + matmul(o, wo)` operand dtypes; the weight step dequantizes
        # on the f32 accumulator first
        acc = acc_ref[...]
        if quantized:
            acc = acc * scale_ref[...]
        out_ref[...] = resid_ref[...] + acc.astype(out_ref.dtype)


def oproj_residual_fused(
    o: jax.Array,       # (M, Q) attention outputs
    wo: jax.Array,      # (Q, D)
    resid: jax.Array,   # (M, D) residual stream
    *,
    w_scale: jax.Array | None = None,   # (D,) f32 -> wo is quantized codes
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """resid + o @ wo with the residual add fused into the GEMM epilogue."""
    m, k = o.shape
    k2, n = wo.shape
    assert k2 == k and resid.shape == (m, n), (o.shape, wo.shape,
                                               resid.shape)
    dtype_bytes = jnp.dtype(o.dtype).itemsize

    m_pad = round_up(max(m, 1), 8)
    if m_pad != m:
        o = jnp.pad(o, ((0, m_pad - m), (0, 0)))
        resid = jnp.pad(resid, ((0, m_pad - m), (0, 0)))

    bn = block_n or pick_bn(m_pad, n, k, dtype_bytes=dtype_bytes)
    bk = block_k or pick_bk(m_pad, bn, k, dtype_bytes=dtype_bytes)
    if n % bn:
        pad_n = bn - n % bn
        wo = jnp.pad(wo, ((0, 0), (0, pad_n)))
        resid = jnp.pad(resid, ((0, 0), (0, pad_n)))
    if k % bk:
        pad_k = bk - k % bk
        o = jnp.pad(o, ((0, 0), (0, pad_k)))
        wo = jnp.pad(wo, ((0, pad_k), (0, 0)))
    kp, np_ = o.shape[1], wo.shape[1]

    quantized = w_scale is not None
    operands = [o, wo, resid]
    in_specs = [
        pl.BlockSpec((m_pad, bk), lambda n_, k_: (0, k_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
        pl.BlockSpec((m_pad, bn), lambda n_, k_: (0, n_)),
    ]
    if quantized:
        scale = w_scale.astype(jnp.float32).reshape(1, -1)
        if np_ != n:
            scale = jnp.pad(scale, ((0, 0), (0, np_ - n)))
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)))

    out = pl.pallas_call(
        functools.partial(_oproj_kernel, quantized=quantized),
        grid=(np_ // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m_pad, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((m_pad, np_), resid.dtype),
        scratch_shapes=[pltpu.VMEM((m_pad, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


def _ffn_norm_kernel(x_ref, scale_ref, wg_ref, wu_ref, *refs,
                     d_real: int, bk: int, activation: str, eps: float,
                     quantized: bool = False):
    # quantized appends two (1, B_N) f32 step operands after ``w_up``;
    # trace-time branch, bf16 jaxpr unchanged
    if quantized:
        sg_ref, su_ref, out_ref, xn_ref, accg_ref, accu_ref = refs
    else:
        out_ref, xn_ref, accg_ref, accu_ref = refs
    ni = pl.program_id(0)
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when((ni == 0) & (ki == 0))
    def _norm():
        # rmsnorm once into the resident normed-x scratch; it persists
        # across the whole (N, K) grid (both dims "arbitrary" = sequential)
        xf = x_ref[...].astype(jnp.float32)
        var = jnp.sum(xf * xf, axis=-1, keepdims=True) / d_real
        xn = xf * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(
            jnp.float32)
        xn_ref[...] = xn.astype(xn_ref.dtype)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    xt = xn_ref[:, pl.ds(ki * bk, bk)]
    dims = (((1,), (0,)), ((), ()))
    wg_t = wg_ref[...].astype(xt.dtype) if quantized else wg_ref[...]
    wu_t = wu_ref[...].astype(xt.dtype) if quantized else wu_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        xt, wg_t, dims, preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        xt, wu_t, dims, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _fin():
        # activation on the unrounded f32 accumulators, like the fused-FFN
        # kernel's epilogue (and fused_ffn_up_ref); weight steps
        # dequantize on the accumulators before the nonlinearity
        g, u = accg_ref[...], accu_ref[...]
        if quantized:
            g = g * sg_ref[...]
            u = u * su_ref[...]
        act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
        out_ref[...] = (act * u).astype(out_ref.dtype)


def ffn_norm_fused(
    x: jax.Array,             # (M, D) residual-stream rows (un-normed)
    norm_scale: jax.Array,    # (D,)
    w_gate: jax.Array,        # (D, F)
    w_up: jax.Array,          # (D, F)
    *,
    activation: str = "swiglu",
    eps: float = 1e-6,
    wg_scale: jax.Array | None = None,  # (F,) f32 -> w_gate is codes
    wu_scale: jax.Array | None = None,  # (F,) f32 -> w_up is codes
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Fused rmsnorm → gate/up GEMMs → act(g)*u. Returns (M, F) in
    x.dtype — feed it to :func:`oproj_residual_fused` with ``w_down``
    for the full mlp seam."""
    assert (wg_scale is None) == (wu_scale is None), \
        "gate/up weights quantize together"
    m, d = x.shape
    d2, f = w_gate.shape
    assert d2 == d and w_up.shape == (d, f), (x.shape, w_gate.shape,
                                              w_up.shape)
    dtype_bytes = jnp.dtype(x.dtype).itemsize

    m_pad = round_up(max(m, 1), 8)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    bn = block_n or pick_bn(m_pad, f, d, dtype_bytes=dtype_bytes)
    bk = block_k or pick_bk(m_pad, bn, d, dtype_bytes=dtype_bytes)
    # two double-buffered weight streams + resident x and normed-x +
    # two f32 accumulators — shrink blocks until the set fits
    from repro import hardware
    budget = hardware.DEFAULT.vmem_bytes // 4
    kp = round_up(d, bk)

    def _working_set(bn_, bk_, kp_):
        return (2 * 2 * bk_ * bn_ * dtype_bytes
                + 2 * m_pad * kp_ * dtype_bytes
                + 2 * m_pad * bn_ * 4)

    while bn > 128 and _working_set(bn, bk, kp) > budget:
        bn //= 2
    while bk > 128 and _working_set(bn, bk, kp) > budget:
        bk //= 2
        kp = round_up(d, bk)

    fp = round_up(f, bn)
    if fp != f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, fp - f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, fp - f)))
    if kp != d:
        x = jnp.pad(x, ((0, 0), (0, kp - d)))
        norm_scale = jnp.pad(norm_scale, (0, kp - d))
        w_gate = jnp.pad(w_gate, ((0, kp - d), (0, 0)))
        w_up = jnp.pad(w_up, ((0, kp - d), (0, 0)))

    quantized = wg_scale is not None
    operands = [x, norm_scale[None, :], w_gate, w_up]
    in_specs = [
        pl.BlockSpec((m_pad, kp), lambda n_, k_: (0, 0)),
        pl.BlockSpec((1, kp), lambda n_, k_: (0, 0)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
    ]
    if quantized:
        for s in (wg_scale, wu_scale):
            s = s.astype(jnp.float32).reshape(1, -1)
            if fp != f:
                s = jnp.pad(s, ((0, 0), (0, fp - f)))
            operands.append(s)
            in_specs.append(pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)))

    out = pl.pallas_call(
        functools.partial(_ffn_norm_kernel, d_real=d, bk=bk,
                          activation=activation, eps=eps,
                          quantized=quantized),
        grid=(fp // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m_pad, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((m_pad, fp), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((m_pad, kp), x.dtype),
            pltpu.VMEM((m_pad, bn), jnp.float32),
            pltpu.VMEM((m_pad, bn), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            # the N dim must run sequentially too: every N block reads
            # the normed-x scratch written at grid step (0, 0)
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :f]
