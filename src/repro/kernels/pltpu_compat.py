"""Version compatibility for the Pallas TPU API surface.

Importing this module makes ``pltpu.CompilerParams`` available on jax
versions where the class is still named ``TPUCompilerParams`` (renamed
upstream around jax 0.5). Every kernel module in this package imports it
for the side effect, so all kernels keep a single call-site idiom
(``pltpu.CompilerParams(dimension_semantics=...)``) across jax versions.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version dep
    pltpu.CompilerParams = pltpu.TPUCompilerParams
