"""The softmax-merge algebra — one implementation for every partial merge.

Every attention kernel in this repo splits the KV sequence into pieces
(grid steps over cache chunks, pool pages, or a shared-prefix/private-tail
pair) and combines per-piece partials. Two schemes exist:

  * **unified-max** (the paper's §3 asynchronized softmax): a partial is
    ``(num, den, msc)`` with ``num = Σ exp(s − φ)·v``, ``den = Σ exp(s − φ)``
    and ``msc = max(s − φ)`` over valid positions. φ is a *static* constant,
    so merging partials is pure addition (plus a max for the overflow stat)
    — commutative and associative, no rescale between pieces.
  * **online-max / LSE** (FlashAttention-style, the recompute fallback): a
    partial is ``(acc, den, m)`` stabilized by its own running max; merging
    rescales by ``exp(m − m_new)``.

The in-kernel accumulate steps (:func:`unified_accumulate`,
:func:`sync_accumulate`) are bitwise-identical to the bodies they were
extracted from — the Pallas kernels in ``decode_attention`` /
``chunk_attention`` / ``group_attention`` all call them, so the property
suite in ``tests/test_merge_properties.py`` exercises the exact fp op
sequence every kernel runs. The symmetric two-partial merges
(:func:`merge_unified`, :func:`merge_lse`) are the algebra those tests
check for split-point equivalence and order invariance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _weighted_sum(e: jax.Array, v: jax.Array) -> jax.Array:
    """(R, K) exp-weights x (K, D) values -> (R, D), f32 on the MXU."""
    return jax.lax.dot_general(
        e, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Unified-max (asynchronized) scheme
# ---------------------------------------------------------------------------


def unified_accumulate(acc, den, msc, centered, v, valid):
    """Fold one KV piece into a unified-max partial.

    acc: (R, D) f32 running numerator; den: (R, *) f32 running denominator
    (lane-broadcast); msc: scalar f32 running max centered score;
    centered: (R, K) f32 logits already shifted by φ; v: (K, D);
    valid: (R, K) bool. Returns the updated ``(acc, den, msc)``.
    """
    msc = jnp.maximum(msc, jnp.max(jnp.where(valid, centered, -jnp.inf)))
    e = jnp.where(valid, jnp.exp(centered), 0.0)
    acc = acc + _weighted_sum(e, v)
    den = den + jnp.broadcast_to(
        jnp.sum(e, axis=1, keepdims=True), den.shape
    )
    return acc, den, msc


def merge_unified(p1, p2):
    """Symmetric merge of two unified-max partials ``(num, den, msc)``."""
    n1, d1, m1 = p1
    n2, d2, m2 = p2
    return n1 + n2, d1 + d2, jnp.maximum(m1, m2)


# ---------------------------------------------------------------------------
# Online-max (synchronized / LSE) scheme
# ---------------------------------------------------------------------------


def sync_accumulate(acc, den, m_prev, s, v, *, valid=None):
    """Fold one KV piece into an online-max partial.

    acc: (R, D) f32; den: (R, *) f32; m_prev: (R, 1) f32 running max;
    s: (R, K) f32 logits with invalid positions already at ``-inf``;
    ``valid`` is passed by kernels that additionally zero the exp weights
    (the chunk kernels) and omitted by those that rely on the ``-inf``
    masking alone (the decode kernels) — the two differ bitwise only on
    fully-masked rows. Returns ``(acc, den, m_new)`` with m_new (R, 1).
    """
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    rescale = jnp.exp(m_prev - m_new)
    if valid is None:
        e = jnp.exp(s - m_new)
    else:
        e = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    acc = acc * rescale + _weighted_sum(e, v)
    den = den * jnp.broadcast_to(rescale, den.shape) + jnp.broadcast_to(
        jnp.sum(e, axis=1, keepdims=True), den.shape
    )
    return acc, den, m_new


def merge_lse(p1, p2):
    """Symmetric merge of two max-stabilized partials ``(acc, den, m)``."""
    a1, d1, m1 = p1
    a2, d2, m2 = p2
    m = jnp.maximum(m1, m2)
    r1 = jnp.exp(m1 - m)
    r2 = jnp.exp(m2 - m)
    return a1 * r1 + a2 * r2, d1 * r1 + d2 * r2, m


# ---------------------------------------------------------------------------
# Finalize
# ---------------------------------------------------------------------------


def finalize(acc, den, *, guard_zero: bool = False):
    """num/den -> output rows. ``guard_zero`` substitutes 1 for an all-
    masked row's zero denominator (chunk/group kernels, whose callers drop
    those garbage rows); the plain decode kernels divide unguarded."""
    d = den[:, :1]
    if guard_zero:
        d = jnp.where(d == 0.0, 1.0, d)
    return acc / d
