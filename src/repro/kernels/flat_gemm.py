"""T2 — Flat GEMM with minimal M-padding and pipelined double buffering.

Paper §4 adapted to TPU:

  * "pad to 8, not 64": the M (token) dimension of decode-phase GEMMs is
    padded only to the sublane atom (8 for f32, here ``round_up(M, 8)``),
    never to a 64/128 tile. The kernel claims exactly an
    ``(M_pad, B_K) × (B_K, B_N)`` working set in VMEM.
  * double buffering: grid = (N/B_N, K/B_K) with
    ``dimension_semantics = ("parallel", "arbitrary")``. Mosaic's pipeline
    emitter double-buffers the input DMAs across the sequential K dimension —
    the (K+1)-th A/B tiles stream into VMEM while the MXU consumes the K-th.
    This is the TPU-native realization of the paper's shared-memory double
    buffering (Fig. 8): we control it structurally via BlockSpec shape
    choice rather than hand-written cp.async.
  * the Eq.-5 parallelism-vs-reuse trade-off is resolved by
    :func:`pick_bn` — the same napkin math with HBM→VMEM traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

from repro import hardware


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_bn(m: int, n: int, k: int, *, dtype_bytes: int = 2,
            spec: hardware.HardwareSpec = hardware.DEFAULT) -> int:
    """Eq. 5 on TPU: choose B_N balancing grid parallelism vs reuse.

    compute/memory ratio of a tile pass ≈ 2·M·K / (K + M·K/B_N + M); larger
    B_N amortizes the A-tile reload, smaller B_N gives more parallel grid
    steps to pipeline. We want at least ``min_grid`` parallel N-steps to keep
    the pipeline busy, subject to the VMEM budget (double-buffered).
    """
    min_grid = 8     # pipeline depth worth of independent N tiles
    budget = spec.vmem_bytes // 4  # leave room for out tile + other buffers
    best = 128
    for bn in (128, 256, 512, 1024, 2048):
        if n % bn:
            continue
        bk = pick_bk(m, bn, k, dtype_bytes=dtype_bytes, spec=spec)
        # double-buffered A and B tiles must fit
        vmem = 2 * (m * bk + bk * bn) * dtype_bytes + m * bn * 4
        if vmem > budget:
            break
        if n // bn >= min_grid or bn == 128:
            best = bn
    return min(best, n)


def pick_bk(m: int, bn: int, k: int, *, dtype_bytes: int = 2,
            spec: hardware.HardwareSpec = hardware.DEFAULT) -> int:
    """Largest K tile whose double-buffered tiles fit the VMEM budget."""
    budget = spec.vmem_bytes // 4
    best = 128
    for bk in (128, 256, 512, 1024, 2048, 4096):
        if k % bk:
            continue
        vmem = 2 * (m * bk + bk * bn) * dtype_bytes + m * bn * 4
        if vmem <= budget:
            best = bk
    return min(best, k)


def _flat_gemm_kernel(x_ref, w_ref, out_ref, acc_ref):
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _fin():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _flat_gemm_quant_kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref):
    """Quantized-weight variant: ``w_ref`` holds int8/fp8 codes streamed
    at stored width; the per-output-channel step (``scale_ref``, (1, B_N)
    f32) multiplies the f32 accumulator once in the epilogue — ``codes *
    step`` factored out of the K sum. The codes cast to the activation
    dtype for the MXU pass (int8 ±127 / fp8 e4m3 are exact in bf16)."""
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...].astype(x_ref.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _fin():
        out_ref[...] = (acc_ref[...] * scale_ref[...]).astype(out_ref.dtype)


def flat_gemm(
    x: jax.Array,   # (M, K)
    w: jax.Array,   # (K, N)
    *,
    w_scale: jax.Array | None = None,   # (N,) f32 -> w is quantized codes
    block_n: int = 0,
    block_k: int = 0,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Minimal-pad flat GEMM. M is padded to the sublane atom (8), only."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or x.dtype
    dtype_bytes = jnp.dtype(x.dtype).itemsize

    m_pad = round_up(max(m, 1), 8)           # <- "pad to 8 not 64"
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    bn = block_n or pick_bn(m_pad, n, k, dtype_bytes=dtype_bytes)
    bk = block_k or pick_bk(m_pad, bn, k, dtype_bytes=dtype_bytes)
    # pad N/K up to tile multiples if the caller passed odd sizes
    if n % bn:
        w = jnp.pad(w, ((0, 0), (0, bn - n % bn)))
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, bk - k % bk)))
        w = jnp.pad(w, ((0, bk - k % bk), (0, 0)))
    kp, np_ = x.shape[1], w.shape[1]

    kernel = _flat_gemm_kernel
    operands = [x, w]
    in_specs = [
        pl.BlockSpec((m_pad, bk), lambda n_, k_: (0, k_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
    ]
    if w_scale is not None:
        scale = w_scale.astype(jnp.float32).reshape(1, -1)
        if np_ != n:
            scale = jnp.pad(scale, ((0, 0), (0, np_ - n)))
        kernel = _flat_gemm_quant_kernel
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)))

    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m_pad, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((m_pad, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((m_pad, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
