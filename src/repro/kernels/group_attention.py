"""Grouped prefix-shared decode attention (Pallas TPU).

PR 4 deduplicated shared-prefix *storage* (refcounted COW pages); this
module deduplicates the decode-step *compute* over those pages. Requests
whose block tables begin with the same run of refcount>1 pages form a
group; the shared run is read once per ``(group, kv_head)`` instead of
once per request:

  * **Stage 1** (:func:`_group_prefix_kernel`): grid ``(NG, HK, LP)`` over
    the *group* block table (scalar-prefetched). Every member's grouped
    query heads ride in one ``(M·G, D)`` tile, so one pass over the prefix
    pages produces every member's partial — emitted raw as unified-max
    ``(num, den, stat)``, not normalized.
  * **Stage 2** (:func:`_tail_merge_kernel`): per-request grid over the
    full block table, skipping pages wholly inside the shared prefix. The
    scratch accumulators are *initialized from the stage-1 partials*, so
    the merge is the unified-max add itself — the paper's §3 asynchronized
    softmax with static φ makes the combine a plain ``(num, den)`` sum
    with no rescale (see :mod:`repro.kernels.merge`), which is exactly why
    two independently-produced partials can meet here without a
    synchronization pass.

Both stages report ``max(s − φ)`` so the wrapper keeps the overflow-
recompute fallback contract of the ungrouped kernels.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import merge
from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)


class DecodeGroups(NamedTuple):
    """Device operands of one tick's shared-prefix group plan.

    NG/LP/M are pow2-padded (group count / max prefix pages / max members)
    so tick-to-tick shape churn doesn't retrace; B is the slot count.
    Padding groups have ``n_pages == num_members == g_prefix_len == 0``;
    padded table entries and member rows hold out-of-bounds sentinels
    (consumers clamp). Solo rows have ``gid == NG`` and ``prefix_len == 0``.
    """

    tables: jax.Array        # (NG, LP) int32 physical pages of shared runs
    n_pages: jax.Array       # (NG,) int32 live pages per group
    g_prefix_len: jax.Array  # (NG,) int32 shared tokens per group
    num_members: jax.Array   # (NG,) int32
    member_rows: jax.Array   # (NG, M) int32 batch row of each member
    gid: jax.Array           # (B,) int32 group of each row (NG = solo)
    member: jax.Array        # (B,) int32 rank of the row within its group
    prefix_len: jax.Array    # (B,) int32 shared tokens of each row (0 = solo)


def _group_prefix_kernel(
    gt_ref,       # (NG, LP) int32 scalar-prefetch (consumed by index maps)
    plen_ref,     # (NG,) int32 scalar-prefetch — shared tokens per group
    nm_ref,       # (NG,) int32 scalar-prefetch — live members per group
    q_ref,        # (1, 1, M*G, D) — all members' grouped query heads
    k_ref,        # (1, PS, 1, D) — physical page gt[g, i]
    v_ref,        # (1, PS, 1, D)
    *rest,        # [ks_ref, vs_ref,] num, den, stat, acc, dacc, msc
    phi: float,
    scale: float,
    page_size: int,
    heads_per_kv: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]   # (1, 1) f32 step of page gt[g,i]
        rest = rest[2:]
    num_ref, den_ref, stat_ref, acc_ref, dacc_ref, msc_ref = rest

    g_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dacc_ref[...] = jnp.zeros_like(dacc_ref)
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    plen = plen_ref[g_idx]
    nm = nm_ref[g_idx]

    # pages past the shared run (incl. every page of padding groups): skip
    @pl.when(i_idx * page_size < plen)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (MG, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (MG, PS)
        offs = i_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        # padding member slots ride along with clamped (garbage) q rows —
        # keep them out of the group's shared stat so they can never flip
        # the overflow fallback
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // heads_per_kv
        valid = jnp.logical_and(offs < plen,    # partial last prefix page
                                row < nm)

        acc, den, msc = merge.unified_accumulate(
            acc_ref[...], dacc_ref[...], msc_ref[0, 0], s - phi, v, valid
        )
        acc_ref[...] = acc
        dacc_ref[...] = den
        msc_ref[0, 0] = msc

    @pl.when(i_idx == n_i - 1)
    def _fin():
        num_ref[0, 0] = acc_ref[...]
        den_ref[0, 0] = dacc_ref[...]
        stat_ref[0, 0] = msc_ref[0, 0]


def _tail_merge_kernel(
    bt_ref,       # (B, NB) int32 scalar-prefetch (consumed by index maps)
    len_ref,      # (B,) int32 scalar-prefetch
    plen_ref,     # (B,) int32 scalar-prefetch — per-row shared tokens
    q_ref,        # (1, 1, G, D)
    num_in_ref,   # (1, 1, G, D) f32 — stage-1 partial (zeros for solo rows)
    den_in_ref,   # (1, 1, G, 128) f32
    k_ref,        # (1, PS, 1, D)
    v_ref,        # (1, PS, 1, D)
    *rest,        # [ks_ref, vs_ref,] out, stat, acc, den, msc
    phi: float,
    scale: float,
    page_size: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    out_ref, stat_ref, acc_ref, den_ref, msc_ref = rest

    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    # the merge: seed the accumulators with the prefix partial — the
    # unified-max scheme needs no rescale to continue accumulating
    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = num_in_ref[0, 0]
        den_ref[...] = den_in_ref[0, 0]
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    length = len_ref[b_idx]
    plen = plen_ref[b_idx]

    # pages wholly inside the shared prefix (stage 1 covered them) or
    # wholly past the sequence carry no tail key
    @pl.when(jnp.logical_and((i_idx + 1) * page_size > plen,
                             i_idx * page_size < length))
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (G, PS)
        offs = i_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = jnp.logical_and(offs >= plen, offs < length)

        acc, den, msc = merge.unified_accumulate(
            acc_ref[...], den_ref[...], msc_ref[0, 0], s - phi, v, valid
        )
        acc_ref[...] = acc
        den_ref[...] = den
        msc_ref[0, 0] = msc

    @pl.when(i_idx == n_i - 1)
    def _fin():
        # guard_zero: empty batch slots (length 0, no carry) -> 0 rows
        out = merge.finalize(acc_ref[...], den_ref[...], guard_zero=True)
        out_ref[0, 0] = out.astype(out_ref.dtype)
        stat_ref[0, 0] = msc_ref[0, 0]


def grouped_paged_decode_attention_unified_max(
    q: jax.Array,             # (B, HQ, D)
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32 — full per-request tables
    lengths: jax.Array,       # (B,) int32
    groups: DecodeGroups,
    *,
    phi: float = 0.0,
    scale: float | None = None,
    k_scale: jax.Array | None = None,   # (NP, HK) f32 — quantized pools
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Two-stage grouped decode attention over a block-paged KV pool.

    Returns ``(out, stat)`` exactly like
    :func:`~repro.kernels.decode_attention.paged_decode_attention_unified_max`
    — ``stat`` is the max over prefix *and* tail contributions, so the
    wrapper-level overflow fallback fires on the same condition as the
    ungrouped kernel. With ``k_scale``/``v_scale`` both stages dequantize
    each page in VMEM right after its DMA.
    """
    b, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = hq // hk
    ng, lp = groups.tables.shape
    m = groups.member_rows.shape[1]
    mg = m * g
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scale is not None
    if quantized:
        k_scale = k_scale.astype(jnp.float32)
        v_scale = v_scale.astype(jnp.float32)

    qg = q.reshape(b, hk, g, d)

    # ---- stage 1: shared-prefix partials, one pass per (group, kv_head)
    gtables = jnp.minimum(groups.tables, num_pages - 1)
    rows = jnp.clip(groups.member_rows, 0, b - 1).reshape(-1)
    qs = (jnp.take(qg, rows, axis=0)
             .reshape(ng, m, hk, g, d)
             .transpose(0, 2, 1, 3, 4)
             .reshape(ng, hk, mg, d))

    s1_page = pl.BlockSpec(
        (1, ps, 1, d), lambda g_, h_, i_, gt, pn, nm: (gt[g_, i_], 0, h_, 0))
    s1_in = [
        pl.BlockSpec((1, 1, mg, d),
                     lambda g_, h_, i_, gt, pn, nm: (g_, h_, 0, 0)),
        s1_page,
        s1_page,
    ]
    s1_operands = [qs, k_pool, v_pool]
    if quantized:
        s1_step = pl.BlockSpec(
            (1, 1), lambda g_, h_, i_, gt, pn, nm: (gt[g_, i_], h_))
        s1_in += [s1_step, s1_step]
        s1_operands += [k_scale, v_scale]

    s1_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(ng, hk, lp),
        in_specs=s1_in,
        out_specs=[
            pl.BlockSpec((1, 1, mg, d),
                         lambda g_, h_, i_, gt, pn, nm: (g_, h_, 0, 0)),
            pl.BlockSpec((1, 1, mg, 128),
                         lambda g_, h_, i_, gt, pn, nm: (g_, h_, 0, 0)),
            pl.BlockSpec((1, 1), lambda g_, h_, i_, gt, pn, nm: (g_, h_)),
        ],
        scratch_shapes=[
            pltpu.VMEM((mg, d), jnp.float32),
            pltpu.VMEM((mg, 128), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    s1_kernel = functools.partial(
        _group_prefix_kernel, phi=phi, scale=scale, page_size=ps,
        heads_per_kv=g, quantized=quantized)
    num, den, stat1 = pl.pallas_call(
        s1_kernel,
        grid_spec=s1_spec,
        out_shape=[
            jax.ShapeDtypeStruct((ng, hk, mg, d), jnp.float32),
            jax.ShapeDtypeStruct((ng, hk, mg, 128), jnp.float32),
            jax.ShapeDtypeStruct((ng, hk), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(gtables.astype(jnp.int32), groups.g_prefix_len.astype(jnp.int32),
      groups.num_members.astype(jnp.int32), *s1_operands)

    # un-scatter each row's own partial; solo rows carry zeros (= empty)
    gid_c = jnp.clip(groups.gid, 0, ng - 1)
    mem_c = jnp.clip(groups.member, 0, m - 1)
    has_pref = groups.prefix_len > 0
    num_b = num.reshape(ng, hk, m, g, d)[gid_c, :, mem_c]       # (B,HK,G,D)
    den_b = den.reshape(ng, hk, m, g, 128)[gid_c, :, mem_c]     # (B,HK,G,128)
    stat_b = stat1[gid_c]                                       # (B,HK)
    num_b = jnp.where(has_pref[:, None, None, None], num_b, 0.0)
    den_b = jnp.where(has_pref[:, None, None, None], den_b, 0.0)
    stat_b = jnp.where(has_pref[:, None], stat_b, -jnp.inf)

    # ---- stage 2: private tail, accumulating on top of the carry
    block_tables = jnp.minimum(block_tables, num_pages - 1)
    s2_page = pl.BlockSpec(
        (1, ps, 1, d), lambda b_, h_, i_, bt, ln, pn: (bt[b_, i_], 0, h_, 0))
    s2_in = [
        pl.BlockSpec((1, 1, g, d),
                     lambda b_, h_, i_, bt, ln, pn: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, g, d),
                     lambda b_, h_, i_, bt, ln, pn: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, g, 128),
                     lambda b_, h_, i_, bt, ln, pn: (b_, h_, 0, 0)),
        s2_page,
        s2_page,
    ]
    s2_operands = [qg, num_b, den_b, k_pool, v_pool]
    if quantized:
        s2_step = pl.BlockSpec(
            (1, 1), lambda b_, h_, i_, bt, ln, pn: (bt[b_, i_], h_))
        s2_in += [s2_step, s2_step]
        s2_operands += [k_scale, v_scale]
    s2_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hk, nb),
        in_specs=s2_in,
        out_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, i_, bt, ln, pn: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, i_, bt, ln, pn: (b_, h_)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    s2_kernel = functools.partial(
        _tail_merge_kernel, phi=phi, scale=scale, page_size=ps,
        quantized=quantized)
    out, stat2 = pl.pallas_call(
        s2_kernel,
        grid_spec=s2_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
            jax.ShapeDtypeStruct((b, hk), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      groups.prefix_len.astype(jnp.int32), *s2_operands)

    return out.reshape(b, hq, d), jnp.maximum(stat_b, stat2)
