"""Fused causal flash attention for the prefill phase (Pallas TPU).

Two softmax schemes, selected by ``unified_max``:

  * ``unified_max=False`` — FlashAttention-2 style online softmax: carry
    ``(m, l, acc)`` across KV blocks, rescaling the accumulator whenever the
    running max grows (the paper's Fig. 4(b) synchronized scheme).
  * ``unified_max=True``  — the paper's T1: a static scaling constant φ.
    No max carry, no rescale; each KV block contributes an order-independent
    ``(num, den)`` partial. Also reports max(s−φ) for the overflow fallback.

GQA is handled inside the BlockSpec index map (``kv_head = q_head // group``)
so grouped query heads read the shared KV tile straight from HBM without
materializing repeated heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256

_NEG_INF = -1e30


def _mask(block_q, block_k, qi, ki, seq_k_start_delta, causal, window):
    """Boolean (block_q, block_k) validity mask for this tile pair."""
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    ) + seq_k_start_delta
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    m = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        m &= q_pos >= k_pos
    if window:
        m &= (q_pos - k_pos) < window
    return m


def _prefill_kernel_async(
    q_ref, k_ref, v_ref,
    out_ref, stat_ref,
    acc_ref, den_ref, msc_ref,
    *, phi, scale, block_q, block_k, causal, window, delta,
):
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    q = q_ref[0, 0].astype(jnp.float32) * scale           # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)                   # (BK, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                     # (BQ, BK)
    valid = _mask(block_q, block_k, qi, ki, delta, causal, window)
    centered = s - phi
    msc_ref[0, 0] = jnp.maximum(
        msc_ref[0, 0], jnp.max(jnp.where(valid, centered, -jnp.inf))
    )
    e = jnp.where(valid, jnp.exp(centered), 0.0)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        e, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den_ref[...] += jnp.broadcast_to(
        jnp.sum(e, axis=1, keepdims=True), den_ref.shape
    )

    @pl.when(ki == n_k - 1)
    def _fin():
        out_ref[0, 0] = (acc_ref[...] / den_ref[:, :1]).astype(out_ref.dtype)
        stat_ref[0, 0] = msc_ref[0, 0]


def _prefill_kernel_sync(
    q_ref, k_ref, v_ref,
    out_ref,
    acc_ref, den_ref, m_ref,
    *, scale, block_q, block_k, causal, window, delta,
):
    ki = pl.program_id(3)
    n_k = pl.num_programs(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    valid = _mask(block_q, block_k, qi, ki, delta, causal, window)
    s = jnp.where(valid, s, _NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    rescale = jnp.exp(m_prev - m_new)
    e = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * rescale + jax.lax.dot_general(
        e, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den_ref[...] = den_ref[...] * jnp.broadcast_to(rescale, den_ref.shape) + (
        jnp.broadcast_to(jnp.sum(e, axis=1, keepdims=True), den_ref.shape)
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == n_k - 1)
    def _fin():
        den = den_ref[:, :1]
        den = jnp.where(den == 0.0, 1.0, den)   # fully-masked rows -> 0 output
        out_ref[0, 0] = (acc_ref[...] / den).astype(out_ref.dtype)


def flash_prefill(
    q: jax.Array,   # (B, Sq, HQ, D)
    k: jax.Array,   # (B, Sk, HK, D)
    v: jax.Array,   # (B, Sk, HK, D)
    *,
    causal: bool = True,
    unified_max: bool = True,
    phi: float = 0.0,
    scale: float | None = None,
    sliding_window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """Fused prefill attention.

    Returns ``out`` (sync mode) or ``(out, stat)`` (unified-max mode) where
    ``stat: (B, HQ)`` is the max centered logit for the overflow fallback.
    """
    b, sq, hq, d = q.shape
    _, sk, hk, _ = k.shape
    g = hq // hk
    scale = scale if scale is not None else d ** -0.5
    delta = sk - sq  # q positions offset when kv is longer (chunked prefill)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)

    # (B, S, H, D) -> (B, H, S, D) tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, hq, sq // block_q, sk // block_k)
    q_spec = pl.BlockSpec(
        (1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)
    )
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d), lambda b_, h_, q_, k_: (b_, h_ // g, k_, 0)
    )
    out_spec = pl.BlockSpec(
        (1, 1, block_q, d), lambda b_, h_, q_, k_: (b_, h_, q_, 0)
    )
    common = dict(
        scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=sliding_window, delta=delta,
    )
    params = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
    )
    if unified_max:
        kernel = functools.partial(_prefill_kernel_async, phi=phi, **common)
        out, stat = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=[
                out_spec,
                pl.BlockSpec((1, 1), lambda b_, h_, q_, k_: (b_, h_)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
                jax.ShapeDtypeStruct((b, hq), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q, d), jnp.float32),
                pltpu.VMEM((block_q, 128), jnp.float32),
                pltpu.SMEM((1, 1), jnp.float32),
            ],
            compiler_params=params,
            interpret=interpret,
        )(qt, kt, vt)
        return out.transpose(0, 2, 1, 3), stat

    kernel = functools.partial(_prefill_kernel_sync, **common)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=params,
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
