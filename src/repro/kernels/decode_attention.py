"""T1 — Asynchronized-softmax decode attention (Pallas TPU).

The paper's §3 insight adapted to TPU: each KV chunk contributes
``num += exp(q·kᵀ − φ)·v`` and ``den += Σ exp(q·kᵀ − φ)`` with a *static*
scaling constant φ, so grid steps over the KV cache are order-independent —
no running-max carry, no rescale of the accumulator between chunks (the
"synchronized partial softmax update" that FlashAttention/FlashDecoding pay
for on every chunk).

The kernel additionally reports ``max(s − φ)`` per (batch, kv-head) block so
the wrapper can implement the paper's recomputation fallback: if any logit
left the safe band, the whole call is recomputed with the synchronized
(online-max) scheme.

Layout: caches are consumed as (batch, kv_head, seq, head_dim) so a KV chunk
is a contiguous (block_k, head_dim) VMEM tile; the grouped query heads that
share one KV head ride along as a (group, head_dim) tile, turning the GQA
decode attention into two small MXU matmuls per chunk:
(G,D)x(D,BK) and (G,BK)x(BK,D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import merge
from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

DEFAULT_BLOCK_K = 512


def _decode_kernel(
    # inputs
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, 1, BK, D)
    v_ref,        # (1, 1, BK, D)
    len_ref,      # (1, 1) int32 in SMEM
    # outputs
    out_ref,      # (1, 1, G, D)
    stat_ref,     # (1, 1) f32 : max(s - phi) over valid positions
    # scratch
    acc_ref,      # (G, D) f32
    den_ref,      # (G, 128) f32
    msc_ref,      # (1, 1) f32  max centered score
    *,
    phi: float,
    scale: float,
    block_k: int,
    kv_len: int,
):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (BK, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (G, BK)

    length = len_ref[0, 0]
    offs = s_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = offs < length

    acc, den, msc = merge.unified_accumulate(
        acc_ref[...], den_ref[...], msc_ref[0, 0], s - phi, v, valid
    )
    acc_ref[...] = acc
    den_ref[...] = den
    msc_ref[0, 0] = msc

    @pl.when(s_idx == n_s - 1)
    def _fin():
        out = merge.finalize(acc_ref[...], den_ref[...])
        out_ref[0, 0] = out.astype(out_ref.dtype)
        stat_ref[0, 0] = msc_ref[0, 0]


def decode_attention_unified_max(
    q: jax.Array,          # (B, HQ, D)
    k_cache: jax.Array,    # (B, HK, S, D)
    v_cache: jax.Array,    # (B, HK, S, D)
    lengths: jax.Array,    # (B,) int32
    *,
    phi: float = 0.0,
    scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Run the async-softmax decode kernel.

    Returns ``(out, stat)`` with ``out: (B, HQ, D)`` and
    ``stat: (B, HK)`` = max centered logit, for the overflow fallback.
    """
    b, hq, d = q.shape
    _, hk, s_max, _ = k_cache.shape
    g = hq // hk
    scale = scale if scale is not None else d ** -0.5

    block_k = min(block_k, s_max)
    if s_max % block_k:
        pad = block_k - s_max % block_k
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s_max += pad

    qg = q.reshape(b, hk, g, d)
    lens = lengths.reshape(b, 1).astype(jnp.int32)

    grid = (b, hk, s_max // block_k)
    kernel = functools.partial(
        _decode_kernel,
        phi=phi,
        scale=scale,
        block_k=block_k,
        kv_len=s_max,
    )
    out, stat = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec(
                (1, 1), lambda b_, h_, s_: (b_, 0), memory_space=pltpu.SMEM
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, s_: (b_, h_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
            jax.ShapeDtypeStruct((b, hk), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, k_cache, v_cache, lens)
    return out.reshape(b, hq, d), stat


# ---------------------------------------------------------------------------
# Synchronized (online-max) fallback kernel — the paper's recomputation path.
# This is the FlashDecoding-style scheme of Fig. 4(b): every chunk updates the
# running max and rescales the accumulator. Used (a) as the overflow fallback
# and (b) as the "paper baseline" in benchmarks.
# ---------------------------------------------------------------------------


def _decode_kernel_sync(
    q_ref, k_ref, v_ref, len_ref,
    out_ref,
    acc_ref, den_ref, m_ref,
    *,
    scale: float,
    block_k: int,
):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    length = len_ref[0, 0]
    offs = s_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(offs < length, s, -jnp.inf)

    # ---- the synchronized partial-softmax update the paper removes ----
    acc, den, m_new = merge.sync_accumulate(
        acc_ref[...], den_ref[...], m_ref[:, :1], s, v
    )
    acc_ref[...] = acc
    den_ref[...] = den
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(s_idx == n_s - 1)
    def _fin():
        out = merge.finalize(acc_ref[...], den_ref[...])
        out_ref[0, 0] = out.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# Paged (block-table) variants — same math, KV gathered page-by-page.
#
# The KV pool is the storage layout of serving/blockpool.py:
# (num_pages, page_size, kv_heads, head_dim). The per-sequence block table
# rides in as a *scalar-prefetch* operand (PrefetchScalarGridSpec) so the
# BlockSpec index_map can translate logical block i of batch row b into the
# physical page bt[b, i] before the DMA issues. The grid spans the full
# table width (NB = ceil(max_seq/PS)) for every sequence; steps past a
# sequence's length hit clamped/sentinel table entries, their compute is
# skipped via pl.when, and their (repeated) page fetch is wasted DMA — a
# per-sequence grid trim is a ROADMAP follow-on.
# ---------------------------------------------------------------------------


def _paged_decode_kernel(
    bt_ref,       # (B, NB) int32 scalar-prefetch (unused in body; index maps)
    len_ref,      # (B,) int32 scalar-prefetch
    q_ref,        # (1, 1, G, D)
    k_ref,        # (1, PS, 1, D) — physical page bt[b, i]
    v_ref,        # (1, PS, 1, D)
    *rest,        # [ks_ref, vs_ref,] out_ref, stat_ref, acc, den, msc
    phi: float,
    scale: float,
    page_size: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]   # (1, 1) f32 step of page bt[b,i]
        rest = rest[2:]
    out_ref, stat_ref, acc_ref, den_ref, msc_ref = rest

    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    length = len_ref[b_idx]

    @pl.when(i_idx * page_size < length)   # fully-masked pages: skip compute
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # codes -> values in VMEM: one fused multiply per tile; the
            # full-precision page never exists in HBM
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (G, PS)

        offs = i_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        valid = offs < length

        acc, den, msc = merge.unified_accumulate(
            acc_ref[...], den_ref[...], msc_ref[0, 0], s - phi, v, valid
        )
        acc_ref[...] = acc
        den_ref[...] = den
        msc_ref[0, 0] = msc

    @pl.when(i_idx == n_i - 1)
    def _fin():
        out = merge.finalize(acc_ref[...], den_ref[...])
        out_ref[0, 0] = out.astype(out_ref.dtype)
        stat_ref[0, 0] = msc_ref[0, 0]


def paged_decode_attention_unified_max(
    q: jax.Array,             # (B, HQ, D)
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32
    lengths: jax.Array,       # (B,) int32
    *,
    phi: float = 0.0,
    scale: float | None = None,
    k_scale: jax.Array | None = None,   # (NP, HK) f32 — quantized pools
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Async-softmax decode attention over a block-paged KV pool.

    Returns ``(out, stat)`` exactly like :func:`decode_attention_unified_max`;
    the block table is a scalar-prefetch operand so each grid step DMAs one
    physical page. With ``k_scale``/``v_scale`` the pools hold quantized
    codes; each page is dequantized in VMEM right after its DMA.
    """
    b, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = hq // hk
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scale is not None

    # unassigned table entries hold the OOB sentinel num_pages — clamp so
    # the page DMA stays in bounds (contents masked off by `lengths`)
    block_tables = jnp.minimum(block_tables, num_pages - 1)
    qg = q.reshape(b, hk, g, d)
    page_spec = pl.BlockSpec(
        (1, ps, 1, d), lambda b_, h_, i_, bt, ln: (bt[b_, i_], 0, h_, 0))
    step_spec = pl.BlockSpec(
        (1, 1), lambda b_, h_, i_, bt, ln: (bt[b_, i_], h_))
    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [step_spec, step_spec]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, nb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, i_, bt, ln: (b_, h_)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel, phi=phi, scale=scale, page_size=ps,
        quantized=quantized)
    out, stat = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
            jax.ShapeDtypeStruct((b, hk), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)
    return out.reshape(b, hq, d), stat


def _paged_decode_kernel_sync(
    bt_ref, len_ref,
    q_ref, k_ref, v_ref,
    *rest,
    scale: float,
    page_size: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    out_ref, acc_ref, den_ref, m_ref = rest

    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)

    length = len_ref[b_idx]

    @pl.when(i_idx * page_size < length)   # fully-masked pages: skip compute
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        offs = i_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(offs < length, s, -jnp.inf)

        acc, den, m_new = merge.sync_accumulate(
            acc_ref[...], den_ref[...], m_ref[:, :1], s, v
        )
        acc_ref[...] = acc
        den_ref[...] = den
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(i_idx == n_i - 1)
    def _fin():
        out = merge.finalize(acc_ref[...], den_ref[...])
        out_ref[0, 0] = out.astype(out_ref.dtype)


def paged_decode_attention_sync(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Online-max (synchronized) paged decode attention — fallback path."""
    b, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = hq // hk
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scale is not None

    block_tables = jnp.minimum(block_tables, num_pages - 1)
    qg = q.reshape(b, hk, g, d)
    page_spec = pl.BlockSpec(
        (1, ps, 1, d), lambda b_, h_, i_, bt, ln: (bt[b_, i_], 0, h_, 0))
    step_spec = pl.BlockSpec(
        (1, 1), lambda b_, h_, i_, bt, ln: (bt[b_, i_], h_))
    in_specs = [
        pl.BlockSpec((1, 1, g, d),
                     lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        page_spec,
        page_spec,
    ]
    operands = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [step_spec, step_spec]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_decode_kernel_sync, scale=scale, page_size=ps,
        quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)
    return out.reshape(b, hq, d)


def decode_attention_sync(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Online-max (synchronized) decode attention — fallback / baseline."""
    b, hq, d = q.shape
    _, hk, s_max, _ = k_cache.shape
    g = hq // hk
    scale = scale if scale is not None else d ** -0.5

    block_k = min(block_k, s_max)
    if s_max % block_k:
        pad = block_k - s_max % block_k
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s_max += pad

    qg = q.reshape(b, hk, g, d)
    lens = lengths.reshape(b, 1).astype(jnp.int32)
    grid = (b, hk, s_max // block_k)
    kernel = functools.partial(_decode_kernel_sync, scale=scale, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec(
                (1, 1), lambda b_, h_, s_: (b_, 0), memory_space=pltpu.SMEM
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, s_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qg, k_cache, v_cache, lens)
    return out.reshape(b, hq, d)
