"""Per-page symmetric KV quantization primitives.

Pages quantize at write time with one scale ("step") per (page, kv head):
``step = amax / qmax`` over the page's tokens and head dim, codes are the
scaled values rounded into the code dtype, and dequantization is the
elementwise ``codes.astype(f32) * step`` — cheap enough to run inside the
decode / chunk / group kernels so the full-precision slab never exists in
HBM (see :mod:`repro.serving.kvquant` for the write-side scatter algebra).

Three precisions, selected by ``PagedPlan.kv_dtype``:

  * ``bf16`` — passthrough. No codes, no steps; the legacy pools are the
    storage and every kernel path is bit-identical to the unquantized tree.
  * ``int8`` — 8-bit symmetric integers, qmax 127, round-to-nearest-even.
  * ``fp8``  — ``float8_e4m3fn`` (ml_dtypes-backed where this jax exposes
    it), qmax 448 = the format's largest finite; the cast itself rounds.

All step math is f32; steps live in a parallel (num_pages, kv_heads) f32
pool carried as extra cache leaves (``k_scale`` / ``v_scale``). A step of
exactly 0.0 means "page holds no content yet" — codes are zero and decode
to zeros regardless, and the write path resets the step whenever a write
covers the page's position 0 (so reused pages can never inherit a stale
step from a previous tenant).
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp

# kv_dtype knob values (mirrored by plan.KV_DTYPES for knob validation)
KV_DTYPES = ("bf16", "int8", "fp8")


def fp8_supported() -> bool:
    """True when this jax/ml_dtypes stack can store float8_e4m3fn arrays."""
    return _fp8_probe()


@functools.lru_cache(maxsize=1)
def _fp8_probe() -> bool:
    try:
        z = jnp.zeros((2,), jnp.float8_e4m3fn)
        _ = (z.astype(jnp.float32) + 1.0).astype(jnp.float8_e4m3fn)
        return True
    except Exception:
        return False


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of one kv_dtype's code format."""
    name: str
    qmax: float          # largest representable |code|
    is_int: bool         # integer codes (explicit round+clip) vs fp8 cast

    @property
    def code_dtype(self):
        return jnp.int8 if self.is_int else jnp.float8_e4m3fn


INT8 = QuantSpec(name="int8", qmax=127.0, is_int=True)
FP8 = QuantSpec(name="fp8", qmax=448.0, is_int=False)

_BY_NAME = {"int8": INT8, "fp8": FP8}


def spec_for(name: str) -> QuantSpec | None:
    """QuantSpec for a kv_dtype name; None for the bf16 passthrough."""
    if name == "bf16":
        return None
    if name not in _BY_NAME:
        raise ValueError(f"unknown kv_dtype {name!r}; expected {KV_DTYPES}")
    if name == "fp8" and not fp8_supported():
        raise ValueError("kv_dtype 'fp8' needs float8_e4m3fn support in "
                         "this jax/ml_dtypes install")
    return _BY_NAME[name]


def spec_for_dtype(dtype) -> QuantSpec | None:
    """QuantSpec from a stored pool's dtype (None = full-precision pool).

    This lets every consumer below the Engine derive the precision from
    the cache leaves themselves instead of threading a knob.
    """
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.int8):
        return INT8
    if fp8_supported() and d == jnp.dtype(jnp.float8_e4m3fn):
        return FP8
    return None


# ---------------------------------------------------------------------------
# Elementwise primitives. Convention: ``x`` is (..., D) full-precision and
# ``step`` broadcasts against ``x.shape[:-1]`` (one step per head, shared
# across the head dim).
# ---------------------------------------------------------------------------


def compute_step(x: jnp.ndarray, spec: QuantSpec, axes) -> jnp.ndarray:
    """amax/qmax over ``axes`` (f32). Zero input -> step exactly 0.0."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes)
    return amax / spec.qmax


def encode(x: jnp.ndarray, step: jnp.ndarray, spec: QuantSpec):
    """Quantize ``x`` under ``step`` into the code dtype.

    ``step == 0`` rows (empty pages) encode through a divisor of 1.0 —
    the content is all zeros there so the codes come out zero too.
    """
    x = x.astype(jnp.float32)
    safe = jnp.where(step > 0.0, step, 1.0)[..., None]
    y = jnp.clip(x / safe, -spec.qmax, spec.qmax)
    if spec.is_int:
        return jnp.round(y).astype(jnp.int8)
    return y.astype(jnp.float8_e4m3fn)


def decode(codes: jnp.ndarray, step: jnp.ndarray) -> jnp.ndarray:
    """Dequantize codes back to f32: ``codes * step`` elementwise.

    This is *the* dequant expression — the Pallas kernels inline exactly
    this so oracle (gathered dequant) and kernel (in-register dequant)
    paths see bit-identical operands.
    """
    return codes.astype(jnp.float32) * step[..., None].astype(jnp.float32)


def rescale_codes(codes: jnp.ndarray, old_step: jnp.ndarray,
                  new_step: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """Re-express codes quantized under ``old_step`` in ``new_step`` units.

    Used when a later write raises a page's amax: existing codes shrink by
    ``old/new``. ``ratio == 1`` is exact (codes round-trip through f32
    unchanged for both int8 and fp8), so untouched pages are bitwise
    stable. ``old_step == 0`` (fresh or laundered page) forces ratio 0,
    zeroing whatever stale codes a reused page slab may hold.
    """
    ratio = jnp.where(new_step > 0.0,
                      old_step / jnp.where(new_step > 0.0, new_step, 1.0),
                      jnp.where(old_step > 0.0, 1.0, 0.0))
    y = codes.astype(jnp.float32) * ratio[..., None]
    if spec.is_int:
        return jnp.round(jnp.clip(y, -spec.qmax, spec.qmax)).astype(jnp.int8)
    return jnp.clip(y, -spec.qmax, spec.qmax).astype(jnp.float8_e4m3fn)


def logits_guard_tol(spec: QuantSpec) -> float:
    """Relative logit tolerance for the kv_dtype accuracy guard.

    The plan may change KV bytes and kernels, never correctness beyond a
    dtype-derived tolerance: quantization perturbs each stored K/V element
    by at most its code format's half-step relative error (``0.5/qmax``
    for int8 codes, half-ulp ``2^-4`` for fp8 e4m3 normals), and softmax
    attention is 1-Lipschitz in V at fixed weights, so decode logits move
    by a small multiple of that relative error at logit scale. The 64x
    headroom covers the K-side perturbation passing through the softmax.
    Use as ``atol = logits_guard_tol(spec) * max(|logits|_max, 1.0)``.
    """
    rel = 0.5 / spec.qmax if spec.is_int else 2.0 ** -4
    return 64.0 * rel


def roundtrip_bound(x: jnp.ndarray, step: jnp.ndarray,
                    spec: QuantSpec) -> jnp.ndarray:
    """Analytic elementwise bound on ``|decode(encode(x)) - x|``.

    int8: half a quantization step. fp8 e4m3fn: half-ulp relative error
    for normals (2^-4 of the magnitude) with an absolute floor of half
    the subnormal quantum (2^-10) in scaled units.
    """
    step_b = step[..., None].astype(jnp.float32)
    if spec.is_int:
        return 0.5 * step_b * jnp.ones_like(x, jnp.float32)
    return jnp.maximum(jnp.abs(x.astype(jnp.float32)) * 2.0 ** -4,
                       step_b * 2.0 ** -10)
