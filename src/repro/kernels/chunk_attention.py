"""Fused paged chunk-prefill attention (Pallas TPU) — flash-style causal
attention for a ``(B, C)`` query chunk directly over the block pool.

This is the chunked-prefill twin of the paged decode kernels: the KV pool
``(num_pages, page_size, kv_heads, head_dim)`` is read **in place** through
a scalar-prefetched block table — no dense ``(B, NB*PS)`` gather is ever
materialized, which is what makes long-prompt admission bandwidth-bound
instead of gather-bound (the ROADMAP "chunk-attention kernel" item; the
fusion argument of Kernel Looping / Efficient Operation Fusion applied to
the admission path).

Two softmax schemes, mirroring :mod:`repro.kernels.decode_attention`:

  * ``paged_chunk_attention_unified_max`` — the paper's §3 asynchronized
    partial softmax with a static scaling constant φ: every page
    contributes an order-independent ``(num, den)`` partial (no running
    max, no accumulator rescale between pages), and the kernel reports
    ``max(s − φ)`` over valid positions so the wrapper can run the
    overflow-recompute fallback.
  * ``paged_chunk_attention_sync`` — the FlashAttention-style online-max
    scheme (Fig. 4(b)); the recompute target and paper baseline.

Layout: q ``(B, C, HQ, D)`` is regrouped to ``(B, HK, C·G, D)`` so the
grouped query heads of one KV head ride together — each page step is two
MXU matmuls, ``(C·G, D) x (D, PS)`` and ``(C·G, PS) x (PS, D)``. Chunk-
local causality is masked in-kernel: query row ``r`` sits at absolute
position ``lengths[b] + r // G`` and sees keys at positions ``<=`` its
own (the chunk's KV must already be scattered into the pool, exactly the
:func:`repro.kernels.ref.attention_chunk_ref` contract). Pages wholly past
``lengths[b] + C`` are skipped via ``pl.when`` — with a resident-bounded
block table (see ``Engine._prefill_chunked``) the grid itself stays
O(resident pages). Rows past a sequence's ``chunk_lens`` produce garbage
that callers drop, same as the gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import merge
from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)


def _chunk_mask(cg: int, ps: int, groups: int, length, page_idx):
    """(C·G, PS) validity: key position <= query's absolute position."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (cg, ps), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (cg, ps), 1)
    q_pos = length + rows // groups          # lengths[b] + chunk offset
    k_pos = page_idx * ps + cols
    return k_pos <= q_pos


def _paged_chunk_kernel(
    bt_ref,       # (B, NB) int32 scalar-prefetch (consumed by index maps)
    len_ref,      # (B,) int32 scalar-prefetch — lengths *before* the chunk
    q_ref,        # (1, 1, C*G, D)
    k_ref,        # (1, PS, 1, D) — physical page bt[b, i]
    v_ref,        # (1, PS, 1, D)
    *rest,        # [ks_ref, vs_ref,] out_ref, stat_ref, acc, den, msc
    phi: float,
    scale: float,
    page_size: int,
    chunk: int,
    groups: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]   # (1, 1) f32 step of page bt[b,i]
        rest = rest[2:]
    out_ref, stat_ref, acc_ref, den_ref, msc_ref = rest

    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        msc_ref[...] = jnp.full_like(msc_ref, -jnp.inf)

    length = len_ref[b_idx]

    # pages wholly past the chunk's last query position carry no valid key
    @pl.when(i_idx * page_size < length + chunk)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (CG, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (PS, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (CG, PS)
        valid = _chunk_mask(s.shape[0], page_size, groups, length, i_idx)

        acc, den, msc = merge.unified_accumulate(
            acc_ref[...], den_ref[...], msc_ref[0, 0], s - phi, v, valid
        )
        acc_ref[...] = acc
        den_ref[...] = den
        msc_ref[0, 0] = msc

    @pl.when(i_idx == n_i - 1)
    def _fin():
        # guard_zero: fully-masked rows -> 0 (callers drop them)
        out = merge.finalize(acc_ref[...], den_ref[...], guard_zero=True)
        out_ref[0, 0] = out.astype(out_ref.dtype)
        stat_ref[0, 0] = msc_ref[0, 0]


def _paged_chunk_kernel_sync(
    bt_ref, len_ref,
    q_ref, k_ref, v_ref,
    *rest,
    scale: float,
    page_size: int,
    chunk: int,
    groups: int,
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref = rest[0], rest[1]
        rest = rest[2:]
    out_ref, acc_ref, den_ref, m_ref = rest

    b_idx = pl.program_id(0)
    i_idx = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        den_ref[...] = jnp.zeros_like(den_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)

    length = len_ref[b_idx]

    @pl.when(i_idx * page_size < length + chunk)
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, :, 0].astype(jnp.float32)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0]
            v = v * vs_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        valid = _chunk_mask(s.shape[0], page_size, groups, length, i_idx)
        s = jnp.where(valid, s, -jnp.inf)

        # ---- the synchronized partial-softmax update T1 removes ----
        acc, den, m_new = merge.sync_accumulate(
            acc_ref[...], den_ref[...], m_ref[:, :1], s, v, valid=valid
        )
        acc_ref[...] = acc
        den_ref[...] = den
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(i_idx == n_i - 1)
    def _fin():
        # guard_zero: fully-masked rows -> 0 (callers drop them)
        out = merge.finalize(acc_ref[...], den_ref[...], guard_zero=True)
        out_ref[0, 0] = out.astype(out_ref.dtype)


def _regroup_q(q: jax.Array, hk: int):
    """(B, C, HQ, D) -> (B, HK, C*G, D): grouped heads of one KV head ride
    in one tile; row r of the tile is chunk position r // G."""
    b, c, hq, d = q.shape
    g = hq // hk
    return (q.reshape(b, c, hk, g, d)
             .transpose(0, 2, 1, 3, 4)
             .reshape(b, hk, c * g, d)), g


def _ungroup_out(out: jax.Array, c: int, g: int):
    """(B, HK, C*G, D) -> (B, C, HQ, D)."""
    b, hk, cg, d = out.shape
    return (out.reshape(b, hk, c, g, d)
               .transpose(0, 2, 1, 3, 4)
               .reshape(b, c, hk * g, d))


def _chunk_grid_spec(b, hk, nb, cg, d, ps, unified: bool,
                     quantized: bool = False):
    page_spec = pl.BlockSpec(
        (1, ps, 1, d), lambda b_, h_, i_, bt, ln: (bt[b_, i_], 0, h_, 0))
    common_in = [
        pl.BlockSpec((1, 1, cg, d),
                     lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0)),
        page_spec,
        page_spec,
    ]
    if quantized:
        step_spec = pl.BlockSpec(
            (1, 1), lambda b_, h_, i_, bt, ln: (bt[b_, i_], h_))
        common_in += [step_spec, step_spec]
    out_spec = pl.BlockSpec((1, 1, cg, d),
                            lambda b_, h_, i_, bt, ln: (b_, h_, 0, 0))
    if unified:
        out_specs = [
            out_spec,
            pl.BlockSpec((1, 1), lambda b_, h_, i_, bt, ln: (b_, h_)),
        ]
        scratch = [
            pltpu.VMEM((cg, d), jnp.float32),
            pltpu.VMEM((cg, 128), jnp.float32),
            pltpu.SMEM((1, 1), jnp.float32),
        ]
    else:
        out_specs = out_spec
        scratch = [
            pltpu.VMEM((cg, d), jnp.float32),
            pltpu.VMEM((cg, 128), jnp.float32),
            pltpu.VMEM((cg, 128), jnp.float32),
        ]
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, nb),
        in_specs=common_in,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )


def paged_chunk_attention_unified_max(
    q: jax.Array,             # (B, C, HQ, D) — a chunk of new tokens
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32
    lengths: jax.Array,       # (B,) int32 — lengths *before* the chunk
    *,
    phi: float = 0.0,
    scale: float | None = None,
    k_scale: jax.Array | None = None,   # (NP, HK) f32 — quantized pools
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """T1 fused chunk-prefill attention over the block pool.

    Returns ``(out, stat)`` with ``out: (B, C, HQ, D)`` and
    ``stat: (B, HK)`` = max centered logit over valid positions, for the
    overflow-recompute fallback. The chunk's own KV must already be
    scattered into the pool (same contract as
    :func:`repro.kernels.ref.attention_chunk_ref`). With ``k_scale``/
    ``v_scale`` the pools hold quantized codes, dequantized per page in
    VMEM right after the DMA.
    """
    b, c, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scale is not None

    # unassigned table entries hold the OOB sentinel num_pages — clamp so
    # the page DMA stays in bounds (contents masked off causally / dropped
    # as garbage rows by the caller)
    block_tables = jnp.minimum(block_tables, num_pages - 1)
    qg, g = _regroup_q(q, hk)
    grid_spec = _chunk_grid_spec(b, hk, nb, c * g, d, ps, unified=True,
                                 quantized=quantized)
    operands = [qg, k_pool, v_pool]
    if quantized:
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    kernel = functools.partial(
        _paged_chunk_kernel, phi=phi, scale=scale, page_size=ps,
        chunk=c, groups=g, quantized=quantized)
    out, stat = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hk, c * g, d), q.dtype),
            jax.ShapeDtypeStruct((b, hk), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)
    return _ungroup_out(out, c, g), stat


def paged_chunk_attention_sync(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    k_scale: jax.Array | None = None,
    v_scale: jax.Array | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Online-max (synchronized) fused chunk attention — the overflow
    recompute target and paper baseline."""
    b, c, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scale is not None

    block_tables = jnp.minimum(block_tables, num_pages - 1)
    qg, g = _regroup_q(q, hk)
    grid_spec = _chunk_grid_spec(b, hk, nb, c * g, d, ps, unified=False,
                                 quantized=quantized)
    operands = [qg, k_pool, v_pool]
    if quantized:
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    kernel = functools.partial(
        _paged_chunk_kernel_sync, scale=scale, page_size=ps,
        chunk=c, groups=g, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, c * g, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      *operands)
    return _ungroup_out(out, c, g)
