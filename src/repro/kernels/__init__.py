"""Pallas TPU kernels for the FlashDecoding++ hot spots.

Modules:
  * decode_attention — T1 async-softmax split-KV decode kernel (+ sync
                       baseline), plus block-paged variants that gather KV
                       through scalar-prefetched block tables
  * chunk_attention  — fused paged chunk-prefill attention: flash-style
                       causal chunk attention reading K/V pages in place
                       via scalar-prefetched block tables (sync &
                       unified-max)
  * flash_prefill    — fused causal prefill attention (sync & unified-max)
  * flat_gemm        — T2 minimal-pad double-buffered flat GEMM
  * fused_ffn        — T2 extension: fused flat-GEMM SwiGLU FFN-up epilogue
  * gemv             — ImplA VPU GEMV
  * ops              — jit wrappers + T3 dispatch entry points
  * ref              — pure-jnp oracles for all of the above
"""
from repro.kernels import ref  # noqa: F401
from repro.kernels.chunk_attention import (  # noqa: F401
    paged_chunk_attention_sync,
    paged_chunk_attention_unified_max,
)
from repro.kernels.decode_attention import (  # noqa: F401
    decode_attention_sync,
    decode_attention_unified_max,
    paged_decode_attention_sync,
    paged_decode_attention_unified_max,
)
from repro.kernels.flash_prefill import flash_prefill  # noqa: F401
from repro.kernels.flat_gemm import flat_gemm  # noqa: F401
from repro.kernels.fused_ffn import fused_ffn_up  # noqa: F401
from repro.kernels.gemv import gemv  # noqa: F401
