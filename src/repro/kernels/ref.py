"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests (``assert_allclose`` against the
``interpret=True`` kernel execution) and also serve as the XLA execution path
used by the dry-run (Pallas-for-TPU does not lower on the CPU backend).

Shapes follow the kernel conventions:
  * prefill attention:  q,k,v = (batch, seq, heads, head_dim)   (kv heads may differ)
  * decode attention:   q = (batch, q_heads, head_dim),
                        k,v = (batch, kv_len, kv_heads, head_dim)
  * flat gemm / gemv:   x = (M, K), w = (K, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Softmax schemes (paper Fig. 4)
# ---------------------------------------------------------------------------


def softmax_ref(x: jax.Array, axis: int = -1) -> jax.Array:
    """Fig. 4(a): classic max-stabilized softmax."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def softmax_unified_max(x: jax.Array, phi: float, axis: int = -1) -> jax.Array:
    """Fig. 4(c): partial-softmax with a unified scaling constant ``phi``.

    Algebraically identical to :func:`softmax_ref` for any finite ``phi``
    (Eq. 3); numerically safe while ``x - phi`` stays inside the band.
    """
    e = jnp.exp(x - phi)
    return e / jnp.sum(e, axis=axis, keepdims=True)


# ---------------------------------------------------------------------------
# Attention oracles
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, KVH*groups, D) by repeating each kv head."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def attention_prefill_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    sliding_window: int = 0,
) -> jax.Array:
    """Full (quadratic) softmax attention, fp32 internals."""
    b, sq, hq, d = q.shape
    _, sk, hk, _ = k.shape
    groups = hq // hk
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    if causal or sliding_window:
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), dtype=bool)
        if causal:
            mask &= qi >= ki
        if sliding_window:
            mask &= qi - ki < sliding_window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = softmax_ref(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attention_decode_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    scale: float | None = None,
    shard=None,
) -> jax.Array:
    """One-new-token attention against a KV cache. Safe (max-stabilized).

    q: (B, HQ, D); k_cache/v_cache: (B, S, HK, D); lengths: (B,) valid KV len.
    ``shard``: optional role-based constraint fn — keeps the score tensor
    sequence-sharded (split-KV; the *synchronized* combine: the max and the
    (num, den) reductions are separate collectives, paper Eq. 2).
    """
    b, hq, d = q.shape
    _, s_max, hk, _ = k_cache.shape
    groups = hq // hk
    scale = scale if scale is not None else d ** -0.5
    # GQA via grouped einsum — never materializes a repeated (x groups)
    # copy of the KV cache, and reads it in its stored dtype (bf16); the
    # f32 upcast happens per-tile inside the dot (deepseek decode
    # hillclimb: 8x1.6 TB of repeat+convert traffic removed).
    qg = q.reshape(b, hk, groups, d)   # native dtype: no extra rounding
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if shard is not None:
        s = shard(s, "act_scores_decode")
    valid = jnp.arange(s_max)[None, None, None, :] < lengths[:, None, None,
                                                            None]
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)          # cross-shard max
    if shard is not None:
        m = shard(m[..., 0], "act_decode_rep")[..., None]
    e = jnp.exp(s - m)
    den = jnp.sum(e, axis=-1)
    num = jnp.einsum("bhgk,bkhd->bhgd", e.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    if shard is not None:
        num = shard(num, "act_decode_rep")
        den = shard(den, "act_decode_rep")
    o = (num / den[..., None]).reshape(b, hq, d)
    return o.astype(q.dtype)


def attention_decode_unified_max_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    phi: float,
    scale: float | None = None,
    shard=None,
) -> tuple[jax.Array, jax.Array]:
    """T1 oracle: async partial-softmax decode with unified max value.

    Returns ``(out, max_abs_centered)`` where the second value is
    ``max_i |s_i - phi|`` per batch row — the overflow statistic the kernel
    reports so the wrapper can trigger the paper's recomputation fallback.

    With ``shard`` the scores stay sequence-sharded and the only cross-shard
    traffic is the additive (num, den) reduction — the asynchronous combine
    of paper Eq. 4 (contrast the extra max collective in the sync scheme).
    """
    b, hq, d = q.shape
    _, s_max, hk, _ = k_cache.shape
    groups = hq // hk
    scale = scale if scale is not None else d ** -0.5
    # grouped GQA einsum straight off the stored-dtype cache (see
    # attention_decode_ref) — T1 needs no row max, so this is one pass:
    # exp(s - phi) -> (num, den), order-independent.
    qg = q.reshape(b, hk, groups, d)   # native dtype: no extra rounding
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if shard is not None:
        s = shard(s, "act_scores_decode")
    valid = jnp.arange(s_max)[None, None, None, :] < lengths[:, None, None,
                                                             None]
    centered = s - phi
    e = jnp.where(valid, jnp.exp(centered), 0.0)
    num = jnp.einsum("bhgk,bkhd->bhgd", e.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    den = jnp.sum(e, axis=-1)
    stat = jnp.max(jnp.where(valid, jnp.abs(centered), 0.0),
                   axis=(1, 2, 3))
    if shard is not None:
        num = shard(num, "act_decode_rep")
        den = shard(den, "act_decode_rep")
        stat = shard(stat, "act_decode_rep")
    out = (num / den[..., None]).reshape(b, hq, d).astype(q.dtype)
    return out, stat


# ---------------------------------------------------------------------------
# Paged (block-table) attention oracles
# ---------------------------------------------------------------------------


def dequantize_pool_ref(pool: jax.Array, scales: jax.Array) -> jax.Array:
    """f32 full-precision view of a quantized page pool (oracle path).

    pool: (NP, PS, HK, D) int8/fp8 codes; scales: (NP, HK) f32 steps.
    The expression is exactly the in-kernel dequant (``codes * step`` in
    f32, elementwise per (page, kv head)), so gathering before or after
    dequantization yields identical values — every XLA oracle below can
    therefore take the dequantized pool through its existing math and
    stay bitwise consistent across gather/grouped/fused disciplines.
    """
    return pool.astype(jnp.float32) * scales[:, None, :, None]


def gather_paged_kv(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize the dense per-sequence view of a paged KV pool.

    pool: (num_pages, page_size, HK, D); block_tables: (B, NB) int32.
    Returns (B, NB * page_size, HK, D). Positions past a sequence's length
    read whatever the addressed pages hold — callers mask by ``lengths``.
    """
    b, nb = block_tables.shape
    ps = pool.shape[1]
    # unassigned table entries hold the OOB sentinel num_pages: clamp to a
    # real page — whatever it holds is masked off by the caller's lengths
    gathered = jnp.take(pool, block_tables.reshape(-1), axis=0, mode="clip")
    return gathered.reshape(b, nb * ps, *pool.shape[2:])


def attention_decode_paged_ref(
    q: jax.Array,             # (B, HQ, D)
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB) int32
    lengths: jax.Array,       # (B,)
    *,
    scale: float | None = None,
    shard=None,
) -> jax.Array:
    """Safe (max-stabilized) decode attention over a block-paged cache.

    The XLA path gathers each sequence's pages into a dense view and reuses
    :func:`attention_decode_ref` — bitwise identical to the dense-cache path
    whenever ``NB * PS`` equals the dense ``max_seq`` (additions of masked
    exact zeros do not perturb the reduction).
    """
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    return attention_decode_ref(q, k, v, lengths, scale=scale, shard=shard)


def attention_decode_paged_unified_max_ref(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    phi: float,
    scale: float | None = None,
    shard=None,
) -> tuple[jax.Array, jax.Array]:
    """T1 (async partial-softmax) oracle over a block-paged cache."""
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    return attention_decode_unified_max_ref(
        q, k, v, lengths, phi=phi, scale=scale, shard=shard)


# ---------------------------------------------------------------------------
# Grouped (prefix-shared) decode oracles
# ---------------------------------------------------------------------------


def gather_grouped_kv(pool: jax.Array, block_tables: jax.Array,
                      groups) -> jax.Array:
    """Dense per-sequence KV view reconstructed *through* the group plan.

    ``groups`` duck-types :class:`repro.kernels.group_attention.DecodeGroups`
    (``tables (NG, LP)``, ``gid (B,)``, ``prefix_len (B,)``). Each row's
    positions below its ``prefix_len`` are read via its *group's* block
    table; the rest via its own table — exactly the data sources of the
    two-stage grouped kernel. Because a grouped row's leading block-table
    entries ARE its group's pages (the group key is a leading run of the
    row's own shared pages), the result is elementwise bitwise-equal to
    ``gather_paged_kv(pool, block_tables)`` — while making the group
    operands load-bearing, which is what lets the grouped XLA path promise
    bit-identical outputs versus the ungrouped one.
    """
    b, nb = block_tables.shape
    ps = pool.shape[1]
    ng, lp = groups.tables.shape
    width = nb * ps
    tail = gather_paged_kv(pool, block_tables)          # (B, NB*PS, ...)
    gkv = gather_paged_kv(pool, groups.tables)          # (NG, LP*PS, ...)
    if lp * ps < width:
        pad = [(0, 0), (0, width - lp * ps)] + [(0, 0)] * (gkv.ndim - 2)
        gkv = jnp.pad(gkv, pad)
    else:
        gkv = gkv[:, :width]
    pref = jnp.take(gkv, jnp.clip(groups.gid, 0, ng - 1), axis=0)
    pos = jnp.arange(width)
    use_pref = (pos[None, :, None, None]
                < groups.prefix_len[:, None, None, None])
    return jnp.where(use_pref, pref, tail)


def attention_decode_grouped_ref(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    groups,
    *,
    scale: float | None = None,
    shard=None,
) -> jax.Array:
    """Safe (max-stabilized) grouped decode oracle: the grouped gather
    feeds the identical dense ref, so grouped == ungrouped bitwise."""
    k = gather_grouped_kv(k_pool, block_tables, groups)
    v = gather_grouped_kv(v_pool, block_tables, groups)
    return attention_decode_ref(q, k, v, lengths, scale=scale, shard=shard)


def attention_decode_grouped_unified_max_ref(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    groups,
    *,
    phi: float,
    scale: float | None = None,
    shard=None,
) -> tuple[jax.Array, jax.Array]:
    """T1 (async partial-softmax) grouped decode oracle."""
    k = gather_grouped_kv(k_pool, block_tables, groups)
    v = gather_grouped_kv(v_pool, block_tables, groups)
    return attention_decode_unified_max_ref(
        q, k, v, lengths, phi=phi, scale=scale, shard=shard)


# ---------------------------------------------------------------------------
# Chunk-append attention (chunked prefill)
# ---------------------------------------------------------------------------


def _chunk_attention(q, k_cache, v_cache, lengths, phi, scale):
    """Shared chunk-attention math. Returns (out, stat) where stat is the
    per-batch max |s - phi| over valid positions — the same two-sided T1
    overflow statistic as :func:`attention_decode_unified_max_ref` (the
    under-band side matters too: exp underflow of every valid logit makes
    den 0 -> NaN) — or zeros when ``phi`` is None (safe scheme)."""
    b, c, hq, d = q.shape
    _, s_max, hk, _ = k_cache.shape
    groups = hq // hk
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, c, hk, groups, d)
    s = jnp.einsum("bchgd,bkhd->bhgck", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    qpos = lengths[:, None] + jnp.arange(c)[None, :]        # (B, C)
    valid = (jnp.arange(s_max)[None, None, None, None, :]
             <= qpos[:, None, None, :, None])               # (B,1,1,C,S)
    if phi is not None:
        centered = s - phi
        e = jnp.where(valid, jnp.exp(centered), 0.0)
        stat = jnp.max(jnp.where(valid, jnp.abs(centered), 0.0),
                       axis=(1, 2, 3, 4))
    else:
        m = jnp.max(jnp.where(valid, s, -jnp.inf), axis=-1, keepdims=True)
        e = jnp.where(valid, jnp.exp(s - m), 0.0)
        stat = jnp.zeros((b,), jnp.float32)
    den = jnp.sum(e, axis=-1)                               # (B, HK, G, C)
    num = jnp.einsum("bhgck,bkhd->bchgd", e.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    den_q = den.transpose(0, 3, 1, 2)[..., None]            # (B, C, HK, G, 1)
    o = (num / den_q).reshape(b, c, hq, d)
    return o.astype(q.dtype), stat


def attention_chunk_ref(
    q: jax.Array,          # (B, C, HQ, D) — chunk of new tokens
    k_cache: jax.Array,    # (B, S, HK, D) — chunk already scattered in
    v_cache: jax.Array,
    lengths: jax.Array,    # (B,) lengths *before* this chunk
    *,
    phi: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Chunked-prefill attention: C new tokens attend to prefix + chunk.

    Query i of row b sits at absolute position ``lengths[b] + i``; valid keys
    are cache positions ``<= lengths[b] + i`` (chunk-local causality — the
    chunk's own KV must already be scattered into the cache). Rows past a
    sequence's chunk length produce garbage that callers drop. ``phi`` picks
    the T1 unified-max scheme; None is the safe per-row max.
    """
    out, _ = _chunk_attention(q, k_cache, v_cache, lengths, phi, scale)
    return out


def attention_chunk_unified_max_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    phi: float,
    scale: float | None = None,
) -> tuple[jax.Array, jax.Array]:
    """T1 chunk-attention oracle returning ``(out, stat)`` — stat is the
    per-batch max centered logit for the overflow recompute fallback
    (chunk twin of :func:`attention_decode_unified_max_ref`)."""
    return _chunk_attention(q, k_cache, v_cache, lengths, phi, scale)


def attention_chunk_paged_ref(
    q: jax.Array,             # (B, C, HQ, D)
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB)
    lengths: jax.Array,
    *,
    phi: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Chunked-prefill attention over a block-paged cache (gather + ref).

    Bounded-table identity: trailing table columns whose pages carry only
    causally-masked positions contribute exact zeros to every (num, den)
    partial, so slicing them off (``block_tables[:, :bound]``) leaves the
    result bitwise unchanged — the engine's fused-mode resident bound
    rests on this (and the bit-identity tests enforce it).
    """
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    return attention_chunk_ref(q, k, v, lengths, phi=phi, scale=scale)


def attention_chunk_paged_fused_ref(
    q: jax.Array,             # (B, C, HQ, D)
    k_pool: jax.Array,        # (NP, PS, HK, D)
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, NB)
    lengths: jax.Array,       # (B,) lengths *before* the chunk
    *,
    phi: float | None = None,
    scale: float | None = None,
    k_scale: jax.Array | None = None,  # (NP, HK) quantized-pool steps
    v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Page-blocked oracle for the fused chunk kernel
    (:mod:`repro.kernels.chunk_attention`): accumulates one order-
    independent ``(num, den)`` partial per page, mirroring the kernel's
    grid walk — the T1 unified-max scheme when ``phi`` is set, the
    two-pass safe scheme (global max first, then the page walk) when
    ``phi`` is None. Returns ``(out, stat)``; ``stat: (B, HK)`` is the max
    centered logit (zeros for the safe scheme). With ``k_scale`` /
    ``v_scale`` the pools hold quantized codes and each page dequantizes
    inside the walk — the oracle twin of the kernel's in-VMEM dequant.
    """
    b, c, hq, d = q.shape
    num_pages, ps, hk, _ = k_pool.shape
    nb = block_tables.shape[1]
    groups = hq // hk
    scale = scale if scale is not None else d ** -0.5
    bt = jnp.minimum(block_tables, num_pages - 1)
    qg = q.reshape(b, c, hk, groups, d).astype(jnp.float32) * scale

    def page(pool, steps, i):
        pg = jnp.take(pool, bt[:, i], axis=0).astype(jnp.float32)
        if steps is None:
            return pg                                       # (B, PS, HK, D)
        st = jnp.take(steps, bt[:, i], axis=0)              # (B, HK)
        return pg * st[:, None, :, None]

    qpos = lengths[:, None] + jnp.arange(c)[None, :]        # (B, C)
    num = jnp.zeros((b, c, hk, groups, d), jnp.float32)
    den = jnp.zeros((b, hk, groups, c), jnp.float32)
    stat = jnp.full((b, hk), -jnp.inf, jnp.float32)

    if phi is None:
        # safe scheme: one extra pass for the global row max
        m = jnp.full((b, hk, groups, c), -jnp.inf, jnp.float32)
        for i in range(nb):
            kpg = page(k_pool, k_scale, i)                  # (B, PS, HK, D)
            s = jnp.einsum("bchgd,bkhd->bhgck", qg, kpg)
            kpos = i * ps + jnp.arange(ps)
            valid = (kpos[None, None, None, None, :]
                     <= qpos[:, None, None, :, None])
            m = jnp.maximum(
                m, jnp.max(jnp.where(valid, s, -jnp.inf), axis=-1))
        center = m[..., None]
    else:
        center = phi

    for i in range(nb):
        kpg = page(k_pool, k_scale, i)                      # (B, PS, HK, D)
        vpg = page(v_pool, v_scale, i)
        s = jnp.einsum("bchgd,bkhd->bhgck", qg, kpg)
        kpos = i * ps + jnp.arange(ps)
        valid = (kpos[None, None, None, None, :]
                 <= qpos[:, None, None, :, None])           # (B,1,1,C,PS)
        centered = s - center
        e = jnp.where(valid, jnp.exp(centered), 0.0)
        num = num + jnp.einsum("bhgck,bkhd->bchgd", e,
                               vpg.astype(jnp.float32))
        den = den + jnp.sum(e, axis=-1)
        if phi is not None:
            stat = jnp.maximum(
                stat,
                jnp.max(jnp.where(valid, centered, -jnp.inf),
                        axis=(2, 3, 4)))
    den_q = den.transpose(0, 3, 1, 2)[..., None]            # (B, C, HK, G, 1)
    den_q = jnp.where(den_q == 0.0, 1.0, den_q)
    out = (num / den_q).reshape(b, c, hq, d).astype(q.dtype)
    if phi is None:
        stat = jnp.zeros((b, hk), jnp.float32)
    return out, stat


def attention_prefill_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    sliding_window: int = 0,
    phi: float | None = 0.0,
    block_q: int = 512,
) -> jax.Array:
    """Flash-style blockwise prefill attention on the XLA path.

    Never materializes the (B, H, S, S) score tensor: a python-unrolled loop
    over query blocks (flat HLO — exactly countable by ``cost_analysis``,
    and bounded live memory ≈ (B, H, block_q, S)). With ``phi`` set this is
    the T1 unified-max scheme — each block's (num, den) needs no running-max
    rescale; with ``phi=None`` it uses the per-block max (safe baseline).

    Used by the dry-run and any long-context XLA execution; the Pallas
    kernel covers real-TPU execution.
    """
    b, sq, hq, d = q.shape
    _, sk, hk, _ = k.shape
    groups = hq // hk
    scale = scale if scale is not None else d ** -0.5
    # grouped GQA einsums off the stored dtype — no repeated KV copy
    # (at 32k context the repeat costs `groups` x the KV bytes per layer)
    qf = q.reshape(b, sq, hk, groups, d)   # native dtype; scale on scores

    bq = min(block_q, sq)
    n_blocks = -(-sq // bq)
    ki = jnp.arange(sk)[None, :]
    outs = []
    for i in range(n_blocks):
        lo = i * bq
        cur = min(bq, sq - lo)
        qb = jax.lax.dynamic_slice_in_dim(qf, lo, cur, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, k,
                       preferred_element_type=jnp.float32) * scale
        qi = (lo + jnp.arange(cur))[:, None] + (sk - sq)
        mask = jnp.ones((cur, sk), dtype=bool)
        if causal:
            mask &= qi >= ki
        if sliding_window:
            mask &= qi - ki < sliding_window
        mask4 = mask[None, None, None]
        if phi is not None:
            e = jnp.where(mask4, jnp.exp(s - phi), 0.0)
        else:
            m = jnp.max(jnp.where(mask4, s, -jnp.inf),
                        axis=-1, keepdims=True)
            e = jnp.where(mask4, jnp.exp(s - m), 0.0)
        den = jnp.sum(e, axis=-1)                      # (B, HK, G, cur)
        num = jnp.einsum("bhgqk,bkhd->bqhgd", e.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        den_q = den.transpose(0, 3, 1, 2)[..., None]   # (B, cur, HK, G, 1)
        outs.append((num / den_q).reshape(b, cur, hq, d))
    o = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GEMM oracles
# ---------------------------------------------------------------------------


def flat_gemm_ref(x: jax.Array, w: jax.Array,
                  *, w_scale: jax.Array | None = None) -> jax.Array:
    """(M, K) @ (K, N), fp32 accumulation, result in x.dtype.

    ``w_scale`` ((N,) f32 per-output-channel steps, models/wquant.py)
    marks ``w`` as int8/fp8 codes: the dot runs on the codes cast to
    x.dtype (int8 ±127 and fp8 e4m3 values are exact in bf16) and the
    step multiplies the f32 accumulator — ``codes * step`` factored out
    of the K sum, the one dequant expression the kernel epilogues also
    use. ``w_scale=None`` is the unchanged full-precision expression
    (the bitwise contract)."""
    if w_scale is None:
        return jnp.dot(
            x, w, preferred_element_type=jnp.float32
        ).astype(x.dtype)
    acc = jnp.dot(
        x, w.astype(x.dtype), preferred_element_type=jnp.float32
    )
    return (acc * w_scale.astype(jnp.float32)[None, :]).astype(x.dtype)


def gemv_ref(x: jax.Array, w: jax.Array,
             *, w_scale: jax.Array | None = None) -> jax.Array:
    """Same math as flat_gemm_ref; kept separate as the ImplA oracle."""
    return flat_gemm_ref(x, w, w_scale=w_scale)


def fused_ffn_up_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                     *, activation: str = "swiglu",
                     wg_scale: jax.Array | None = None,
                     wu_scale: jax.Array | None = None) -> jax.Array:
    """Oracle for kernels/fused_ffn.py: act(x@w_gate) * (x@w_up), f32.

    Per-output-channel weight steps (``wg_scale``/``wu_scale``) apply on
    the f32 accumulators *before* the activation — the same order as the
    kernel epilogue, so the nonlinearity sees dequantized values."""
    g = jnp.dot(x, w_gate if wg_scale is None else w_gate.astype(x.dtype),
                preferred_element_type=jnp.float32)
    if wg_scale is not None:
        g = g * wg_scale.astype(jnp.float32)[None, :]
    u = jnp.dot(x, w_up if wu_scale is None else w_up.astype(x.dtype),
                preferred_element_type=jnp.float32)
    if wu_scale is not None:
        u = u * wu_scale.astype(jnp.float32)[None, :]
    act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
    return (act * u).astype(x.dtype)


# ---------------------------------------------------------------------------
# Decode-fusion stage oracles (kernels/decode_fuse.py)
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    """Expression-for-expression copy of ``models.layers.rmsnorm`` (the
    kernels layer cannot import models); the fused-ingest oracle composes
    it so the XLA ``fused``/``looped`` granularities stay bit-identical
    to the split chain."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_ref(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Expression-for-expression copy of ``models.layers.rope``."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def decode_ingest_ref(
    x: jax.Array,             # (B, 1, D) residual-stream input
    norm_scale: jax.Array,    # (D,)
    wq: jax.Array,            # (D, HQ*Dh)
    wk: jax.Array,            # (D, HK*Dh)
    wv: jax.Array,
    positions: jax.Array,     # (B,) int32 absolute positions
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
    eps: float = 1e-6,
    use_rope: bool = True,
    bq: jax.Array | None = None,
    bk: jax.Array | None = None,
    bv: jax.Array | None = None,
    wq_scale: jax.Array | None = None,
    wk_scale: jax.Array | None = None,
    wv_scale: jax.Array | None = None,
):
    """Oracle for the fused decode-ingest stage: rmsnorm → QKV → bias →
    rope in one seam. Composes exactly the split chain's expressions in
    the same order (norm, three f32-accumulated GEMMs, bias add, head
    reshape, rope on q/k), so on the XLA backend the fused granularities
    are bitwise equal to split. Returns q (B,1,HQ,Dh), k/v (B,1,HK,Dh).
    Weight steps (``w*_scale``) dequantize on the f32 accumulators before
    the bias add, matching the kernel epilogue order.
    """
    b, s, d = x.shape
    h = rmsnorm_ref(x, norm_scale, eps)
    h2 = h.reshape(b * s, d)
    q = flat_gemm_ref(h2, wq, w_scale=wq_scale).reshape(b, s, wq.shape[-1])
    k = flat_gemm_ref(h2, wk, w_scale=wk_scale).reshape(b, s, wk.shape[-1])
    v = flat_gemm_ref(h2, wv, w_scale=wv_scale).reshape(b, s, wv.shape[-1])
    if bq is not None:
        q, k, v = q + bq, k + bk, v + bv
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv_heads, head_dim)
    v = v.reshape(b, s, num_kv_heads, head_dim)
    if use_rope:
        pos = positions[:, None]
        q = rope_ref(q, pos, rope_theta)
        k = rope_ref(k, pos, rope_theta)
    return q, k, v


def oproj_residual_ref(o: jax.Array, wo: jax.Array, resid: jax.Array,
                       *, w_scale: jax.Array | None = None) -> jax.Array:
    """Oracle for the fused attention epilogue: ``resid + o @ wo`` — the
    split chain's o_proj GEMM and residual add, same f32 accumulation and
    operand order. o: (B, 1, HQ*Dh); wo: (HQ*Dh, D); resid: (B, 1, D).
    ``w_scale`` dequantizes on the f32 accumulator before the residual
    add (kernel epilogue order)."""
    b, s, qd = o.shape
    out = flat_gemm_ref(
        o.reshape(b * s, qd), wo, w_scale=w_scale
    ).reshape(b, s, wo.shape[-1])
    return resid + out


def ffn_norm_ref(
    x: jax.Array,           # (B, 1, D) residual-stream input (un-normed)
    norm_scale: jax.Array,  # (D,)
    w_gate: jax.Array,      # (D, F)
    w_up: jax.Array,        # (D, F)
    *,
    activation: str = "swiglu",
    eps: float = 1e-6,
    fused: bool = True,
    wg_scale: jax.Array | None = None,
    wu_scale: jax.Array | None = None,
) -> jax.Array:
    """Oracle for the fused mlp-ingest stage: rmsnorm → gate/up GEMMs →
    act(g)*u. ``fused`` selects which split composition to mirror —
    the plan's ``fused_ffn`` knob decides whether the split chain runs
    ``fused_ffn_up_ref`` (f32 epilogue) or two dispatched GEMMs rounded
    to the activation dtype before the activation; the fused seam must
    compose the *same* expressions — with the same reshape placement,
    since the split/looped scan bodies must trace to identical jaxprs
    for XLA to round identically — to stay bitwise."""
    b, s, d = x.shape
    f = w_gate.shape[-1]
    h = rmsnorm_ref(x, norm_scale, eps)
    if fused:
        # mirror ops.fused_ffn: flatten, fused epilogue, reshape back
        return fused_ffn_up_ref(
            h.reshape(b * s, d), w_gate, w_up, activation=activation,
            wg_scale=wg_scale, wu_scale=wu_scale,
        ).reshape(b, s, f)
    # mirror the unfused mlp_block: each GEMM flattens and reshapes back
    # (ops.matmul's XLA path), activation applied on the 3-D tensors
    g = flat_gemm_ref(
        h.reshape(b * s, d), w_gate, w_scale=wg_scale).reshape(b, s, f)
    u = flat_gemm_ref(
        h.reshape(b * s, d), w_up, w_scale=wu_scale).reshape(b, s, f)
    act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
    return act * u
