"""ImplA — VPU GEMV kernel for M ∈ {1..4} (paper §5's FastGEMV analogue).

On GPU the paper routes tiny-M workloads to CUDA cores (FastGEMV) because
Tensor-Core GEMM wastes the M tile. The TPU analogue: for M ≤ 4 even the
8-sublane MXU pass wastes ≥ 50 % of issue slots, and the workload is purely
memory-bound (arithmetic intensity ≈ M FLOP/byte). This kernel keeps the MXU
out of the picture: a broadcast-multiply-reduce on the VPU, streaming W
K-major with the same double-buffered pipeline as the flat GEMM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _gemv_kernel(x_ref, w_ref, out_ref, acc_ref):
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)       # (M, BK)
    w = w_ref[...].astype(jnp.float32)       # (BK, BN)
    # VPU path: broadcast-multiply-reduce, no MXU involvement.
    acc_ref[...] += jnp.sum(x[:, :, None] * w[None, :, :], axis=1)

    @pl.when(ki == n_k - 1)
    def _fin():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _gemv_quant_kernel(x_ref, w_ref, scale_ref, out_ref, acc_ref):
    """Quantized-weight variant: the body already lifts W to f32 for the
    VPU — codes lift the same way — and the per-output-channel step
    ((1, B_N) f32) multiplies the f32 accumulator in the epilogue."""
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)       # (M, BK)
    w = w_ref[...].astype(jnp.float32)       # (BK, BN) codes
    acc_ref[...] += jnp.sum(x[:, :, None] * w[None, :, :], axis=1)

    @pl.when(ki == n_k - 1)
    def _fin():
        out_ref[...] = (acc_ref[...] * scale_ref[...]).astype(out_ref.dtype)


def gemv(
    x: jax.Array,   # (M, K), M <= 4 typical
    w: jax.Array,   # (K, N)
    *,
    w_scale: jax.Array | None = None,   # (N,) f32 -> w is quantized codes
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    out_dtype = out_dtype or x.dtype
    bn = min(block_n, n)
    bk = min(block_k, k)
    if n % bn:
        w = jnp.pad(w, ((0, 0), (0, bn - n % bn)))
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, bk - k % bk)))
        w = jnp.pad(w, ((0, bk - k % bk), (0, 0)))
    kp, np_ = x.shape[1], w.shape[1]

    kernel = _gemv_kernel
    operands = [x, w]
    in_specs = [
        pl.BlockSpec((m, bk), lambda n_, k_: (0, k_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
    ]
    if w_scale is not None:
        scale = w_scale.astype(jnp.float32).reshape(1, -1)
        if np_ != n:
            scale = jnp.pad(scale, ((0, 0), (0, np_ - n)))
        kernel = _gemv_quant_kernel
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)))

    out = pl.pallas_call(
        kernel,
        grid=(np_ // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((m, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :n]
