"""T2 extension — fused flat-GEMM SwiGLU FFN-up for the decode phase.

The decode-phase FFN does two flat GEMMs on the same (M, D) activations
(gate and up projections) followed by ``silu(gate) * up``. Running them as
separate kernels costs an extra read of x and a full HBM round-trip of the
(M, F) gate and up tensors. This kernel computes

    h = silu(x @ w_gate) * (x @ w_up)

in one pass: both K-stream pipelines share the (M_pad, B_K) x-tile, the
epilogue runs on the VPU while the accumulators are still in VMEM, and
only the final (M, B_N) h-tile is written to HBM — the paper's
double-buffering insight extended across the FFN pair:

    HBM traffic    separate: 2·M·K + 2·K·N + 3·M·N   (h read back for mul)
                   fused:      M·K + 2·K·N +   M·N
    (decode M=8..128, K=d_model, N=d_ff: the 2·K·N weight stream dominates
     both, but the fused epilogue removes every activation round-trip and
     half the kernel launches.)

Same minimal M-padding rule as flat_gemm (pad to the 8-sublane atom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pltpu_compat  # noqa: F401  (pltpu.CompilerParams alias)

from repro.kernels.flat_gemm import pick_bk, pick_bn, round_up


def _fused_ffn_kernel(x_ref, wg_ref, wu_ref, *refs,
                      activation: str, quantized: bool = False):
    # Quantized variant appends two per-output-channel step operands
    # ((1, B_N) f32) after the weights; the branches are trace-time, so
    # the bf16 kernel's jaxpr is unchanged. Steps apply on the f32
    # accumulators *before* the activation (dequant-then-nonlinearity).
    if quantized:
        sg_ref, su_ref, out_ref, accg_ref, accu_ref = refs
    else:
        out_ref, accg_ref, accu_ref = refs
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    x = x_ref[...]
    wg = wg_ref[...].astype(x.dtype) if quantized else wg_ref[...]
    wu = wu_ref[...].astype(x.dtype) if quantized else wu_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        x, wg, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    accu_ref[...] += jax.lax.dot_general(
        x, wu, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _fin():
        g = accg_ref[...]
        u = accu_ref[...]
        if quantized:
            g = g * sg_ref[...]
            u = u * su_ref[...]
        act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
        out_ref[...] = (act * u).astype(out_ref.dtype)


def fused_ffn_up(
    x: jax.Array,        # (M, K)
    w_gate: jax.Array,   # (K, N)
    w_up: jax.Array,     # (K, N)
    *,
    activation: str = "swiglu",
    wg_scale: jax.Array | None = None,  # (N,) f32 -> w_gate is codes
    wu_scale: jax.Array | None = None,  # (N,) f32 -> w_up is codes
    block_n: int = 0,
    block_k: int = 0,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """h = act(x @ w_gate) * (x @ w_up), epilogue fused in VMEM."""
    assert (wg_scale is None) == (wu_scale is None), \
        "gate/up weights quantize together"
    m, k = x.shape
    k2, n = w_gate.shape
    assert (k2, n) == w_up.shape == (k, n), (x.shape, w_gate.shape,
                                             w_up.shape)
    out_dtype = out_dtype or x.dtype
    dtype_bytes = jnp.dtype(x.dtype).itemsize

    m_pad = round_up(max(m, 1), 8)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))

    bn = block_n or pick_bn(m_pad, n, k, dtype_bytes=dtype_bytes)
    bk = block_k or pick_bk(m_pad, bn, k, dtype_bytes=dtype_bytes)
    # halve B_K if the doubled (two weight streams + two f32 accumulators)
    # working set would overflow the VMEM budget the single-GEMM picker
    # assumed
    from repro import hardware
    budget = hardware.DEFAULT.vmem_bytes // 4
    while bk > 128 and (
            2 * (m_pad * bk + 2 * bk * bn) * dtype_bytes
            + 2 * m_pad * bn * 4) > budget:
        bk //= 2
    if n % bn:
        pad_n = bn - n % bn
        w_gate = jnp.pad(w_gate, ((0, 0), (0, pad_n)))
        w_up = jnp.pad(w_up, ((0, 0), (0, pad_n)))
    if k % bk:
        pad_k = bk - k % bk
        x = jnp.pad(x, ((0, 0), (0, pad_k)))
        w_gate = jnp.pad(w_gate, ((0, pad_k), (0, 0)))
        w_up = jnp.pad(w_up, ((0, pad_k), (0, 0)))
    kp, np_ = x.shape[1], w_gate.shape[1]

    quantized = wg_scale is not None
    operands = [x, w_gate, w_up]
    in_specs = [
        pl.BlockSpec((m_pad, bk), lambda n_, k_: (0, k_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
        pl.BlockSpec((bk, bn), lambda n_, k_: (k_, n_)),
    ]
    if quantized:
        for s in (wg_scale, wu_scale):
            s = s.astype(jnp.float32).reshape(1, -1)
            if np_ != n:
                s = jnp.pad(s, ((0, 0), (0, np_ - n)))
            operands.append(s)
            in_specs.append(pl.BlockSpec((1, bn), lambda n_, k_: (0, n_)))

    out = pl.pallas_call(
        functools.partial(_fused_ffn_kernel, activation=activation,
                          quantized=quantized),
        grid=(np_ // bn, kp // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m_pad, bn), lambda n_, k_: (0, n_)),
        out_shape=jax.ShapeDtypeStruct((m_pad, np_), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((m_pad, bn), jnp.float32),
            pltpu.VMEM((m_pad, bn), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
