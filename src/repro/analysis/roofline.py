"""Three-term roofline from dry-run artifacts (TPU v5e target).

  compute    = HLO_FLOPs        / (chips x peak_FLOP/s)
  memory     = HLO_bytes        / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; because XLA reports a
``lax.scan`` body once, the dry-run lowers two small *unrolled probes*
(L=1 and L=3) per cell and this module linearly decomposes

  total(L) = outside + L x per_layer

which is exact since every layer is identical. Collective bytes come from
:mod:`repro.analysis.hlo` over the probe HLO (flat, no while loops), scaled
the same way. The full-depth scan model is separately compiled as the
fit/shard proof (memory_analysis).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro import hardware


@dataclasses.dataclass
class ProbeCost:
    """cost_analysis + collective bytes of one lowered probe."""

    num_layers: int
    flops: float
    bytes_accessed: float
    collective_bytes: float
    # measured on the sharded program; all values are *global* (all chips)


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float       # MODEL_FLOPS / HLO_FLOPs
    bottleneck: str
    bound_s: float            # max of the three terms
    per_device_bytes: Optional[int] = None  # from memory_analysis

    def to_dict(self):
        return dataclasses.asdict(self)


def extrapolate(probes: list[ProbeCost], num_layers: int) -> ProbeCost:
    """Linear L-decomposition from two probes; exact for identical layers.

    FLOPs/bytes come from the pre-SPMD module and are exactly linear.
    Collective bytes come from the *compiled* per-device module, where
    GSPMD occasionally flips strategy between probe depths — both the
    per-layer slope and the depth-0 intercept are clamped at 0 so a
    strategy flip can never produce a negative projection.
    """
    assert len(probes) >= 2
    a, b = probes[0], probes[-1]
    dl = b.num_layers - a.num_layers
    assert dl > 0

    def project(va: float, vb: float, *, clamp: bool) -> float:
        per_layer = (vb - va) / dl
        if clamp:
            per_layer = max(per_layer, 0.0)
        out = va - a.num_layers * per_layer
        if clamp:
            out = max(out, 0.0)
        return out + num_layers * per_layer

    return ProbeCost(
        num_layers=num_layers,
        flops=project(a.flops, b.flops, clamp=False),
        bytes_accessed=project(a.bytes_accessed, b.bytes_accessed,
                               clamp=False),
        collective_bytes=project(a.collective_bytes, b.collective_bytes,
                                 clamp=True),
    )


def model_flops_estimate(
    *, params_active: int, tokens: int, kind: str,
    kv_len: int = 0, num_layers: int = 0, d_model: int = 0,
    num_kv_heads: int = 0, head_dim: int = 0, num_q_heads: int = 0,
    seq_len: int = 0,
) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for fwd-only (+ attention).

    Attention score/value FLOPs are added explicitly since 6ND ignores them
    (they matter at 32k+ context).
    """
    base = (6.0 if kind == "train" else 2.0) * params_active * tokens
    attn = 0.0
    if num_layers and num_q_heads:
        if kind == "decode":
            # one new token vs kv_len cache
            attn = (
                num_layers * tokens * num_q_heads * head_dim * kv_len * 2 * 2.0
            )
        else:
            # causal prefill/train: S^2/2 per head pair, x2 matmuls
            attn = (
                num_layers * tokens * num_q_heads * head_dim * seq_len * 0.5
                * 2 * 2.0
            )
            if kind == "train":
                attn *= 3  # fwd + 2x bwd
    return base + attn


def terms_from(
    *, arch: str, shape: str, mesh: str, chips: int,
    cost: ProbeCost, model_flops: float,
    per_device_bytes: Optional[int] = None,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> RooflineTerms:
    compute_s = cost.flops / (chips * spec.peak_flops_bf16)
    memory_s = cost.bytes_accessed / (chips * spec.hbm_bw)
    collective_s = cost.collective_bytes / (chips * spec.ici_bw_per_link)
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=cost.flops, hlo_bytes=cost.bytes_accessed,
        collective_bytes=cost.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=model_flops,
        useful_ratio=model_flops / cost.flops if cost.flops else 0.0,
        bottleneck=bottleneck, bound_s=terms[bottleneck],
        per_device_bytes=per_device_bytes,
    )


def save_report(path: str, rows: list[RooflineTerms]) -> None:
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=2)


def load_report(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)


def format_table(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':<16}{'shape':<13}{'mesh':<10}{'compute_s':>12}"
        f"{'memory_s':>12}{'collect_s':>12}{'bottleneck':>12}"
        f"{'useful':>8}{'GB/dev':>8}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        gb = (r.get("per_device_bytes") or 0) / 2**30
        lines.append(
            f"{r['arch']:<16}{r['shape']:<13}{r['mesh']:<10}"
            f"{r['compute_s']:>12.4e}{r['memory_s']:>12.4e}"
            f"{r['collective_s']:>12.4e}{r['bottleneck']:>12}"
            f"{r['useful_ratio']:>8.2f}{gb:>8.2f}"
        )
    return "\n".join(lines)
