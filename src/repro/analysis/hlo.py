"""Parse compiled HLO text for the roofline's collective term.

``compiled.cost_analysis()`` has no collective-bytes entry, so we sum the
result-shape bytes of every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op in the HLO
text, per computation — callers that lower ``lax.scan``-based programs supply
trip-count multipliers for while-body computations (XLA reports a loop body
once; see EXPERIMENTS.md §Methodology).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = f32[1,2,3]{2,1,0} all-reduce(` — possibly tuple-typed
_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9]+\[[^\]=]*\](?:\{[^}]*\})?"
    r"(?:,\s*[a-z0-9]+\[[^\]=]*\](?:\{[^}]*\})?)*\)?)\s+"
    r"(?P<kind>" + "|".join(COLLECTIVE_KINDS) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[^\]=]*)\]")
_COMP_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$"
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dtype")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims").strip()
        n = 1
        if dims:
            for d in dims.split(","):
                d = d.strip()
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    """Bytes per collective kind, per computation."""

    by_comp: Dict[str, Dict[str, int]]
    counts: Dict[str, int]

    def total_bytes(self, multipliers: Dict[str, int] | None = None) -> int:
        """Total collective bytes; ``multipliers`` maps a substring of a
        computation name (e.g. ``"while"``) to its trip count."""
        multipliers = multipliers or {}
        total = 0
        for comp, kinds in self.by_comp.items():
            mult = 1
            for key, m in multipliers.items():
                if key in comp:
                    mult = m
                    break
            total += mult * sum(kinds.values())
        return total

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = defaultdict(int)
        for kinds in self.by_comp.values():
            for kind, b in kinds.items():
                out[kind] += b
        return dict(out)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_comp: Dict[str, Dict[str, int]] = defaultdict(lambda: defaultdict(int))
    counts: Dict[str, int] = defaultdict(int)
    comp = "entry"
    for line in hlo_text.splitlines():
        cm = _COMP_RE.match(line)
        if cm:
            comp = cm.group("name")
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        if kind + "-done(" in line:
            continue  # avoid double counting async pairs: count -start only
        by_comp[comp][kind] += _shape_bytes(m.group("type"))
        counts[kind] += 1
    return CollectiveStats(
        by_comp={k: dict(v) for k, v in by_comp.items()},
        counts=dict(counts),
    )
