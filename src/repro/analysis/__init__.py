"""Dry-run artifact analysis: HLO collective parsing + roofline terms."""
from repro.analysis import hlo, roofline  # noqa: F401
