"""ExecutionPlan — one tuned, serializable kernel-dispatch surface (T3
generalized to every op).

The paper's heuristic dataflow (§5) profiles implementations *offline* and
consults a zero-overhead lookup at runtime. The original reproduction did
this for GEMM only; every other implementation decision — sync vs.
unified-max softmax, the overflow-recompute branch, decode ``block_k``,
the chunked-prefill threshold, fused-FFN on/off, Pallas vs. XLA ref — was
a per-call-site flag. :class:`ExecutionPlan` makes implementation
selection a first-class tunable surface spanning the whole graph:

  * a registry of per-op decisions (``matmul`` inflections per [K, N],
    ``attention_decode`` scheme + ``block_k`` + fallback,
    ``attention_prefill`` chunking threshold + φ policy, ``fused_ffn``
    fused/unfused, the paged-path knobs — decode backend/scheme plus
    the chunked-prefill ``gather_chunk`` mode with its tuned
    ``fused_threshold`` / ``chunk_block`` companions — and the
    ``decode_fusion`` stage granularity: split op chain vs. fused
    ingest/epilogue stage kernels vs. the looped whole-depth dispatch);
  * one offline :func:`tune` flow (``measure="analytical"`` roofline
    models in this CPU container, ``measure="wallclock"`` on real
    hardware) that generalizes ``find_inflections`` beyond GEMM;
  * versioned JSON serialization (``plans/<arch>-<hw>.json``) carrying
    provenance — backend, hardware-spec hash, config hash — with
    staleness rejection on load: a plan tuned for different hardware or a
    different architecture refuses to drive a run.

``Ctx``, ``ops.*``, ``Engine`` and the launch CLIs all take exactly one
``plan=`` operand; plans may change *which* kernel runs, never the math
(enforced by the greedy-identity tests).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Callable, Dict, Optional, Tuple, Union

from repro import hardware
from repro.config import ModelConfig
from repro.core import dispatch

PLAN_VERSION = 1

BACKENDS = ("xla", "pallas")
SCHEMES = ("sync", "unified_max")
GATHER_MODES = ("dense", "fused")  # chunk-path page access discipline
GROUP_MODES = ("off", "grouped")   # decode-path shared-prefix discipline
KV_DTYPES = ("bf16", "int8", "fp8")  # paged KV page storage precision
WEIGHT_DTYPES = ("bf16", "int8", "fp8")  # GEMM weight storage precision
FUSION_MODES = ("split", "fused", "looped")  # decode-layer stage granularity


class PlanError(ValueError):
    """Malformed plan document (bad JSON shape, unknown knob value)."""


class StalePlanError(PlanError):
    """Plan provenance does not match the requested run (wrong plan
    version, hardware spec, or model config) — retune instead of serving
    decisions profiled for a different world."""


# ---------------------------------------------------------------------------
# Per-op decision records
# ---------------------------------------------------------------------------


def _check(value: str, allowed: Tuple[str, ...], what: str) -> None:
    if value not in allowed:
        raise PlanError(f"{what} must be one of {allowed}, got {value!r}")


def _check_pos(value: int, what: str) -> None:
    if not isinstance(value, int) or isinstance(value, bool) or value < 1:
        raise PlanError(f"{what} must be a positive int, got {value!r}")


@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    """GEMM routing: tuned [K, N] inflection entries + the default policy
    for unseen shapes (single source of truth for the static ladder that
    used to be duplicated in ``DispatchTable.pick`` and ``ops.matmul``).

    ``weight_dtype`` is the GEMM weight storage precision
    (:data:`WEIGHT_DTYPES`) — the weight-side twin of
    ``PagedPlan.kv_dtype``:

      * ``"bf16"`` — full-precision weight slabs, the legacy bitwise
        path.
      * ``"int8"`` / ``"fp8"`` — the engine's quantize-at-load pass
        (:mod:`repro.models.wquant`) converts every GEMM weight leaf to
        codes plus one f32 step per output channel; the GEMM kernels and
        their jnp oracles dequantize on the f32 accumulator in-register
        (``codes * step`` factored out of the K sum), so every decode
        tick streams ~half the weight bytes and the bf16 slab never
        materializes in HBM. Bias/norm/embedding/lm-head leaves stay
        full precision.

    The precision scales the weight-byte term of the dispatch rooflines
    (:data:`repro.core.dispatch.WEIGHT_DTYPE_BYTES` via
    :func:`repro.core.dispatch.param_bytes`) and is auto-picked by
    :func:`repro.core.dispatch.find_weight_dtype` under the dtype-derived
    logits-closeness guard (``quant.logits_guard_tol`` — the same
    accuracy contract as the KV axis). Quantization changes logits only
    within that tolerance; the bf16 path stays bitwise.
    """

    backend: str = "xla"
    # unseen-shape policy: ImplA below m1, ImplB below m2, ImplC above —
    # the conservative static ladder (GEMV only at M<=2, XLA from M=128)
    default_m1: int = 3
    default_m2: int = 128
    weight_dtype: str = "bf16"
    entries: Dict[Tuple[int, int], dispatch.DispatchEntry] = \
        dataclasses.field(default_factory=dict)

    def __post_init__(self):
        _check(self.backend, BACKENDS, "matmul.backend")
        _check(self.weight_dtype, WEIGHT_DTYPES, "matmul.weight_dtype")
        _check_pos(self.default_m1, "matmul.default_m1")
        _check_pos(self.default_m2, "matmul.default_m2")
        if self.default_m2 < self.default_m1:
            raise PlanError(
                f"matmul default ladder inverted: m1={self.default_m1} > "
                f"m2={self.default_m2}")
        for (k, n), e in self.entries.items():
            if e.m2 < e.m1:
                raise PlanError(
                    f"matmul entry [{k}, {n}] inverted: m1={e.m1} > "
                    f"m2={e.m2}")

    def pick(self, m: int, k: int, n: int) -> dispatch.Impl:
        e = self.entries.get((k, n))
        if e is None:
            return dispatch.pick_impl(m, self.default_m1, self.default_m2)
        return e.pick(m)


@dataclasses.dataclass(frozen=True)
class AttentionDecodePlan:
    """Decode-phase attention: softmax scheme, KV grid block, overflow
    recompute. ``scheme="unified_max"`` is effective only when the model's
    φ config is active (T1 needs a calibrated φ); ``fallback=False`` drops
    the ``lax.cond`` recompute branch (dry-run cost-analysis hygiene)."""

    backend: str = "xla"
    scheme: str = "unified_max"
    block_k: int = 512
    fallback: bool = True

    def __post_init__(self):
        _check(self.backend, BACKENDS, "attention_decode.backend")
        _check(self.scheme, SCHEMES, "attention_decode.scheme")
        _check_pos(self.block_k, "attention_decode.block_k")


@dataclasses.dataclass(frozen=True)
class AttentionPrefillPlan:
    """Prefill-phase attention: softmax scheme, overflow recompute, and
    the sequence threshold above which the XLA path switches from the
    materialized (S, S) scores to the blockwise chunked scheme."""

    backend: str = "xla"
    scheme: str = "unified_max"
    fallback: bool = True
    chunk_threshold: int = 2048

    def __post_init__(self):
        _check(self.backend, BACKENDS, "attention_prefill.backend")
        _check(self.scheme, SCHEMES, "attention_prefill.scheme")
        _check_pos(self.chunk_threshold, "attention_prefill.chunk_threshold")


@dataclasses.dataclass(frozen=True)
class FusedFFNPlan:
    """Gate+up epilogue fusion (T2 extension): ``fused=True`` routes the
    gated MLP through the single fused kernel instead of two dispatched
    GEMMs. Only meaningful on the Pallas backend."""

    backend: str = "xla"
    fused: bool = False

    def __post_init__(self):
        _check(self.backend, BACKENDS, "fused_ffn.backend")


@dataclasses.dataclass(frozen=True)
class PagedPlan:
    """Block-paged KV path knobs: Pallas scalar-prefetch kernels vs. the
    XLA gather view for paged decode, and the chunked-prefill page-access
    discipline.

    ``gather_chunk`` names how chunked prefill reads resident KV:

      * ``"dense"`` — gather the full ``(B, NB*PS)`` per-sequence view
        per layer per chunk step (one compiled shape, but O(table width)
        materialized bytes every step — the pre-fused path).
      * ``"fused"`` — no full-width materialization. On the Pallas
        backend the fused chunk kernel
        (:mod:`repro.kernels.chunk_attention`) reads K/V pages in place
        via scalar-prefetched block tables; on the XLA backend the
        engine bounds the block-table operand to a bucketed
        O(resident pages) width (bitwise identical — trailing masked
        pages contribute exact zeros) so the remaining gather is
        O(resident KV), not O(max_seq).

    ``fused_threshold`` is the tuned gather-vs-fused inflection: prompts
    shorter than it keep the one-compile dense gather (the fused path's
    per-wave shape changes and per-page grid bubbles only pay off once
    enough of the table is *not* resident); prompts at/above it run the
    fused discipline. ``chunk_block`` is the tuned prefill chunk size
    (``Engine(prefill_chunk=None)`` adopts it); it must divide the page
    size so prefix-sharing chunk boundaries stay on the share-less grid.
    ``decode_group`` names how decode attention treats sequences whose
    block tables share refcounted prefix pages:

      * ``"off"`` — every row re-reads its full table (shared pages are
        deduplicated in *storage* only).
      * ``"grouped"`` — the engine hands the attention op a per-tick
        group plan; the shared prefix's attention is computed **once per
        group** and merged into each member's private tail via the
        unified-max combine (no per-member rescale), so N-way sharing
        reads the prefix KV once instead of N times.

    ``group_threshold`` is the tuned dispatch floor: a group is only
    worth the extra kernel stage when ``members * prefix_pages`` reaches
    it (below that the stage overhead beats the saved KV reads). Tuned
    by :func:`repro.core.dispatch.find_group_threshold`; the other knobs
    by :func:`repro.core.dispatch.find_fused_threshold` /
    :func:`repro.core.dispatch.find_chunk_block`.

    ``swap_threshold`` is the tiered-KV swap-vs-re-prefill inflection:
    at re-admission, a prefix match that extends into demoted (host/disk)
    pages is promoted — one bulk host→device copy — only when the
    demoted span reaches this many pages; below it the match truncates
    at the first demoted entry and those positions re-prefill (the
    PCIe-class copy's fixed setup beats recompute only past the
    crossover). Tuned by :func:`repro.core.dispatch.find_swap_threshold`.

    ``kv_dtype`` is the page storage precision (:data:`KV_DTYPES`):

      * ``"bf16"`` — full-precision pages, the legacy bit-identical path.
      * ``"int8"`` / ``"fp8"`` — pages store quantized codes plus one
        f32 scale per (page, kv head) in a parallel scale pool
        (:mod:`repro.serving.kvquant`); the decode / chunk / group
        kernels dequantize in place, so every KV read moves ~half the
        bytes and the same pool budget holds ~2x the resident tokens.

    The precision scales every KV-byte term in the dispatch rooflines
    (:data:`repro.core.dispatch.KV_DTYPE_BYTES`): smaller pages shift
    ``fused_threshold`` (the gather's O(resident-KV) bytes shrink),
    ``group_threshold`` (the prefix re-read a group saves is cheaper, so
    the stage overhead needs more members/pages to pay off) and
    ``swap_threshold`` (a demoted span moves fewer bytes over the host
    link, so swapping wins earlier). Quantization changes logits only
    within a dtype-derived tolerance, enforced by the logits-closeness
    guard tests — never which tokens a plan may legally produce beyond
    that tolerance.
    """

    backend: str = "xla"
    scheme: str = "unified_max"
    fallback: bool = True
    gather_chunk: str = "dense"
    fused_threshold: int = 256
    chunk_block: int = 64
    decode_group: str = "off"
    group_threshold: int = 2
    swap_threshold: int = 1
    kv_dtype: str = "bf16"

    def __post_init__(self):
        _check(self.backend, BACKENDS, "paged.backend")
        _check(self.scheme, SCHEMES, "paged.scheme")
        _check(self.gather_chunk, GATHER_MODES, "paged.gather_chunk")
        _check_pos(self.fused_threshold, "paged.fused_threshold")
        _check_pos(self.chunk_block, "paged.chunk_block")
        _check(self.decode_group, GROUP_MODES, "paged.decode_group")
        _check_pos(self.group_threshold, "paged.group_threshold")
        _check_pos(self.swap_threshold, "paged.swap_threshold")
        _check(self.kv_dtype, KV_DTYPES, "paged.kv_dtype")


@dataclasses.dataclass(frozen=True)
class DecodeFusionPlan:
    """Decode-layer fusion granularity (the kernel-looping axis).

    Per-token decode is a chain of many small memory-bound ops per layer;
    past the paged/quantized KV work the dominant small-batch cost is the
    dispatch + synchronization boundary *between* them. ``granularity``
    names how much of the per-layer chain one dispatch claims
    (:data:`FUSION_MODES`):

      * ``"split"`` — today's op chain: every stage (norm, QKV, rope,
        scatter, attention, o_proj, residual, FFN) is its own dispatch,
        the whole depth under one ``lax.scan``. The reference path,
        bit-identical by definition.
      * ``"fused"`` — the memory-bound seams collapse into fused stage
        kernels (``ops.decode_ingest`` = norm→QKV→bias→rope,
        ``ops.oproj_residual`` = GEMM-into-residual, serving both the
        o_proj and FFN down-projection epilogues, and ``ops.ffn_norm``
        = mlp_norm→gate/up→activation), with the layer loop
        python-unrolled — L traced layer bodies, each a short fused
        chain.
      * ``"looped"`` — the same fused stage dispatch with the stacked-L
        params run under one ``lax.scan`` (:mod:`repro.models.stack`):
        the layer body is traced once and the whole depth is a single
        host-visible looped dispatch — the Kernel Looping shape.

    The fused stage *kernels* are Pallas-only; on the XLA backend the
    ``fused``/``looped`` granularities dispatch the jnp oracles
    (``ref.decode_ingest_ref`` / ``ref.oproj_residual_ref`` /
    ``ref.ffn_norm_ref``), which compose exactly the split chain's
    expressions in the same order.
    ``split`` and ``looped`` therefore produce bit-identical logits on
    XLA (same scan, same jaxpr per stage — tier-1 enforced). ``fused``
    is the one documented reassociated seam: python-unrolling the L
    layer bodies lets XLA place bf16 rounding at different fusion
    boundaries than the scan body, so it is held to the scheme-swap
    dtype-eps value-closeness bound instead (greedy tokens still agree
    wherever the argmax is decisive). The Pallas kernels additionally
    reassociate the K-streamed GEMM accumulation (f32 tile
    accumulators), so kernel-vs-oracle equality is bounded by the same
    dtype-eps closeness tests, like every other Pallas GEMM in the
    repo. Tuned by
    :func:`repro.core.dispatch.find_decode_fusion` from the
    :func:`repro.core.dispatch.predict_fusion_time` roofline (per-layer
    stage-dispatch count × pipeline fill vs. the scan's one-time loop
    setup).
    """

    backend: str = "xla"
    granularity: str = "split"

    def __post_init__(self):
        _check(self.backend, BACKENDS, "decode_fusion.backend")
        _check(self.granularity, FUSION_MODES, "decode_fusion.granularity")


# ---------------------------------------------------------------------------
# Provenance
# ---------------------------------------------------------------------------


def _digest(obj) -> str:
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True, default=str).encode()
    ).hexdigest()[:12]


def hardware_hash(spec: hardware.HardwareSpec) -> str:
    return _digest(dataclasses.asdict(spec))


def config_hash(cfg: ModelConfig) -> str:
    return _digest(dataclasses.asdict(cfg))


@dataclasses.dataclass(frozen=True)
class PlanProvenance:
    """Where a tuned plan came from — checked on load."""

    backend: str
    hardware: str        # hardware_hash(spec)
    hardware_name: str
    config: str          # config_hash(cfg)
    config_name: str
    measure: str         # analytical | wallclock | custom
    version: int = PLAN_VERSION


# ---------------------------------------------------------------------------
# The plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    matmul: MatmulPlan = dataclasses.field(default_factory=MatmulPlan)
    attention_decode: AttentionDecodePlan = dataclasses.field(
        default_factory=AttentionDecodePlan)
    attention_prefill: AttentionPrefillPlan = dataclasses.field(
        default_factory=AttentionPrefillPlan)
    fused_ffn: FusedFFNPlan = dataclasses.field(default_factory=FusedFFNPlan)
    paged: PagedPlan = dataclasses.field(default_factory=PagedPlan)
    decode_fusion: DecodeFusionPlan = dataclasses.field(
        default_factory=DecodeFusionPlan)
    provenance: Optional[PlanProvenance] = None

    # -- bulk knob overrides -------------------------------------------------

    def with_overrides(
        self,
        *,
        backend: Optional[str] = None,
        scheme: Optional[str] = None,
        fallback: Optional[bool] = None,
        block_k: Optional[int] = None,
    ) -> "ExecutionPlan":
        """Return a copy with shared knobs overridden across every op that
        carries them (``None`` keeps the existing decision). Used by hosts
        with hard constraints — e.g. the dry-run forces ``backend="xla"``
        (Mosaic does not lower on CPU) and ``fallback=False`` (no
        ``lax.cond`` double-count in cost analysis)."""
        def sub(p, **fields):
            fields = {k: v for k, v in fields.items() if v is not None}
            return dataclasses.replace(p, **fields) if fields else p

        fused = None
        if backend is not None and backend != "pallas":
            fused = False   # the fused epilogue kernel is Pallas-only
        return dataclasses.replace(
            self,
            matmul=sub(self.matmul, backend=backend),
            attention_decode=sub(self.attention_decode, backend=backend,
                                 scheme=scheme, fallback=fallback,
                                 block_k=block_k),
            attention_prefill=sub(self.attention_prefill, backend=backend,
                                  scheme=scheme, fallback=fallback),
            fused_ffn=sub(self.fused_ffn, backend=backend, fused=fused),
            paged=sub(self.paged, backend=backend, scheme=scheme,
                      fallback=fallback),
            # granularity survives a backend override: on XLA the fused
            # stages dispatch their bit-identical jnp oracles
            decode_fusion=sub(self.decode_fusion, backend=backend),
        )

    def describe(self) -> str:
        d, p = self.attention_decode, self.attention_prefill
        return (f"matmul[{len(self.matmul.entries)} entries, "
                f"{self.matmul.backend}"
                + (f", w={self.matmul.weight_dtype}"
                   if self.matmul.weight_dtype != "bf16" else "")
                + "] "
                f"decode[{d.scheme}, block_k={d.block_k}, "
                f"fallback={d.fallback}] "
                f"prefill[{p.scheme}, chunk>={p.chunk_threshold}] "
                f"ffn[{'fused' if self.fused_ffn.fused else 'unfused'}] "
                f"paged[{self.paged.backend}/{self.paged.gather_chunk}"
                + (f">={self.paged.fused_threshold}"
                   if self.paged.gather_chunk == "fused" else "")
                + f", chunk={self.paged.chunk_block}"
                + (f", group>={self.paged.group_threshold}"
                   if self.paged.decode_group == "grouped" else "")
                + f", swap>={self.paged.swap_threshold}"
                + (f", kv={self.paged.kv_dtype}"
                   if self.paged.kv_dtype != "bf16" else "")
                + "] "
                f"fusion[{self.decode_fusion.granularity}]")

    # -- serialization -------------------------------------------------------

    def to_json(self) -> str:
        doc = {
            "version": PLAN_VERSION,
            "ops": {
                "matmul": {
                    "backend": self.matmul.backend,
                    "weight_dtype": self.matmul.weight_dtype,
                    "default": {"m1": self.matmul.default_m1,
                                "m2": self.matmul.default_m2},
                    "entries": {
                        f"{k},{n}": {"m1": e.m1, "m2": e.m2}
                        for (k, n), e in sorted(self.matmul.entries.items())
                    },
                },
                "attention_decode": dataclasses.asdict(self.attention_decode),
                "attention_prefill": dataclasses.asdict(
                    self.attention_prefill),
                "fused_ffn": dataclasses.asdict(self.fused_ffn),
                "paged": dataclasses.asdict(self.paged),
                "decode_fusion": dataclasses.asdict(self.decode_fusion),
            },
        }
        if self.provenance is not None:
            doc["provenance"] = dataclasses.asdict(self.provenance)
        return json.dumps(doc, indent=2)

    @staticmethod
    def from_json(s: str) -> "ExecutionPlan":
        try:
            doc = json.loads(s)
        except json.JSONDecodeError as e:
            raise PlanError(f"plan is not valid JSON: {e}") from e
        if not isinstance(doc, dict) or "ops" not in doc:
            raise PlanError("plan document has no 'ops' registry")
        version = doc.get("version")
        if version != PLAN_VERSION:
            raise StalePlanError(
                f"plan version {version!r} != supported {PLAN_VERSION}")
        ops = doc["ops"]
        try:
            mm = ops["matmul"]
            entries = {}
            for key, d in mm.get("entries", {}).items():
                k, n = (int(x) for x in key.split(","))
                entries[(k, n)] = dispatch.DispatchEntry(
                    k=k, n=n, m1=int(d["m1"]), m2=int(d["m2"]))
            matmul = MatmulPlan(
                backend=mm["backend"],
                # pre-wquant plans load with the bf16 default
                weight_dtype=mm.get("weight_dtype", "bf16"),
                default_m1=int(mm["default"]["m1"]),
                default_m2=int(mm["default"]["m2"]),
                entries=entries,
            )
            plan = ExecutionPlan(
                matmul=matmul,
                attention_decode=AttentionDecodePlan(
                    **ops["attention_decode"]),
                attention_prefill=AttentionPrefillPlan(
                    **ops["attention_prefill"]),
                fused_ffn=FusedFFNPlan(**ops["fused_ffn"]),
                paged=PagedPlan(**ops["paged"]),
                # pre-fusion plans load with the split default
                decode_fusion=DecodeFusionPlan(
                    **ops.get("decode_fusion", {})),
            )
        except (KeyError, TypeError, ValueError) as e:
            if isinstance(e, PlanError):
                raise
            raise PlanError(f"malformed plan ops registry: {e!r}") from e
        prov = doc.get("provenance")
        if prov is not None:
            try:
                plan = dataclasses.replace(
                    plan, provenance=PlanProvenance(**prov))
            except TypeError as e:
                raise PlanError(
                    f"malformed plan provenance: {e!r}") from e
        return plan

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @staticmethod
    def load(
        path: str,
        *,
        cfg: Optional[ModelConfig] = None,
        spec: Optional[hardware.HardwareSpec] = hardware.DEFAULT,
        strict: bool = True,
    ) -> "ExecutionPlan":
        """Load a plan, rejecting stale artifacts.

        ``strict=True`` (the default) refuses plans without provenance and
        plans whose recorded hardware/config hash differs from the
        requested ``spec``/``cfg`` (pass ``cfg=None``/``spec=None`` to
        skip that axis). ``strict=False`` loads anything structurally
        valid — for inspection tooling, never for serving.
        """
        with open(path) as f:
            plan = ExecutionPlan.from_json(f.read())
        if not strict:
            return plan
        prov = plan.provenance
        if prov is None:
            raise StalePlanError(
                f"{path}: plan has no provenance; tune one with "
                "repro.core.plan.tune (or load with strict=False)")
        if spec is not None and prov.hardware != hardware_hash(spec):
            raise StalePlanError(
                f"{path}: tuned for hardware {prov.hardware_name} "
                f"[{prov.hardware}], run targets {spec.name} "
                f"[{hardware_hash(spec)}] — retune")
        if cfg is not None and prov.config != config_hash(cfg):
            raise StalePlanError(
                f"{path}: tuned for config {prov.config_name} "
                f"[{prov.config}], run uses {cfg.name} "
                f"[{config_hash(cfg)}] — retune")
        return plan


DEFAULT_PLAN = ExecutionPlan()


def make_plan(
    backend: str = "xla",
    *,
    scheme: str = "unified_max",
    fallback: bool = True,
    block_k: int = 512,
    chunk_threshold: int = 2048,
    fused_ffn: Optional[bool] = None,
    gather_chunk: str = "dense",
    fused_threshold: int = 256,
    chunk_block: int = 64,
    decode_group: str = "off",
    group_threshold: int = 2,
    swap_threshold: int = 1,
    kv_dtype: str = "bf16",
    weight_dtype: str = "bf16",
    decode_fusion: str = "split",
) -> ExecutionPlan:
    """Build an untuned plan with uniform knobs — the hand-rolled
    counterpart of :func:`tune` for hosts that only need to pin backends
    or drop fallbacks (benchmarks, the dry-run, tests)."""
    if fused_ffn is None:
        fused_ffn = backend == "pallas"
    return ExecutionPlan(
        matmul=MatmulPlan(backend=backend, weight_dtype=weight_dtype),
        attention_decode=AttentionDecodePlan(
            backend=backend, scheme=scheme, fallback=fallback,
            block_k=block_k),
        attention_prefill=AttentionPrefillPlan(
            backend=backend, scheme=scheme, fallback=fallback,
            chunk_threshold=chunk_threshold),
        fused_ffn=FusedFFNPlan(backend=backend, fused=fused_ffn),
        paged=PagedPlan(backend=backend, scheme=scheme, fallback=fallback,
                        gather_chunk=gather_chunk,
                        fused_threshold=fused_threshold,
                        chunk_block=chunk_block,
                        decode_group=decode_group,
                        group_threshold=group_threshold,
                        swap_threshold=swap_threshold,
                        kv_dtype=kv_dtype),
        decode_fusion=DecodeFusionPlan(backend=backend,
                                       granularity=decode_fusion),
    )


# ---------------------------------------------------------------------------
# Offline tuning flow (generalizes find_inflections beyond GEMM)
# ---------------------------------------------------------------------------


MeasureLike = Union[str, dispatch.MeasureFn, None]


def _resolve_measure(measure: MeasureLike):
    """-> (gemm measure fn | None, provenance label)."""
    if measure is None or measure == "analytical":
        return None, "analytical"
    if measure == "wallclock":
        return dispatch.wallclock_measure_factory(), "wallclock"
    if callable(measure):
        return measure, "custom"
    raise PlanError(
        f"measure must be 'analytical', 'wallclock', or a callable; "
        f"got {measure!r}")


def tune(
    cfg: ModelConfig,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    *,
    measure: MeasureLike = "analytical",
    backend: str = "xla",
    decode_seq: int = 32768,
    page_size: int = 64,
    kv_dtype: str = "bf16",
    weight_dtype: Optional[str] = "bf16",
) -> ExecutionPlan:
    """Profile every op decision offline and emit a provenanced plan.

    GEMM inflections come from ``measure`` (the paper's Fig. 9(b) flow —
    analytical roofline here, wallclock on real hardware; attention/FFN
    decisions always use the analytical models, which is what the
    wallclock backend can't reach without a device anyway). ``decode_seq``
    is the representative decode KV length the ``block_k`` sweep
    optimizes for; ``page_size`` anchors the paged chunked-prefill
    decisions (``chunk_block`` and the dense-gather vs fused-kernel
    ``fused_threshold`` inflection). ``kv_dtype`` selects the page
    precision and rescales every KV-byte roofline term the paged
    thresholds come from (see :class:`PagedPlan`). ``weight_dtype``
    selects the GEMM weight storage precision (see :class:`MatmulPlan`);
    pass ``None`` to let :func:`repro.core.dispatch.find_weight_dtype`
    pick the fastest candidate whose dtype-derived guard tolerance the
    run accepts — the resolved value rescales the weight-byte terms of
    the swap and fusion rooflines via
    :func:`repro.core.dispatch.param_bytes`.
    """
    _check(backend, BACKENDS, "backend")
    _check(kv_dtype, KV_DTYPES, "kv_dtype")
    if weight_dtype is None:
        weight_dtype = dispatch.find_weight_dtype(cfg, spec=spec)
    _check(weight_dtype, WEIGHT_DTYPES, "weight_dtype")
    gemm_measure, measure_name = _resolve_measure(measure)

    entries: Dict[Tuple[int, int], dispatch.DispatchEntry] = {}
    for gs in dispatch.model_gemm_shapes(cfg):
        if (gs.k, gs.n) not in entries:
            entries[(gs.k, gs.n)] = dispatch.find_inflections(
                gs.k, gs.n, measure=gemm_measure, spec=spec)
    # the unseen-shape policy is itself tuned: a representative square
    # [d_model, d_model] workload stands in for shapes the sweep missed
    default = dispatch.find_inflections(
        cfg.d_model, cfg.d_model, measure=gemm_measure, spec=spec)

    scheme = "unified_max" if cfg.softmax_phi.active else "sync"
    block_k = dispatch.find_block_k(
        min(decode_seq, cfg.max_seq_len), cfg.kv_dim, spec=spec)
    threshold = dispatch.find_chunk_threshold(cfg.num_heads, spec=spec)
    rep_seq = min(decode_seq, cfg.max_seq_len)
    chunk_block = dispatch.find_chunk_block(
        rep_seq, cfg.kv_dim, page_size=page_size, spec=spec,
        kv_dtype=kv_dtype)
    fused_threshold = dispatch.find_fused_threshold(
        rep_seq, cfg.kv_dim, chunk=chunk_block, page_size=page_size,
        spec=spec, kv_dtype=kv_dtype)
    group_threshold = dispatch.find_group_threshold(
        cfg.kv_dim, page_size=page_size, spec=spec, kv_dtype=kv_dtype)
    swap_threshold = dispatch.find_swap_threshold(
        cfg, chunk=chunk_block, page_size=page_size, spec=spec,
        kv_dtype=kv_dtype, weight_dtype=weight_dtype)
    granularity = dispatch.find_decode_fusion(cfg, spec=spec,
                                              weight_dtype=weight_dtype)

    plan = ExecutionPlan(
        matmul=MatmulPlan(backend=backend, default_m1=default.m1,
                          default_m2=default.m2, entries=entries,
                          weight_dtype=weight_dtype),
        attention_decode=AttentionDecodePlan(
            backend=backend, scheme=scheme, block_k=block_k),
        attention_prefill=AttentionPrefillPlan(
            backend=backend, scheme=scheme, chunk_threshold=threshold),
        fused_ffn=FusedFFNPlan(
            backend=backend,
            fused=backend == "pallas"
            and cfg.activation in ("swiglu", "geglu")),
        paged=PagedPlan(backend=backend, scheme=scheme,
                        gather_chunk="fused",
                        fused_threshold=fused_threshold,
                        chunk_block=chunk_block,
                        decode_group="grouped",
                        group_threshold=group_threshold,
                        swap_threshold=swap_threshold,
                        kv_dtype=kv_dtype),
        decode_fusion=DecodeFusionPlan(backend=backend,
                                       granularity=granularity),
        provenance=PlanProvenance(
            backend=backend,
            hardware=hardware_hash(spec), hardware_name=spec.name,
            config=config_hash(cfg), config_name=cfg.name,
            measure=measure_name),
    )
    return plan


def default_plan_path(
    cfg: ModelConfig,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    root: str = "plans",
) -> str:
    """The versioned artifact location: ``plans/<arch>-<hw>.json``."""
    return os.path.join(root, f"{cfg.name}-{spec.name}.json")
