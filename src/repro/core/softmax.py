"""T1 math — unified-max partial softmax and the two combine schemes.

The distributed decode path (sequence-split attention across the ``model``
mesh axis) uses these helpers inside ``shard_map``: each shard produces a
partial ``(num, den)`` from its KV slice, and the combine is

  * async (T1):  ``psum(num), psum(den)`` — one additive reduction, because a
    unified φ makes partials directly addable (Eq. 4).
  * sync (baseline): ``pmax(m)`` first, then rescale each shard's partial by
    ``exp(m_local − m_global)``, then ``psum`` — the synchronized update of
    Eq. 2, which costs an extra collective plus a rescale on every shard.

The removal of that max-collective is the pod-scale payoff of T1 and is
visible in the dry-run's HLO collective schedule.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AsyncPartial(NamedTuple):
    """Order-independent softmax partial (Eq. 4 inner accumulations)."""

    num: jax.Array    # Σ e^{s−φ} · v   — (..., d)
    den: jax.Array    # Σ e^{s−φ}       — (...,)
    max_centered: jax.Array  # max(s−φ)  — (...,) overflow statistic


class SyncPartial(NamedTuple):
    """Max-carrying partial (Eq. 2) — needs synchronized combination."""

    num: jax.Array
    den: jax.Array
    m: jax.Array      # local max


def async_partial(
    s: jax.Array,          # (..., kv) pre-softmax logits
    v: jax.Array,          # (..., kv, d)
    phi: float,
    valid: jax.Array | None = None,
) -> AsyncPartial:
    centered = s - phi
    if valid is not None:
        e = jnp.where(valid, jnp.exp(centered), 0.0)
        mc = jnp.max(jnp.where(valid, centered, -jnp.inf), axis=-1)
    else:
        e = jnp.exp(centered)
        mc = jnp.max(centered, axis=-1)
    num = jnp.einsum("...k,...kd->...d", e, v)
    den = jnp.sum(e, axis=-1)
    return AsyncPartial(num, den, mc)


def sync_partial(
    s: jax.Array,
    v: jax.Array,
    valid: jax.Array | None = None,
) -> SyncPartial:
    if valid is not None:
        s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(s - safe_m[..., None])
    e = jnp.where(jnp.isfinite(s), e, 0.0)
    num = jnp.einsum("...k,...kd->...d", e, v)
    den = jnp.sum(e, axis=-1)
    return SyncPartial(num, den, m)


# -- single-host combines (tree-reduction over a list of partials) -----------


def combine_async(partials: list[AsyncPartial]) -> tuple[jax.Array, jax.Array]:
    """Additive combine: returns (out, max_centered)."""
    num = sum(p.num for p in partials)
    den = sum(p.den for p in partials)
    mc = jnp.stack([p.max_centered for p in partials]).max(0)
    return num / den[..., None], mc


def combine_sync(partials: list[SyncPartial]) -> jax.Array:
    """Synchronized combine: global max, rescale every partial, then add."""
    m = jnp.stack([p.m for p in partials]).max(0)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    num = sum(p.num * jnp.exp(jnp.where(jnp.isfinite(p.m), p.m, -jnp.inf)
                              - safe_m)[..., None] for p in partials)
    den = sum(p.den * jnp.exp(jnp.where(jnp.isfinite(p.m), p.m, -jnp.inf)
                              - safe_m) for p in partials)
    den = jnp.where(den == 0.0, 1.0, den)
    return num / den[..., None]


# -- collective combines (inside shard_map, over a named mesh axis) ----------


def combine_async_collective(
    p: AsyncPartial, axis: str
) -> tuple[jax.Array, jax.Array]:
    """T1 cross-shard combine: a single additive psum pair."""
    num = jax.lax.psum(p.num, axis)
    den = jax.lax.psum(p.den, axis)
    mc = jax.lax.pmax(p.max_centered, axis)
    return num / den[..., None], mc


def combine_sync_collective(p: SyncPartial, axis: str) -> jax.Array:
    """Baseline cross-shard combine: pmax + rescale + psum (Eq. 2)."""
    m = jax.lax.pmax(p.m, axis)
    safe_m = jnp.where(jnp.isfinite(m), m, 0.0)
    scale = jnp.exp(jnp.where(jnp.isfinite(p.m), p.m, -jnp.inf) - safe_m)
    num = jax.lax.psum(p.num * scale[..., None], axis)
    den = jax.lax.psum(p.den * scale, axis)
    den = jnp.where(den == 0.0, 1.0, den)
    return num / den[..., None]
