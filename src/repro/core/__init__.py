"""The paper's contributions as composable features.

  * T1 — :mod:`repro.core.softmax` (unified-max partial softmax + combines)
          and :mod:`repro.core.phi` (phi calibration / per-arch registry).
  * T2 — surfaced through :mod:`repro.kernels.flat_gemm`.
  * T3 — :mod:`repro.core.dispatch` (heuristic dataflow cost models) and
          :mod:`repro.core.plan` (the tuned, serializable
          :class:`~repro.core.plan.ExecutionPlan` every op dispatches by).
  * :mod:`repro.core.attention` — the attention front door the model zoo uses.
"""
from repro.core import dispatch, phi, plan, softmax  # noqa: F401
