"""Distributed decode attention — T1's pod-scale payoff, explicitly.

``decode_attention_sharded`` runs split-KV decode attention under
``shard_map``: each ``model``-axis shard owns a contiguous S/TP slice of
the KV cache, computes its partial ``(num, den)`` with the unified max
value φ, and the cross-shard combine is

  * **async (T1)** — ``psum(num), psum(den)``: one additive reduction
    (the two psums fuse into a single variadic all-reduce in XLA). No max
    exchange, no rescale — Eq. 4's outer accumulation as a collective.
  * **sync (baseline)** — ``pmax(m)`` then rescale then psum: the
    synchronized update of Eq. 2 as a collective; one extra all-reduce
    plus a rescale multiply on every shard, every token, every layer.

The per-shard math runs the Pallas decode kernel on TPU
(a ``backend="pallas"`` plan) or the jnp oracle on CPU. The GSPMD-automatic path
(ops.attention_decode + sharding constraints) compiles to the same
schedule; this explicit version is the auditable artifact and the unit
of the attention hillclimb in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import SoftmaxPhiConfig
from repro.core import softmax as smx


def _local_partial_async(q, k_loc, v_loc, start, lengths, phi, scale):
    """One shard's (num, den, max_centered) over its KV slice.

    q: (B, HQ, D); k_loc/v_loc: (B, S_loc, HK, D); start: scalar global
    offset of this shard's slice; lengths: (B,).
    """
    b, hq, d = q.shape
    s_loc, hk = k_loc.shape[1], k_loc.shape[2]
    groups = hq // hk
    kf = jnp.repeat(k_loc, groups, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_loc, groups, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bhd,bkhd->bhk", qf, kf)
    pos = start + jnp.arange(s_loc)
    valid = pos[None, None, :] < lengths[:, None, None]
    return smx.async_partial(
        s, vf.swapaxes(1, 2), phi, valid=valid)


def _local_partial_sync(q, k_loc, v_loc, start, lengths, scale):
    b, hq, d = q.shape
    s_loc, hk = k_loc.shape[1], k_loc.shape[2]
    groups = hq // hk
    kf = jnp.repeat(k_loc, groups, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_loc, groups, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bhd,bkhd->bhk", qf, kf)
    pos = start + jnp.arange(s_loc)
    valid = pos[None, None, :] < lengths[:, None, None]
    return smx.sync_partial(s, vf.swapaxes(1, 2), valid=valid)


def decode_attention_sharded(
    mesh: Mesh,
    q: jax.Array,          # (B, HQ, D)
    k_cache: jax.Array,    # (B, S, HK, D)
    v_cache: jax.Array,
    lengths: jax.Array,    # (B,)
    *,
    phi_cfg: SoftmaxPhiConfig = SoftmaxPhiConfig(),
    scheme: str = "unified_max",
    scale: Optional[float] = None,
    model_axis: str = "model",
    batch_axes: tuple = ("data",),
) -> jax.Array:
    """Split-KV decode attention over the ``model`` mesh axis.

    ``scheme`` mirrors the plan's ``attention_decode.scheme`` knob: the
    async T1 combine needs both an active φ config and a
    ``"unified_max"`` request; either veto runs the sync baseline.
    """
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s_global = k_cache.shape[1]
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis]
    assert s_global % tp == 0, (s_global, tp)
    s_loc = s_global // tp

    use_async = phi_cfg.active and scheme == "unified_max"

    def body(q_l, k_l, v_l, len_l):
        idx = jax.lax.axis_index(model_axis)
        start = idx * s_loc
        if use_async:
            part = _local_partial_async(
                q_l, k_l, v_l, start, len_l, phi_cfg.phi, scale)
            out, _mc = smx.combine_async_collective(part, model_axis)
        else:
            part = _local_partial_sync(q_l, k_l, v_l, start, len_l, scale)
            out = smx.combine_sync_collective(part, model_axis)
        return out.astype(q_l.dtype)

    bspec = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(bspec, model_axis, None, None),
            P(bspec, model_axis, None, None),
            P(bspec),
        ),
        out_specs=P(bspec, None, None),
        axis_names={model_axis, *batch_axes},
    )
    return fn(q, k_cache, v_cache, lengths)


def make_decode_attention_fn(mesh, rules, phi_cfg,
                             scheme: str = "unified_max"):
    """Adapter producing a ``LayerCtx.decode_attention_fn``; pass the
    plan's ``attention_decode.scheme`` so the override honors it."""
    return functools.partial(
        decode_attention_sharded, mesh,
        phi_cfg=phi_cfg,
        scheme=scheme,
        model_axis=rules.model_axis,
        batch_axes=tuple(rules.act_batch_axes),
    )
