"""φ calibration — choosing the unified max value per model (paper Fig. 5).

The paper picks φ from the empirical distribution of pre-softmax logits
(``x_i``) of each model: Llama2-7B logits concentrate in a narrow band, so a
static φ plus a safety band ``[a, b]`` covers >99.99 % of rows; OPT-6.7B's
range is too wide and the technique is disabled for it.

We reproduce that workflow:
  * :class:`LogitStats` — streaming min/max/mean/var/quantile-ish stats
    accumulated over calibration batches (a pure-JAX ``collect`` update).
  * :func:`calibrate` — turns stats into a :class:`SoftmaxPhiConfig`;
    disables T1 when the observed range exceeds what one exp band can hold
    (the OPT case).
  * per-arch defaults in :data:`PHI_REGISTRY` — attention logits for
    RoPE-scaled trained transformers land in a small band around 0; archs we
    cannot calibrate here get a conservative φ=0 with a wide f32-safe band.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import SoftmaxPhiConfig

# exp() in f32 is finite below ~88.7; keep headroom for the Σ over kv_len
# (long_500k: ln(2^19) ≈ 13.2) and for bf16 intermediates.
F32_EXP_SAFE = 80.0


@dataclasses.dataclass
class LogitStats:
    count: int = 0
    minimum: float = float("inf")
    maximum: float = float("-inf")
    mean: float = 0.0
    m2: float = 0.0  # Welford

    def update(self, x: jax.Array) -> "LogitStats":
        x = jnp.asarray(x, jnp.float32).ravel()
        n = int(x.size)
        if n == 0:
            return self
        mn = float(jnp.min(x))
        mx = float(jnp.max(x))
        mu = float(jnp.mean(x))
        var = float(jnp.var(x))
        # Chan parallel-variance merge
        tot = self.count + n
        delta = mu - self.mean
        new_mean = self.mean + delta * n / tot if tot else mu
        new_m2 = self.m2 + var * n + delta**2 * self.count * n / tot
        return LogitStats(
            count=tot,
            minimum=min(self.minimum, mn),
            maximum=max(self.maximum, mx),
            mean=new_mean,
            m2=new_m2,
        )

    @property
    def std(self) -> float:
        return (self.m2 / self.count) ** 0.5 if self.count else 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "LogitStats":
        return LogitStats(**json.loads(s))


def calibrate(
    stats: LogitStats,
    *,
    sigma: float = 6.0,
    safe: float = F32_EXP_SAFE,
) -> SoftmaxPhiConfig:
    """Derive (φ, band) from calibration stats, or disable T1 (the OPT case).

    φ is centered on the observed mean; the band is ``±max(sigma·std,
    observed range)`` with margin. If that band cannot fit inside the
    f32-safe exponent range, the unified-max technique is disabled and the
    engine falls back to the synchronized scheme everywhere — exactly what
    the paper does for OPT-6.7B.
    """
    if stats.count == 0:
        return SoftmaxPhiConfig(phi=0.0, band=(-safe, safe), enabled=True)
    phi = stats.mean
    half = max(sigma * stats.std, stats.maximum - phi, phi - stats.minimum)
    half *= 1.25  # margin
    if half > safe:
        return SoftmaxPhiConfig(phi=None, band=(-safe, safe), enabled=False)
    # keep a wide-but-safe band: false fallbacks are cheap, overflow is not
    half = max(half, 8.0)
    return SoftmaxPhiConfig(phi=float(phi), band=(-float(half), float(half)))


def collect_attention_logit_stats(
    q: jax.Array, k: jax.Array, *, scale: Optional[float] = None,
    stats: Optional[LogitStats] = None,
) -> LogitStats:
    """Accumulate stats over one batch of attention logits (calibration).

    q: (..., S, HQ, D); k: (..., S, HK, D) — GQA-aware (kv heads repeated).
    """
    d = q.shape[-1]
    groups = q.shape[-2] // k.shape[-2]
    if groups > 1:
        k = jnp.repeat(k, groups, axis=-2)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("...qhd,...khd->...hqk", q * scale, k)
    return (stats or LogitStats()).update(s)


# Per-arch defaults. Trained-transformer attention logits sit in a narrow
# band; without real weights we ship the conservative φ=0 wide band (still
# fully exercising the async dataflow) and the calibration tool for refining
# on-device. ``None`` φ = T1 disabled (paper's OPT case).
PHI_REGISTRY: dict[str, SoftmaxPhiConfig] = {
    "default": SoftmaxPhiConfig(phi=0.0, band=(-F32_EXP_SAFE, F32_EXP_SAFE)),
    "llama2-7b": SoftmaxPhiConfig(phi=0.0, band=(-16.0, 16.0)),
    "opt-6.7b": SoftmaxPhiConfig(phi=None, enabled=False),
}


def phi_for(arch: str) -> SoftmaxPhiConfig:
    return PHI_REGISTRY.get(arch, PHI_REGISTRY["default"])
