"""T3 — Heuristic dataflow cost models and decision flows (paper §5).

The paper's observation: a transformer has only four GEMM ``[K, N]`` shapes
(QKV, O, FFN-up, FFN-down; MoE adds the per-expert pair), and only ``M``
varies at runtime (batch·tokens). So an *offline* profile over M per [K, N]
finds two inflection points

    M < M₁            → ImplA  (VPU GEMV — CUDA-core/FastGEMV analogue)
    M₁ ≤ M < M₂       → ImplB  (Pallas flat GEMM, minimal M-padding — T2)
    M₂ ≤ M            → ImplC  (XLA dot_general — cuBLAS/CUTLASS analogue)

and the runtime consults a zero-overhead lookup. This module holds the
*decision machinery*: the per-impl cost models (:func:`predict_time` for
GEMM, :func:`predict_decode_time` for the decode-attention KV grid), the
sweep flows (:func:`find_inflections`, :func:`find_block_k`,
:func:`find_chunk_threshold`), and the measurement backends. The tuned
decisions themselves live in :class:`repro.core.plan.ExecutionPlan` —
build one with :func:`repro.core.plan.tune`, which drives every flow here
and serializes the result with provenance.

Profiling backend: on a real TPU, pass ``measure="wallclock"`` to
``plan.tune`` and the GEMM inflection points come from timings. In this
CPU-only container the default backend is the analytical v5e roofline model
below — the decision *structure* is identical and unit-tested for the
invariants the paper relies on (piecewise dominance, monotone crossover).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterable

from repro import hardware
from repro.config import ModelConfig


class Impl(enum.Enum):
    GEMV = "ImplA"        # VPU broadcast-multiply-reduce
    FLAT_GEMM = "ImplB"   # Pallas minimal-pad MXU kernel
    XLA_DOT = "ImplC"     # XLA/Mosaic generic dot


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One [K, N] workload; ``count`` = occurrences per layer."""

    name: str
    k: int
    n: int
    count: int = 1


def model_gemm_shapes(cfg: ModelConfig) -> list[GemmShape]:
    """The paper's 'only four [K,N] shapes' — extracted per architecture."""
    d = cfg.d_model
    shapes = [
        GemmShape("qkv_proj", d, cfg.q_dim + 2 * cfg.kv_dim),
        GemmShape("o_proj", cfg.q_dim, d),
    ]
    gates = 2 if cfg.activation in ("swiglu", "geglu") else 1
    if cfg.family == "moe" and cfg.moe is not None:
        shapes += [
            GemmShape("router", d, cfg.moe.num_experts),
            GemmShape("expert_up", d, gates * cfg.d_ff, cfg.moe.num_experts),
            GemmShape("expert_down", cfg.d_ff, d, cfg.moe.num_experts),
        ]
    else:
        shapes += [
            GemmShape("ffn_up", d, gates * cfg.d_ff),
            GemmShape("ffn_down", cfg.d_ff, d),
        ]
    if cfg.family == "ssm":
        shapes += [GemmShape("rkvg_proj", d, 4 * d)]
    shapes += [GemmShape("lm_head", d, cfg.vocab_size)]
    return shapes


# ---------------------------------------------------------------------------
# Analytical cost model (v5e). All times in seconds for one GEMM call.
# ---------------------------------------------------------------------------

# one Mosaic pipeline fill: the fixed bubble any extra Pallas kernel stage
# pays before its grid streams at full rate (the ImplB GEMM model's
# launch constant — shared so every "extra kernel launch" term in this
# module prices launches identically)
_PIPELINE_FILL_S = 2e-6

# bytes per stored KV element by ``PagedPlan.kv_dtype`` — every KV-stream
# roofline term below scales by this, which is how quantized pages shift
# the fused/group/swap inflections (smaller pages, cheaper reads).
# Quantized pools also carry one f32 scale per (page, kv head);
# :func:`kv_page_bytes` accounts those exactly, the stream terms fold
# them in as negligible (4 bytes vs page_size*head_dim codes).
KV_DTYPE_BYTES = {"bf16": 2.0, "int8": 1.0, "fp8": 1.0}

# bytes per stored GEMM weight element by ``MatmulPlan.weight_dtype`` —
# the weight-stream twin of :data:`KV_DTYPE_BYTES`. Decode-phase GEMMs
# are flat (M = batch) and memory-bound on the K×N weight read, so this
# factor scales the dominant term of every decode roofline; quantized
# weights also carry one f32 scale per output channel, which
# :func:`param_bytes` and :func:`predict_flat_gemm_time` account exactly.
WEIGHT_DTYPE_BYTES = {"bf16": 2.0, "int8": 1.0, "fp8": 1.0}

# dtype-derived logits-closeness tolerance per weight_dtype — the
# plain-number mirror of ``repro.kernels.quant.logits_guard_tol`` over
# ``quant.spec_for`` (this module stays jax-free; a tier-1 test asserts
# the two stay in sync). ``"bf16"`` is the bitwise path: zero budget.
WEIGHT_GUARD_TOL = {
    "bf16": 0.0,
    "int8": 64 * (0.5 / 127.0),
    "fp8": 64 * 2.0 ** -4,
}


def _mem_time(m_eff: int, k: int, n: int, dtype_bytes: int,
              spec: hardware.HardwareSpec) -> float:
    """HBM traffic with the *effective* (padded) M — a padded layout reads
    and writes the padding too, which is exactly the paper's >50 %
    under-utilization argument restated as memory traffic."""
    bytes_moved = (m_eff * k + k * n + m_eff * n) * dtype_bytes
    return bytes_moved / spec.hbm_bw


def predict_time(
    impl: Impl, m: int, k: int, n: int, *,
    dtype_bytes: int = 2,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline-style time estimate for one (M,K,N) GEMM per implementation.

    The models encode the paper's Eq. 5 structure on TPU terms:
      * ImplA: VPU math, no M padding at all — wins only while the workload
        is so flat that HBM traffic dominates even the slow VPU.
      * ImplB: MXU with M padded to the 8-sublane atom ("pad to 8 not 64");
        both compute and traffic use M_pad=⌈M/8⌉·8. Mosaic's pipeline
        double-buffers the K stream, so overhead is one fill bubble, not
        per-tile.
      * ImplC: XLA's generic layout tiles M to 128; compute *and traffic*
        pay ⌈M/128⌉·128 — unbeatable once M fills the tile, >90 % wasted
        at M=8 (the paper's cuBLAS 'pad to 64' criticism, TPU version).
    """
    if impl is Impl.GEMV:
        mem = _mem_time(m, k, n, dtype_bytes, spec)
        compute = 2.0 * m * k * n / spec.peak_flops_vpu_f32
        return max(mem, compute)
    if impl is Impl.FLAT_GEMM:
        m_pad = max(8, -(-m // 8) * 8)
        mem = _mem_time(m_pad, k, n, dtype_bytes, spec)
        compute = 2.0 * m_pad * k * n / spec.peak_flops_bf16
        return max(mem, compute) + _PIPELINE_FILL_S
    if impl is Impl.XLA_DOT:
        m_pad = max(128, -(-m // 128) * 128)
        mem = _mem_time(m_pad, k, n, dtype_bytes, spec)
        compute = 2.0 * m_pad * k * n / spec.peak_flops_bf16
        return max(mem, compute) + 1e-6   # mature-library epilogue edge
    raise ValueError(impl)


def predict_flat_gemm_time(
    m: int, k: int, n: int, *,
    weight_dtype: str = "bf16",
    dtype_bytes: int = 2,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """ImplB roofline with the weight stream priced at its stored width.

    Equal to ``predict_time(Impl.FLAT_GEMM, ...)`` at
    ``weight_dtype="bf16"``. Quantized dtypes shrink only the K×N weight
    term (:data:`WEIGHT_DTYPE_BYTES`) and add the (N,) f32
    per-output-channel scale read — exactly the operands the quantized
    kernel streams; the activation read and output write keep
    ``dtype_bytes``. The compute term is unchanged: dequant rides the
    existing f32 accumulator epilogue and the codes feed the MXU at the
    activation dtype.
    """
    wb = WEIGHT_DTYPE_BYTES[weight_dtype]
    m_pad = max(8, -(-m // 8) * 8)
    scale_bytes = 0 if weight_dtype == "bf16" else n * 4
    bytes_moved = ((m_pad * k + m_pad * n) * dtype_bytes
                   + k * n * wb + scale_bytes)
    mem = bytes_moved / spec.hbm_bw
    compute = 2.0 * m_pad * k * n / spec.peak_flops_bf16
    return max(mem, compute) + _PIPELINE_FILL_S


MeasureFn = Callable[[Impl, int, int, int], float]


def wallclock_measure_factory(dtype="bfloat16", *, warmup: int = 3,
                              iters: int = 10) -> MeasureFn:
    """Real-hardware timing hook (used when running on an actual TPU).

    Discipline: independent PRNG keys for the two operands (a shared key
    would correlate x and w and flatter the reduction), ``warmup``
    post-compile calls to settle caches/autotuning, then ``iters`` timed
    dispatches each blocked to completion — timing N async dispatches
    against one trailing ``block_until_ready`` would measure queue depth,
    not kernel time.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import flat_gemm as fg
    from repro.kernels import gemv as gv

    def measure(impl: Impl, m: int, k: int, n: int) -> float:
        kx, kw = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(kx, (m, k), dtype=dtype)
        w = jax.random.normal(kw, (k, n), dtype=dtype)
        if impl is Impl.GEMV:
            f = jax.jit(lambda a, b: gv.gemv(a, b))
        elif impl is Impl.FLAT_GEMM:
            f = jax.jit(lambda a, b: fg.flat_gemm(a, b))
        else:
            f = jax.jit(lambda a, b: jnp.dot(a, b))
        f(x, w).block_until_ready()  # compile
        for _ in range(warmup):
            f(x, w).block_until_ready()
        total = 0.0
        for _ in range(iters):
            t0 = time.perf_counter()
            f(x, w).block_until_ready()
            total += time.perf_counter() - t0
        return total / iters

    return measure


# ---------------------------------------------------------------------------
# Offline decision flows (paper Fig. 9(b)) → plan entries
# ---------------------------------------------------------------------------

M_SWEEP = (1, 2, 4, 8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512, 1024)


def pick_impl(m: int, m1: int, m2: int) -> Impl:
    """The piecewise routing ladder every GEMM decision reduces to."""
    if m < m1:
        return Impl.GEMV
    if m < m2:
        return Impl.FLAT_GEMM
    return Impl.XLA_DOT


@dataclasses.dataclass(frozen=True)
class DispatchEntry:
    """One tuned [K, N] inflection record (a matmul-plan entry)."""

    k: int
    n: int
    m1: int  # first M where ImplB beats ImplA
    m2: int  # first M where ImplC beats ImplB

    def pick(self, m: int) -> Impl:
        return pick_impl(m, self.m1, self.m2)


def find_inflections(
    k: int, n: int, *,
    measure: MeasureFn | None = None,
    m_sweep: Iterable[int] = M_SWEEP,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> DispatchEntry:
    """The paper's decision flow: sweep M, find M₁ (A→B) and M₂ (B→C)."""
    measure = measure or (
        lambda impl, m, kk, nn: predict_time(impl, m, kk, nn, spec=spec)
    )
    ms = sorted(m_sweep)
    m1 = ms[-1] + 1
    m2 = ms[-1] + 1
    found1 = False
    for m in ms:
        ta = measure(Impl.GEMV, m, k, n)
        tb = measure(Impl.FLAT_GEMM, m, k, n)
        tc = measure(Impl.XLA_DOT, m, k, n)
        if not found1 and tb < ta:
            m1, found1 = m, True
        if found1 and tc < tb:
            m2 = m
            break
    return DispatchEntry(k=k, n=n, m1=m1, m2=max(m2, m1))


# ---------------------------------------------------------------------------
# Decode-attention block_k decision flow (find_inflections beyond GEMM)
# ---------------------------------------------------------------------------

BLOCK_K_CANDIDATES = (128, 256, 512, 1024, 2048)

# per-grid-step issue/bookkeeping bubble of the decode kernel's KV loop
_GRID_STEP_OVERHEAD_S = 5e-7


def predict_decode_time(
    block_k: int, s: int, kv_dim: int, *,
    dtype_bytes: int = 2,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time for one decode-attention call at KV length ``s``.

    The grid loops over ``ceil(s / block_k)`` KV tiles; each tile streams
    K and V rows (padding included — a tile past ``s`` still DMAs), so a
    large ``block_k`` amortizes per-step overhead but pays padded traffic
    when ``s`` is short, and is capped by the double-buffered VMEM claim.
    """
    steps = -(-s // block_k)
    rows = steps * block_k
    mem = 2 * rows * kv_dim * dtype_bytes / spec.hbm_bw      # K + V streams
    vmem = 2 * 2 * block_k * kv_dim * dtype_bytes            # dbl-buffered K+V
    if vmem > spec.vmem_bytes // 2:
        return float("inf")
    return mem + steps * _GRID_STEP_OVERHEAD_S


def find_block_k(
    s: int, kv_dim: int, *,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    candidates: Iterable[int] = BLOCK_K_CANDIDATES,
) -> int:
    """Pick the decode KV grid block for a representative KV length."""
    best, best_t = None, float("inf")
    for bk in sorted(candidates):
        t = predict_decode_time(bk, s, kv_dim, spec=spec)
        if t < best_t:
            best, best_t = bk, t
    if best is None:
        raise ValueError(f"no feasible block_k among {tuple(candidates)}")
    return best


# ---------------------------------------------------------------------------
# Prefill chunking-threshold decision flow
# ---------------------------------------------------------------------------

CHUNK_THRESHOLD_CANDIDATES = (1024, 2048, 4096, 8192, 16384, 32768)


def find_chunk_threshold(
    num_heads: int, *,
    dtype_bytes: int = 4,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    budget_frac: float = 0.25,
) -> int:
    """Largest sequence length whose materialized per-sequence (H, S, S)
    f32 score tensor still fits a ``budget_frac`` slice of HBM; beyond it
    the blockwise T1 scheme must take over (live memory ≈ (H, Bq, S))."""
    budget = spec.hbm_bytes * budget_frac
    best = CHUNK_THRESHOLD_CANDIDATES[0]
    for s in CHUNK_THRESHOLD_CANDIDATES:
        if num_heads * s * s * dtype_bytes <= budget:
            best = s
    return best


# ---------------------------------------------------------------------------
# Paged chunked-prefill decision flows: dense-gather vs fused chunk kernel
# (find_inflections for the admission path)
# ---------------------------------------------------------------------------

CHUNK_BLOCK_CANDIDATES = (8, 16, 32, 64, 128, 256)

# per-admission-chunk-step dispatch/bookkeeping bubble (one jitted model
# call per chunk step — host sample + device dispatch)
_CHUNK_STEP_OVERHEAD_S = 2e-5


def predict_chunk_prefill_time(
    mode: str, prompt_len: int, table_positions: int, kv_dim: int, *,
    chunk: int = 64,
    page_size: int = 64,
    dtype_bytes: int = 2,
    kv_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time for the *KV side* of one whole chunked-prefill
    admission of a ``prompt_len`` prompt (the q-side GEMMs are identical
    across modes and cancel out of the decision).

    ``mode="dense"`` gathers the full ``(table_positions,)`` KV view per
    chunk step per K/V: each step reads the pool pages, writes the dense
    view, and reads it back for attention — 3x the table bytes, every
    step, regardless of how little of the table is resident. Under a
    quantized ``kv_dtype`` only the pool read shrinks; the materialized
    view is dequantized, so its write + readback stay full-precision —
    which is why quantization pushes the fused inflection *down*.

    ``mode="fused"`` reads only the pages covering ``resident + chunk``
    in place (scalar-prefetched block tables, no materialization, dequant
    in-kernel — all traffic at ``kv_dtype`` width), paying a per-page
    grid-step bubble instead — the Kernel Looping trade.
    """
    kvb = KV_DTYPE_BYTES[kv_dtype]
    steps = max(-(-prompt_len // chunk), 1)
    if mode == "dense":
        # K + V: pool read (stored width) + dense-view write + attention
        # read (dequantized width), per step
        bytes_per_step = (2 * table_positions * kv_dim
                          * (kvb + 2 * dtype_bytes))
        return steps * (bytes_per_step / spec.hbm_bw
                        + _CHUNK_STEP_OVERHEAD_S)
    if mode == "fused":
        total = 0.0
        for i in range(steps):
            resident = min((i + 1) * chunk, prompt_len)
            pages = -(-resident // page_size)
            bytes_step = 2 * pages * page_size * kv_dim * kvb
            total += (bytes_step / spec.hbm_bw
                      + pages * _GRID_STEP_OVERHEAD_S
                      + _CHUNK_STEP_OVERHEAD_S)
        return total
    raise ValueError(f"unknown chunk mode {mode!r}")


def find_fused_threshold(
    max_seq: int, kv_dim: int, *,
    chunk: int = 64,
    page_size: int = 64,
    kv_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> int:
    """Smallest prompt length at which the fused chunk path beats the
    dense gather (table provisioned at ``max_seq``); prompts below it keep
    the one-compile full-table gather. Returns ``max_seq + 1`` when the
    gather never loses (tiny tables). Quantized ``kv_dtype`` lowers the
    inflection: the fused path's traffic is all stored-width while the
    dense gather still pays full-precision view bytes."""
    p = chunk
    while p <= max_seq:
        t_dense = predict_chunk_prefill_time(
            "dense", p, max_seq, kv_dim, chunk=chunk, page_size=page_size,
            kv_dtype=kv_dtype, spec=spec)
        t_fused = predict_chunk_prefill_time(
            "fused", p, max_seq, kv_dim, chunk=chunk, page_size=page_size,
            kv_dtype=kv_dtype, spec=spec)
        if t_fused < t_dense:
            return p
        p *= 2
    return max_seq + 1


def find_chunk_block(
    max_seq: int, kv_dim: int, *,
    page_size: int = 64,
    kv_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    candidates: Iterable[int] = CHUNK_BLOCK_CANDIDATES,
) -> int:
    """Pick the prefill chunk size for the fused path at a representative
    (``max_seq``-long) admission: large chunks amortize the per-step
    dispatch bubble, small chunks keep early pages from re-streaming.
    Only sizes that divide the page size are eligible — prefix sharing
    needs every chunk boundary on the share-less page grid
    (``page_size % prefill_chunk == 0``, enforced by the engine)."""
    best, best_t = None, float("inf")
    for c in sorted(candidates):
        if page_size % c:
            continue
        t = predict_chunk_prefill_time(
            "fused", max_seq, max_seq, kv_dim, chunk=c,
            page_size=page_size, kv_dtype=kv_dtype, spec=spec)
        if t < best_t:
            best, best_t = c, t
    if best is None:
        raise ValueError(
            f"no chunk size among {tuple(candidates)} sits on the "
            f"page grid (page_size {page_size})")
    return best


# ---------------------------------------------------------------------------
# Grouped-decode decision flow: per-row prefix reads vs one read per group
# (find_inflections for the shared-prefix decode path)
# ---------------------------------------------------------------------------

def group_stage_overhead(
    spec: hardware.HardwareSpec = hardware.DEFAULT, *,
    batch: int = 8, q_heads: int = 16, head_dim: int = 128,
) -> float:
    """Fixed cost of the extra grouped-attention stage per decode step,
    derived from the same calibration path as the GEMM cost model rather
    than guessed: one extra kernel launch (the ImplB pipeline-fill
    constant — stage 1 is a second Pallas dispatch the ungrouped path
    does not pay) plus the HBM round-trip of the merge partials the
    split introduces (stage 1 writes, stage 2 reads, one
    ``(batch, q_heads, head_dim + 2)`` f32 record per row — accumulator
    plus the unified-max merge's running (max, sum) pair)."""
    partial_bytes = batch * q_heads * (head_dim + 2) * 4
    return _PIPELINE_FILL_S + 2 * partial_bytes / spec.hbm_bw


# evaluated once at the defaults the group-threshold sweep targets
# (steady decode: full slot batch, qwen2-class head shape)
_GROUP_STAGE_OVERHEAD_S = group_stage_overhead(hardware.DEFAULT)


def predict_group_decode_time(
    mode: str, members: int, prefix_pages: int, tail_pages: int,
    kv_dim: int, *,
    page_size: int = 64,
    dtype_bytes: int = 2,
    kv_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time for the KV side of one decode step over one
    shared-prefix group (the q-side work is identical across modes and
    cancels out of the decision). ``kv_dtype`` scales the page bytes both
    modes stream (both read stored-width pages and dequantize
    in-register), so quantization shrinks the absolute gap but leaves
    the fixed stage bubble — grouped needs more members/pages to win.

    ``mode="off"`` streams every member's full table: each of the
    ``members`` rows re-reads the ``prefix_pages`` it shares plus its own
    ``tail_pages``.

    ``mode="grouped"`` reads the shared prefix **once** (stage 1,
    one pass per group) and only the private tails per member (stage 2),
    paying the extra stage's fixed launch/merge bubble — the
    FlashDecoding++ unified-max merge is what makes the split free of a
    per-member rescale pass.
    """
    del dtype_bytes  # superseded by the kv_dtype stored-width scaling
    page_bytes = 2 * page_size * kv_dim * KV_DTYPE_BYTES[kv_dtype]  # K + V
    if mode == "off":
        pages = members * (prefix_pages + tail_pages)
        return (pages * page_bytes / spec.hbm_bw
                + pages * _GRID_STEP_OVERHEAD_S)
    if mode == "grouped":
        pages = prefix_pages + members * tail_pages
        return (pages * page_bytes / spec.hbm_bw
                + pages * _GRID_STEP_OVERHEAD_S
                + _GROUP_STAGE_OVERHEAD_S)
    raise ValueError(f"unknown group mode {mode!r}")


def find_group_threshold(
    kv_dim: int, *,
    page_size: int = 64,
    max_members: int = 64,
    max_prefix_pages: int = 64,
    tail_pages: int = 1,
    kv_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> int:
    """Smallest ``members * prefix_pages`` product at which grouped
    decode beats per-row prefix re-reads — the dispatch floor the slot
    manager's group plan applies per group each tick. Sweeps the
    (members, prefix pages) grid at one private tail page (the
    steady-decode shape); returns a sentinel above the sweep when the
    grouped path never wins (stage bubble dominates tiny pools)."""
    best = None
    for members in range(2, max_members + 1):
        pages = 1
        while pages <= max_prefix_pages:
            t_off = predict_group_decode_time(
                "off", members, pages, tail_pages, kv_dim,
                page_size=page_size, kv_dtype=kv_dtype, spec=spec)
            t_grp = predict_group_decode_time(
                "grouped", members, pages, tail_pages, kv_dim,
                page_size=page_size, kv_dtype=kv_dtype, spec=spec)
            if t_grp < t_off:
                work = members * pages
                if best is None or work < best:
                    best = work
            pages *= 2
    return best if best is not None else max_members * max_prefix_pages + 1


# ---------------------------------------------------------------------------
# Tiered-KV swap decision flow: promote demoted pages vs re-prefill them
# (find_inflections for the session-cache re-admission path)
# ---------------------------------------------------------------------------

# per-batch host-transfer setup: DMA programming + the host-side sync the
# engine's bulk gather/scatter pays once per promotion/demotion batch,
# matching the per-model-call dispatch bubble of the chunk loop (both are
# one host→device round trip of control)
_HOST_COPY_LATENCY_S = _CHUNK_STEP_OVERHEAD_S


def kv_page_bytes(cfg: ModelConfig, *, page_size: int = 64,
                  dtype_bytes: int = 2, kv_dtype: str = "bf16") -> int:
    """Bytes one KV page moves across the host link: K + V for every
    layer (the page id is shared across layers, so a demotion/promotion
    always moves the whole per-layer stack). Quantized dtypes store
    codes at stored width plus one f32 scale per (page, kv head, layer,
    K/V) — the exact slab a tier demotion carries."""
    del dtype_bytes  # superseded by the kv_dtype stored-width scaling
    kvb = KV_DTYPE_BYTES[kv_dtype]
    scale = 0 if kv_dtype == "bf16" else cfg.num_kv_heads * 4
    return int(2 * cfg.num_layers
               * (page_size * cfg.kv_dim * kvb + scale))


def param_bytes(cfg: ModelConfig, weight_dtype: str = "bf16", *,
                dtype_bytes: int = 2) -> int:
    """Resident bytes of the model's per-layer GEMM weight stream at a
    storage precision — the weight-side analog of :func:`kv_page_bytes`.

    Sums every per-layer [K, N] shape across the layer stack: codes at
    stored width (:data:`WEIGHT_DTYPE_BYTES`) plus the (N,) f32
    per-output-channel scales when quantized. ``lm_head`` (and the tied
    embedding) is excluded — it is not a per-layer stream and never
    quantizes — so this is both the resident GEMM weight footprint and
    the exact bytes one decode tick reads (every granularity streams each
    layer's weights once per tick).
    """
    wb = WEIGHT_DTYPE_BYTES[weight_dtype]
    total = 0.0
    for gs in model_gemm_shapes(cfg):
        if gs.name == "lm_head":
            continue
        if weight_dtype == "bf16":
            per = gs.k * gs.n * dtype_bytes
        else:
            per = gs.k * gs.n * wb + gs.n * 4
        total += per * gs.count
    return int(total * cfg.num_layers)


def predict_swap_time(
    pages: int, page_bytes: int, *,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time to promote ``pages`` demoted KV pages back to the
    device pool: one bulk host→device copy over the PCIe-class link plus
    the fixed per-batch setup."""
    return _HOST_COPY_LATENCY_S + pages * page_bytes / spec.host_bw


def predict_reprefill_time(
    cfg: ModelConfig, positions: int, *,
    chunk: int = 64,
    page_size: int = 64,
    dtype_bytes: int = 2,
    kv_dtype: str = "bf16",
    weight_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time to *recompute* ``positions`` KV positions through
    the chunked-prefill path — the cost a re-admission pays for every
    span whose pages were not (or could not be) promoted.

    Sums the model's GEMM work per chunk step (best implementation per
    [K, N] shape, per layer; lm_head once per step) with the fused-path
    attention KV streaming per layer and the per-step dispatch bubble —
    the same per-term constants every other flow in this module uses, so
    the swap decision is commensurable with the chunk/group decisions.
    Under a quantized ``weight_dtype`` the per-layer GEMMs stream the
    smaller stored-width weights (:func:`predict_flat_gemm_time`; the
    lm_head stays bf16), so recompute gets cheaper and swapping needs a
    longer span to win.
    """
    steps = max(-(-positions // chunk), 1)
    gemm_step = 0.0
    for gs in model_gemm_shapes(cfg):
        if weight_dtype != "bf16" and gs.name != "lm_head":
            t = predict_flat_gemm_time(
                chunk, gs.k, gs.n, weight_dtype=weight_dtype,
                dtype_bytes=dtype_bytes, spec=spec)
        else:
            t = min(predict_time(impl, chunk, gs.k, gs.n,
                                 dtype_bytes=dtype_bytes, spec=spec)
                    for impl in Impl)
        layers = 1 if gs.name == "lm_head" else cfg.num_layers
        gemm_step += t * gs.count * layers
    kv = 0.0
    for i in range(steps):
        resident = min((i + 1) * chunk, positions)
        pages = -(-resident // page_size)
        kv += (2 * pages * page_size * cfg.kv_dim
               * KV_DTYPE_BYTES[kv_dtype]
               / spec.hbm_bw + pages * _GRID_STEP_OVERHEAD_S)
    return (steps * gemm_step + cfg.num_layers * kv
            + steps * _CHUNK_STEP_OVERHEAD_S)


def find_swap_threshold(
    cfg: ModelConfig, *,
    chunk: int = 64,
    page_size: int = 64,
    max_pages: int = 64,
    kv_dtype: str = "bf16",
    weight_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> int:
    """Smallest demoted-span page count at which promoting (bulk
    host→device copy) beats re-prefilling the same span — the
    per-admission decision the slot manager applies to a prefix match
    that extends into the tiered store (``PagedPlan.swap_threshold``).
    Re-prefill cost grows superlinearly (attention re-streams resident
    KV per chunk step) while the copy is linear, so the first crossover
    is the inflection. Returns ``max_pages + 1`` when the copy never
    wins inside the sweep (tiny models on a fat link the other way).
    Quantized ``kv_dtype`` moves *both* sides (smaller slabs over the
    link, cheaper KV re-streaming) but the link side scales fully while
    re-prefill keeps its bf16 GEMM term, so swapping wins earlier; a
    quantized ``weight_dtype`` pushes the other way (recompute streams
    the smaller weight slab, so re-prefill gets cheaper)."""
    page_bytes = kv_page_bytes(cfg, page_size=page_size, kv_dtype=kv_dtype)
    for pages in range(1, max_pages + 1):
        t_swap = predict_swap_time(pages, page_bytes, spec=spec)
        t_pre = predict_reprefill_time(
            cfg, pages * page_size, chunk=chunk, page_size=page_size,
            kv_dtype=kv_dtype, weight_dtype=weight_dtype, spec=spec)
        if t_swap < t_pre:
            return pages
    return max_pages + 1


# ---------------------------------------------------------------------------
# Decode-fusion granularity (DecodeFusionPlan.granularity)
# ---------------------------------------------------------------------------

# per-layer stage dispatches in one decode tick. The split chain is the
# full op list (norm, 3 QKV GEMMs, bias, 2 ropes, 2 KV scatters,
# attention, o_proj, residual, mlp norm, gate/up GEMMs, activation,
# down GEMM, residual); fusing the ingest seam (norm+QKV+rope), the
# attention epilogue (o_proj+residual), the mlp ingest
# (norm+gate/up+activation) and the down-projection epilogue
# (down+residual) collapses it to: ingest, 2 scatters, attention,
# epilogue, ffn_norm, down-epilogue.
_DECODE_STAGES = {"split": 16, "fused": 7, "looped": 7}

# one-time cost of entering the scan'd (looped) depth dispatch: the
# while-loop's condition/carry plumbing, priced like one chunk-step
# dispatch bubble
_LOOP_SETUP_S = 2e-5

# host-visible dispatch cost per stage when the layer loop is python-
# unrolled: every traced stage is its own XLA computation boundary the
# host runtime walks, vs. the scan'd path's single looped dispatch
_HOST_DISPATCH_S = 1e-6


def predict_fusion_time(
    cfg: ModelConfig, granularity: str, *,
    m: int = 1,
    dtype_bytes: int = 2,
    weight_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> float:
    """Roofline time for one decode tick at a fusion granularity.

    Decode at small batch is memory-bound: every tick streams each
    layer's weights once regardless of granularity
    (:func:`param_bytes` at the plan's ``weight_dtype`` — quantized
    weights shrink the common term, so the fixed boundary costs weigh
    relatively more), and the granularities differ only in *boundary*
    cost — stage-dispatch bubbles per layer (:data:`_DECODE_STAGES`,
    priced at the shared :data:`_PIPELINE_FILL_S` launch constant), plus
    the host-side term: ``fused`` python-unrolls the depth (L × stages
    host-visible dispatches), while ``split``/``looped`` run the whole
    depth under one ``lax.scan`` (one looped dispatch + a fixed
    :data:`_LOOP_SETUP_S`).
    """
    if granularity not in _DECODE_STAGES:
        raise ValueError(f"unknown fusion granularity {granularity!r}")
    weight_bytes = param_bytes(
        cfg, weight_dtype, dtype_bytes=dtype_bytes) / cfg.num_layers
    stages = _DECODE_STAGES[granularity]
    t_layer = weight_bytes / spec.hbm_bw + stages * _PIPELINE_FILL_S
    if granularity == "fused":
        return cfg.num_layers * (t_layer + stages * _HOST_DISPATCH_S)
    return cfg.num_layers * t_layer + _LOOP_SETUP_S


def find_decode_fusion(
    cfg: ModelConfig, *,
    m: int = 1,
    weight_dtype: str = "bf16",
    spec: hardware.HardwareSpec = hardware.DEFAULT,
) -> str:
    """Cheapest decode-tick granularity for this model (ties break toward
    the earlier, simpler mode in ``FUSION_MODES`` order: split < fused <
    looped)."""
    modes = ("split", "fused", "looped")
    times = {g: predict_fusion_time(cfg, g, m=m, weight_dtype=weight_dtype,
                                    spec=spec) for g in modes}
    return min(modes, key=lambda g: times[g])


# ---------------------------------------------------------------------------
# Weight-precision decision flow (MatmulPlan.weight_dtype)
# ---------------------------------------------------------------------------

WEIGHT_DTYPE_CANDIDATES = ("bf16", "int8", "fp8")


def find_weight_dtype(
    cfg: ModelConfig, *,
    m: int = 1,
    tol_budget: float | None = None,
    spec: hardware.HardwareSpec = hardware.DEFAULT,
    candidates: Iterable[str] = WEIGHT_DTYPE_CANDIDATES,
) -> str:
    """Fastest GEMM weight storage precision under the accuracy guard.

    Candidates whose dtype-derived logits tolerance
    (:data:`WEIGHT_GUARD_TOL`) exceeds ``tol_budget`` are excluded
    (``None`` = any tolerance; ``0.0`` admits only the bitwise bf16
    path). Survivors are ranked by one decode tick's flat-GEMM roofline
    summed over the model's [K, N] shapes at decode M
    (:func:`predict_flat_gemm_time`; the lm_head prices at bf16 — it
    never quantizes). Decode is weight-bandwidth-bound, so the smaller
    stream wins whenever it is admissible; the strict ``<`` keeps int8
    ahead of fp8 on their byte-for-byte tie (same stored width, tighter
    analytic round-trip bound).
    """
    best, best_t = "bf16", None
    for wd in candidates:
        if wd not in WEIGHT_DTYPE_BYTES:
            raise ValueError(f"unknown weight_dtype {wd!r}")
        if tol_budget is not None and WEIGHT_GUARD_TOL[wd] > tol_budget:
            continue
        t = 0.0
        for gs in model_gemm_shapes(cfg):
            shape_wd = "bf16" if gs.name == "lm_head" else wd
            layers = 1 if gs.name == "lm_head" else cfg.num_layers
            t += (predict_flat_gemm_time(
                      m, gs.k, gs.n, weight_dtype=shape_wd, spec=spec)
                  * gs.count * layers)
        if best_t is None or t < best_t:
            best, best_t = wd, t
    return best
