"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

T1 applicability note (DESIGN.md §4): RWKV has **no softmax attention**, so
the paper's unified-max softmax does not apply — the arch is implemented
without it (per the assignment) while T2/T3 fully apply to its projections
(decode-phase RWKV is the flattest-GEMM regime of all the assigned archs).

TPU-native formulation: training/prefill use a **chunked-parallel** scheme —
within a chunk the recurrence is expanded into dense einsums (MXU-friendly),
across chunks the state is propagated with ``jax.lax.associative_scan``
(log-depth, *flat HLO*: no sequential while loop, so XLA cost analysis and
the dry-run probes see every FLOP). Decode is the O(1) recurrence step.

Per head (head size N), with data-dependent decay w_t ∈ (0,1)^N and bonus u:

    S_t = diag(w_t) · S_{t-1} + k_t vᵗ_t
    o_t = r_tᵀ · (S_{t-1} + diag(u) k_t vᵗ_t)

Chunk algebra (cumulative log-decay ``la_t = Σ_{s≤t} log w_s``):
    o_t   = (r_t e^{la_{t-1}}) · S_0  +  Σ_{s<t} (r_t·k_s e^{la_{t-1}-la_s}) v_s
            + (r_t·u·k_t) v_t
    S_end = diag(e^{la_L}) S_0 + Σ_s (k_s e^{la_L - la_s}) vᵗ_s

All exponents in the S_end/inter terms are ≤ 0 (safe); the intra-chunk
``e^{la_{t-1}-la_s}`` (s<t ⇒ ≤0) is factored as e^{la_{t-1}}·e^{-la_s} with a
clamp at ±30 — exact for the calibrated decay range (|log w| ≤ ~0.1/token,
chunk=64), see DESIGN.md §8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.kvlayout import require_dense
from repro.models.layers import LayerCtx, Params

CHUNK = 64
_CLAMP = 30.0


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    n = cfg.ssm.head_dim if cfg.ssm else 64
    return cfg.d_model // n, n


def layer_params(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    h, n = _heads(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    lora = 64
    return {
        "tm_norm": L.norm_params(cfg, d),
        "tm": {
            "mu_r": jnp.full((d,), 0.5, dt),
            "mu_k": jnp.full((d,), 0.5, dt),
            "mu_v": jnp.full((d,), 0.5, dt),
            "mu_g": jnp.full((d,), 0.5, dt),
            "mu_w": jnp.full((d,), 0.5, dt),
            "w_r": L.dense_init(ks[0], (d, d), dt),
            "w_k": L.dense_init(ks[1], (d, d), dt),
            "w_v": L.dense_init(ks[2], (d, d), dt),
            "w_g": L.dense_init(ks[3], (d, d), dt),
            "w_o": L.dense_init(ks[4], (d, d), dt),
            # data-dependent decay lora (Finch signature): w = exp(-exp(·))
            "decay_base": jnp.full((d,), -6.0, jnp.float32),
            "decay_A": L.dense_init(ks[5], (d, lora), jnp.float32),
            "decay_B": (jax.random.normal(ks[6], (lora, d)) * 0.01).astype(
                jnp.float32),
            "bonus_u": jnp.zeros((h, n), jnp.float32),
            "ln_out": jnp.ones((d,), dt),
        },
        "cm_norm": L.norm_params(cfg, d),
        "cm": {
            "mu_k": jnp.full((d,), 0.5, dt),
            "mu_r": jnp.full((d,), 0.5, dt),
            "w_k": L.dense_init(ks[7], (d, cfg.d_ff), dt),
            "w_v": L.dense_init(ks[0], (cfg.d_ff, d), dt),
            "w_r": L.dense_init(ks[1], (d, d), dt),
        },
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.num_layers)
    stacked = jax.vmap(lambda k: layer_params(cfg, k))(lkeys)
    return {
        **L.embed_params(cfg, ke),
        "layers": stacked,
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Time mixing — chunked parallel (train/prefill)
# ---------------------------------------------------------------------------


def _shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Previous-token features; ``last`` seeds position 0 (decode cache)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _decay_logw(tm: Params, xw: jax.Array) -> jax.Array:
    """log w_t ∈ (-inf, 0): data-dependent per-channel decay."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ tm["decay_A"]) @ tm["decay_B"]
    return -jnp.exp(tm["decay_base"] + lo)  # log w = -exp(·) < 0


def time_mix_chunked(
    ctx: LayerCtx, tm: Params, x: jax.Array,
    state0: jax.Array | None = None, last_x: jax.Array | None = None,
    *, return_state: bool = False, valid: jax.Array | None = None,
):
    """x: (B, T, D). Returns out (+ final state, last x).

    ``valid``: (B, T) bool — invalid (padding) positions neither decay nor
    write the state, so per-row prompt lengths produce exact states.
    T is padded internally to a CHUNK multiple.
    """
    cfg = ctx.cfg
    h, n = _heads(cfg)
    b, t_in, d = x.shape
    pad_t = (-t_in) % min(CHUNK, max(t_in, 1))
    if pad_t:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
        if valid is None:
            valid = jnp.arange(t_in + pad_t)[None, :] < t_in
        else:
            valid = jnp.pad(valid, ((0, 0), (0, pad_t)))
    b, t, d = x.shape
    xx = _shift(x, last_x)

    def lerp(mu):
        return x + (xx - x) * mu

    r = ctx.matmul(lerp(tm["mu_r"]), tm["w_r"])
    k = ctx.matmul(lerp(tm["mu_k"]), tm["w_k"])
    v = ctx.matmul(lerp(tm["mu_v"]), tm["w_v"])
    gate = jax.nn.silu(ctx.matmul(lerp(tm["mu_g"]), tm["w_g"]))
    logw = _decay_logw(tm, lerp(tm["mu_w"]))                  # (B,T,D) f32
    if valid is not None:
        vm = valid[..., None]
        k = jnp.where(vm, k, 0)        # no state write at padding
        logw = jnp.where(vm, logw, 0)  # no decay at padding

    c = min(CHUNK, t)
    assert t % c == 0
    nc = t // c
    shape = (b, nc, c, h, n)
    rr = r.reshape(shape).astype(jnp.float32)
    kk = k.reshape(shape).astype(jnp.float32)
    vv = v.reshape(shape).astype(jnp.float32)
    lw = logw.reshape(shape)

    la = jnp.cumsum(lw, axis=2)                                # (B,NC,C,H,N)
    la_prev = la - lw
    la_end = la[:, :, -1:]                                     # (B,NC,1,H,N)

    # ---- per-chunk summaries for the cross-chunk associative scan ----
    dec = jnp.exp(la_end[:, :, 0])                             # (B,NC,H,N)
    kd = kk * jnp.exp(la_end - la)                             # ≤ 0 exps
    u_mat = jnp.einsum("bcthn,bcthm->bchnm", kd, vv)           # (B,NC,H,N,N)

    def combine(a, b_):
        d1, u1 = a
        d2, u2 = b_
        return d1 * d2, u2 + d2[..., None] * u1

    dec_s, u_s = jax.lax.associative_scan(combine, (dec, u_mat), axis=1)
    # state at chunk START j: S_j = dec/u up to chunk j-1 applied to state0
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    s_end = dec_s[..., None] * state0[:, None] + u_s           # (B,NC,H,N,N)
    s_start = jnp.concatenate([state0[:, None], s_end[:, :-1]], axis=1)

    # ---- within-chunk ----
    q_t = rr * jnp.exp(la_prev)                                # safe: ≤0
    inter = jnp.einsum("bcthn,bchnm->bcthm", q_t, s_start)
    k_neg = kk * jnp.exp(jnp.clip(-la, -_CLAMP, _CLAMP))
    scores = jnp.einsum("bcthn,bcshn->bchts", q_t, k_neg)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    intra = jnp.einsum("bchts,bcshn->bcthn", scores, vv)
    diag = jnp.einsum(
        "bcthn,hn,bcthn->bcth", rr, tm["bonus_u"], kk
    )[..., None] * vv
    out = inter + intra + diag                                 # (B,NC,C,H,N)

    out = out.reshape(b, t, d)
    out = _headnorm(out, tm["ln_out"], h, n).astype(x.dtype) * gate
    out = ctx.matmul(out, tm["w_o"])[:, :t_in]
    if return_state:
        return out, s_end[:, -1], x[:, t_in - 1]
    return out


def _headnorm(x: jax.Array, scale: jax.Array, h: int, n: int) -> jax.Array:
    b, t, d = x.shape
    xh = x.reshape(b, t, h, n).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(b, t, d) * scale.astype(jnp.float32))


def time_mix_step(ctx: LayerCtx, tm: Params, x: jax.Array,
                  state: jax.Array, last_x: jax.Array):
    """One-token recurrence. x: (B, D); state: (B,H,N,N); last_x: (B,D)."""
    cfg = ctx.cfg
    h, n = _heads(cfg)
    b, d = x.shape

    def lerp(mu):
        return x + (last_x - x) * mu

    r = ctx.matmul(lerp(tm["mu_r"]), tm["w_r"]).astype(jnp.float32)
    k = ctx.matmul(lerp(tm["mu_k"]), tm["w_k"]).astype(jnp.float32)
    v = ctx.matmul(lerp(tm["mu_v"]), tm["w_v"]).astype(jnp.float32)
    gate = jax.nn.silu(ctx.matmul(lerp(tm["mu_g"]), tm["w_g"]))
    logw = _decay_logw(tm, lerp(tm["mu_w"]))                  # (B,D)

    rr = r.reshape(b, h, n)
    kk = k.reshape(b, h, n)
    vv = v.reshape(b, h, n)
    w = jnp.exp(logw).reshape(b, h, n)

    kv = jnp.einsum("bhn,bhm->bhnm", kk, vv)
    att = state + tm["bonus_u"][None, :, :, None] * kv
    o = jnp.einsum("bhn,bhnm->bhm", rr, att).reshape(b, d)
    new_state = w[..., None] * state + kv

    o = _headnorm(o[:, None], tm["ln_out"], h, n)[:, 0].astype(x.dtype) * gate
    return ctx.matmul(o, tm["w_o"]), new_state, x


# ---------------------------------------------------------------------------
# Channel mixing
# ---------------------------------------------------------------------------


def channel_mix(ctx: LayerCtx, cm: Params, x: jax.Array,
                last_x: jax.Array | None = None):
    xx = _shift(x, last_x) if x.ndim == 3 else last_x
    xk = x + (xx - x) * cm["mu_k"]
    xr = x + (xx - x) * cm["mu_r"]
    k = ctx.matmul(xk, cm["w_k"])
    k = ctx.shard(k, "act_ffn") if x.ndim == 3 else k
    k = jnp.square(jax.nn.relu(k))
    out = ctx.matmul(k, cm["w_v"])
    return out * jax.nn.sigmoid(ctx.matmul(xr, cm["w_r"]))


# ---------------------------------------------------------------------------
# Blocks / model API
# ---------------------------------------------------------------------------


def block(ctx: LayerCtx, p: Params, x: jax.Array, positions=None):
    cfg = ctx.cfg
    h = L.norm(cfg, p["tm_norm"], x)
    x = x + time_mix_chunked(ctx, p["tm"], h)
    x = ctx.shard(x, "act_resid")
    h = L.norm(cfg, p["cm_norm"], x)
    x = x + channel_mix(ctx, p["cm"], h)
    return ctx.shard(x, "act_resid"), jnp.zeros((), jnp.float32)


def train_loss(ctx: LayerCtx, params: Params, batch: dict, *,
               unroll: bool = False, remat: bool = True):
    from repro.models import transformer as tfm
    return tfm.train_loss(
        ctx, params, batch, unroll=unroll, remat=remat, block_fn=block
    )


def init_cache(cfg: ModelConfig, layout, dtype=None):
    batch = require_dense(layout, cfg.family).num_slots
    h, n = _heads(cfg)  # O(1) state regardless of max_seq — long_500k story
    return {
        "state": jnp.zeros((cfg.num_layers, batch, h, n, n), jnp.float32),
        "tm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model),
                          jnp.dtype(cfg.activation_dtype)),
        "cm_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model),
                          jnp.dtype(cfg.activation_dtype)),
    }


def cache_spec(cfg: ModelConfig, layout, dtype=None):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, layout)),
    )


def prefill(ctx: LayerCtx, params: Params, tokens, lengths, cache, *,
            unroll: bool = False, **kw):
    """Chunked-parallel prompt processing; emits the recurrent state cache.

    Per-row ragged prompts are exact: positions >= lengths are masked in
    the recurrence (no state write, no decay), and the shift features for
    the next decode step are gathered at each row's own last position.
    """
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens)
    b, t, _ = x.shape
    valid = jnp.arange(t)[None, :] < lengths[:, None]

    def last_tok(h):
        return jnp.take_along_axis(
            h, (lengths - 1)[:, None, None].clip(0), axis=1)[:, 0]

    def blk(p_i, xx):
        h = L.norm(cfg, p_i["tm_norm"], xx)
        tm_out, s_end, _ = time_mix_chunked(
            ctx, p_i["tm"], h, return_state=True, valid=valid
        )
        xx = xx + tm_out
        h2 = L.norm(cfg, p_i["cm_norm"], xx)
        xx = xx + channel_mix(ctx, p_i["cm"], h2)
        return ctx.shard(xx, "act_resid"), {
            "state": s_end, "tm_x": last_tok(h), "cm_x": last_tok(h2)
        }

    x, entries = stack.run_stack_collect(
        params["layers"], x, blk, unroll=unroll
    )
    x = L.norm(cfg, params["final_norm"], x)
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None].clip(0), 1)
    logits = L.lm_logits(ctx, params, last)[:, 0]
    return logits, entries


def decode_step(ctx: LayerCtx, params: Params, tokens, cache, lengths, *,
                block_tables=None, positions=None, unroll: bool = False):
    # `positions` is accepted for the uniform engine operand; recurrent
    # state has no rope, the operand is unused
    assert block_tables is None, "ssm state cache has no paged layout"
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens[:, None])[:, 0]  # (B, D)

    def blk(p_i, xx, c_i):
        h = L.norm(cfg, p_i["tm_norm"], xx)
        o, new_state, tm_last = time_mix_step(
            ctx, p_i["tm"], h, c_i["state"], c_i["tm_x"]
        )
        xx = xx + o
        h2 = L.norm(cfg, p_i["cm_norm"], xx)
        xx = xx + channel_mix(ctx, p_i["cm"], h2, last_x=c_i["cm_x"])
        return xx, {"state": new_state, "tm_x": tm_last, "cm_x": h2}

    x, new_cache = stack.run_stack_cached(
        params["layers"], x, cache, blk, unroll=unroll
    )
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(ctx, params, x[:, None])[:, 0]
    return logits, new_cache
