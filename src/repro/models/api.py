"""Uniform model API over all five families, keyed by ``cfg.family``.

Also home of ``input_specs`` — ShapeDtypeStruct stand-ins for every model
input per (arch × shape) cell, as required by the multi-pod dry-run (no
device allocation; weak-type-correct; shardable).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, moe, ssm, transformer
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx

N_IMAGE_TOKENS = 256  # vision stub: patch embeddings prepended (internvl2)


def n_image_tokens(seq_len: int) -> int:
    """Vision-prefix length; clamped so reduced smoke shapes stay valid."""
    return min(N_IMAGE_TOKENS, max(seq_len // 4, 1))


@dataclasses.dataclass(frozen=True)
class ModelApi:
    """One cache-agnostic surface per family.

    Cache construction and the decode/chunk steps are parameterized by a
    :class:`~repro.models.kvlayout.KVLayout` rather than forked into
    ``*_paged`` twins: ``init_cache``/``cache_spec`` take a layout object,
    and ``decode_step``/``prefill_chunk`` take the layout's optional
    ``block_tables`` operand (``None`` = dense slot addressing, an array =
    block-paged addressing). ``supports_paged`` says whether the family's
    KV tensors admit :class:`PagedLayout` at all — recurrent/ring state
    caches (ssm, hybrid, encdec) do not.
    """

    cfg: ModelConfig
    init_params: Callable
    train_loss: Callable          # (ctx, params, batch, *, unroll, remat)
    prefill: Callable             # (ctx, params, tokens, lengths, cache, **)
    decode_step: Callable
    #   (ctx, params, tokens, cache, lengths, *, block_tables=None, **)
    init_cache: Callable          # (layout: KVLayout)
    cache_spec: Callable          # (layout: KVLayout)
    supports_paged: bool = False
    prefill_chunk: Optional[Callable] = None
    #   (ctx, params, tokens, chunk_lens, cache, lengths,
    #    *, block_tables=None, **)

    @property
    def supports_chunked_prefill(self) -> bool:
        return self.prefill_chunk is not None


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "vlm"):
        mod = transformer
    elif cfg.family == "moe":
        mod = moe
    elif cfg.family == "ssm":
        mod = ssm
    elif cfg.family == "hybrid":
        mod = hybrid
    elif cfg.family == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {cfg.family}")

    return ModelApi(
        cfg=cfg,
        init_params=lambda key: mod.init_params(cfg, key),
        train_loss=mod.train_loss,
        prefill=mod.prefill,
        decode_step=mod.decode_step,
        init_cache=lambda layout: mod.init_cache(cfg, layout),
        cache_spec=lambda layout: mod.cache_spec(cfg, layout),
        supports_paged=getattr(mod, "PAGED_KV", False),
        prefill_chunk=getattr(mod, "prefill_chunk", None),
    )


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per (arch × shape) cell
# ---------------------------------------------------------------------------


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.frontend == "vision":
        # stub frontend: precomputed patch embeddings prepended; token count
        # shrinks so the backbone still runs exactly `s` positions.
        npfx = n_image_tokens(s)
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s - npfx), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s - npfx), jnp.int32),
            "prefix_embeds": jax.ShapeDtypeStruct(
                (b, npfx, cfg.d_model), jnp.bfloat16),
        }
    if cfg.family == "encdec":
        # stub conv frontend: precomputed frame embeddings
        batch["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)
    return batch


def serve_decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs for one serve_step: current token, cache, lengths."""
    b, s = shape.global_batch, shape.seq_len
    api = get_model(cfg)
    return {
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": api.cache_spec(DenseLayout(b, s)),
        "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def serve_prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
    if cfg.frontend == "vision":
        npfx = n_image_tokens(s)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - npfx), jnp.int32)
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (b, npfx, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, encdec.ENC_FRAMES_SERVE, cfg.d_model), jnp.bfloat16)
    return specs


def make_synthetic_batch(cfg: ModelConfig, shape_or_specs, key) -> dict:
    """Materialize a random batch matching the spec (for smoke/examples)."""
    if isinstance(shape_or_specs, ShapeConfig):
        specs = train_input_specs(cfg, shape_or_specs)
    else:
        specs = shape_or_specs
    out = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            out[name] = jax.random.randint(
                sub, spec.shape, 0, cfg.vocab_size, dtype=spec.dtype)
        else:
            out[name] = (jax.random.normal(sub, spec.shape) * 0.02).astype(
                spec.dtype)
    return out
