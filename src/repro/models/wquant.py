"""Quantize-at-load pass for GEMM weights — the second precision knob.

The weight-side twin of the kv_dtype subsystem
(:mod:`repro.serving.kvquant`): after PR 8 halved the KV stream, the
decode tick's dominant HBM traffic is the layer weight slab, read once
per tick at M = batch ≤ ~8 (the paper's flat-GEMM regime, where every
GEMM is memory-bound on weight bytes). This module converts each GEMM
weight leaf of a params pytree into int8/fp8 *codes* plus one f32 step
per **output channel**, reusing ``kernels/quant.py``'s
QuantSpec/encode/decode algebra so ``codes * step`` remains THE dequant
expression everywhere:

  * per-output-channel steps: a weight ``(…, K, N)`` is quantized along
    K with one step per N column — ``step[n] = max_k |w[k, n]| / qmax``.
    The step factors out of the GEMM's K sum, so the kernels multiply it
    onto the f32 accumulator once in the epilogue (exactly
    ``decode(codes, step)`` distributed over the reduction) and the bf16
    weight slab never materializes in HBM.
  * a quantized leaf is the dict ``{"codes": (…, K, N) code_dtype,
    "scale": (…, N) f32}`` — a plain pytree node, so the stacked-L
    layer params stack/slice/scan through :mod:`repro.models.stack`'s
    generic ``tree.map`` plumbing unchanged, and the looped decode
    granularity keeps tracing the identical scan-body jaxpr.
  * only the GEMM weight leaves named in :data:`WEIGHT_KEYS` quantize;
    bias, norm, embedding and lm-head leaves stay full precision (they
    are tiny or accuracy-critical — the ``kv_dtype`` design's scale-row
    exemption, applied to the weight side).

``ops.*`` detect the dict form structurally and thread the scales into
the kernels; model call sites never change. The accuracy contract is the
same dtype-derived logits-closeness guard as the KV axis
(``quant.logits_guard_tol``); the bf16 path never sees this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import quant

# GEMM weight leaves of the dense-transformer families (attention + glu
# mlp projections). Leaves with other names — biases, norm scales,
# embedding/lm_head, recurrent/ssm mixers — stay full precision.
WEIGHT_KEYS = frozenset(
    {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"})


def is_quantized_leaf(w) -> bool:
    """True for the ``{"codes", "scale"}`` dict a quantized leaf becomes."""
    return isinstance(w, dict) and "codes" in w and "scale" in w


def quantize_weight(w: jax.Array, spec: quant.QuantSpec) -> dict:
    """One leaf ``(…, K, N)`` -> ``{"codes": (…, K, N), "scale": (…, N)}``.

    The reduction axis is K (the contraction dim), one step per output
    channel: transpose to (…, N, K), reuse the last-axis
    ``compute_step``/``encode`` algebra, transpose the codes back.
    """
    wt = jnp.swapaxes(w, -1, -2)                      # (…, N, K)
    step = quant.compute_step(wt, spec, axes=-1)      # (…, N)
    codes = jnp.swapaxes(quant.encode(wt, step, spec), -1, -2)
    return {"codes": codes, "scale": step.astype(jnp.float32)}


def dequantize_weight(wq: dict) -> jax.Array:
    """``codes * step`` back to a full (…, K, N) f32 weight (tests and
    error-bound probes; the serving path never materializes this)."""
    wt = quant.decode(jnp.swapaxes(wq["codes"], -1, -2), wq["scale"])
    return jnp.swapaxes(wt, -1, -2)


def quantize_params(params: dict, spec: quant.QuantSpec) -> dict:
    """Quantize every :data:`WEIGHT_KEYS` leaf under ``params["layers"]``.

    Returns a new pytree; non-weight leaves (and everything outside the
    layer stack — embedding, lm_head, final_norm) are passed through
    untouched. Stacked leaves ``(L, K, N)`` quantize per (layer, output
    channel) — the leading axes broadcast through the same algebra.
    """
    def walk(tree):
        if isinstance(tree, dict):
            return {
                key: (quantize_weight(v, spec)
                      if key in WEIGHT_KEYS and not isinstance(v, dict)
                      else walk(v))
                for key, v in tree.items()
            }
        return tree

    out = dict(params)
    if "layers" in out:
        out["layers"] = walk(out["layers"])
    return out


def gemm_weight_bytes(params: dict) -> int:
    """True stored bytes of the decode tick's GEMM weight stream: every
    :data:`WEIGHT_KEYS` leaf under ``params["layers"]``, codes *and*
    scales as stored (bf16 leaves at full width). Embedding/lm_head are
    excluded — they are not per-layer streams and never quantize."""
    total = 0

    def walk(tree):
        nonlocal total
        if not isinstance(tree, dict):
            return
        for key, v in tree.items():
            if key in WEIGHT_KEYS:
                if is_quantized_leaf(v):
                    total += v["codes"].nbytes + v["scale"].nbytes
                else:
                    total += v.nbytes
            elif isinstance(v, dict):
                walk(v)

    walk(params.get("layers", {}))
    return total
