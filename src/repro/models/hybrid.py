"""Hymba — hybrid layers with *parallel* attention + SSM heads.

Each layer runs a sliding-window GQA attention path and a Mamba-style SSM
path on the same normed input and sums their projections (the Hymba
parallel-head design). Sub-quadratic end to end: attention cost is O(T·W)
with a ring-buffer KV cache of W entries, the SSM is O(T) with O(1) state —
this is why hymba runs the ``long_500k`` cell.

TPU adaptation notes (DESIGN.md §8): the SSM path uses the Mamba-2/SSD
scalar-per-head decay form (chunked einsums + log-depth associative scan,
flat HLO) rather than Mamba-1's per-channel selective scan; the short
depthwise conv of the reference stack is folded into the token-shift lerp.
T1 applies to the attention heads only (the SSM path has no softmax).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SoftmaxPhiConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.kvlayout import require_dense
from repro.models.layers import LayerCtx, Params
from repro.core import softmax as smx

CHUNK = 64
_CLAMP = 30.0
SSM_HEAD = 64


def _ssm_dims(cfg: ModelConfig):
    inner = cfg.ssm.expand * cfg.d_model if cfg.ssm else 2 * cfg.d_model
    hm = inner // SSM_HEAD
    return inner, hm, cfg.ssm.state_size if cfg.ssm else 16


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    inner, hm, n = _ssm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "norm": L.norm_params(cfg, d),
        "attn": L.attention_params(cfg, ks[0]),
        "ssm": {
            "w_in": L.dense_init(ks[1], (d, inner), dt),
            "w_gate": L.dense_init(ks[2], (d, inner), dt),
            "w_bc": L.dense_init(ks[3], (d, 2 * n), dt),
            "w_dt": L.dense_init(ks[4], (d, hm), dt),
            "a_log": jnp.zeros((hm,), jnp.float32),
            "d_skip": jnp.ones((hm,), jnp.float32),
            "w_out": L.dense_init(ks[5], (inner, d), dt),
        },
        "mlp_norm": L.norm_params(cfg, d),
        "mlp": L.mlp_params(cfg, ks[6]),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    from repro.models import transformer as tfm
    return tfm.init_params(cfg, key, layer_params_fn=layer_params)


# ---------------------------------------------------------------------------
# SSD scalar-decay chunked scan
# ---------------------------------------------------------------------------


def ssm_chunked(ctx: LayerCtx, p: Params, x: jax.Array,
                state0: jax.Array | None = None,
                *, return_state: bool = False,
                valid: jax.Array | None = None):
    """x: (B,T,D) -> (B,T,D). State: (B,HM,P,N).

    ``valid``: (B,T) bool — padding positions have dt=0, which zeroes both
    their state write *and* their decay (SSD decay is a·dt), so per-row
    prompt lengths produce exact states. T padded to a CHUNK multiple.
    """
    cfg = ctx.cfg
    inner, hm, n = _ssm_dims(cfg)
    b, t_in, d = x.shape
    pad_t = (-t_in) % min(CHUNK, max(t_in, 1))
    if pad_t:
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
        if valid is None:
            valid = jnp.arange(t_in + pad_t)[None, :] < t_in
        else:
            valid = jnp.pad(valid, ((0, 0), (0, pad_t)))
    b, t, d = x.shape
    xi = ctx.matmul(x, p["w_in"])
    z = ctx.matmul(x, p["w_gate"])
    bc = ctx.matmul(x, p["w_bc"]).astype(jnp.float32)
    bmat, cmat = bc[..., :n], bc[..., n:]                    # (B,T,N)
    dt_ = jax.nn.softplus(
        ctx.matmul(x, p["w_dt"]).astype(jnp.float32)
    )                                                        # (B,T,HM)
    if valid is not None:
        dt_ = jnp.where(valid[..., None], dt_, 0.0)
    a = -jnp.exp(p["a_log"])                                 # (HM,) < 0
    la_step = a[None, None] * dt_                            # (B,T,HM) ≤ 0

    c = min(CHUNK, t)
    assert t % c == 0
    nc = t // c
    xh = xi.reshape(b, nc, c, hm, SSM_HEAD).astype(jnp.float32)
    bm = bmat.reshape(b, nc, c, n)
    cm = cmat.reshape(b, nc, c, n)
    dtc = dt_.reshape(b, nc, c, hm)
    law = la_step.reshape(b, nc, c, hm)

    la = jnp.cumsum(law, axis=2)                             # (B,NC,C,HM)
    la_end = la[:, :, -1:]

    # chunk summaries
    dec = jnp.exp(la_end[:, :, 0])                           # (B,NC,HM)
    w_in = dtc * jnp.exp(la_end - la)                        # ≤0 exps
    u_mat = jnp.einsum(
        "bcthp,bctn,bcth->bchpn",
        xh, bm, w_in,
    )                                                        # (B,NC,HM,P,N)

    def combine(p1, p2):
        d1, u1 = p1
        d2, u2 = p2
        return d1 * d2, u2 + d2[..., None, None] * u1

    dec_s, u_s = jax.lax.associative_scan(combine, (dec, u_mat), axis=1)
    if state0 is None:
        state0 = jnp.zeros((b, hm, SSM_HEAD, n), jnp.float32)
    s_end = dec_s[..., None, None] * state0[:, None] + u_s
    s_start = jnp.concatenate([state0[:, None], s_end[:, :-1]], axis=1)

    # within chunk (inclusive decay: y_t uses S_t)
    inter = jnp.einsum(
        "bcth,bchpn,bctn->bcthp", jnp.exp(la), s_start, cm
    )
    qk = jnp.einsum("bctn,bcsn->bcts", cm, bm)               # (B,NC,C,C)
    decay_ts = jnp.exp(
        jnp.clip(la[:, :, :, None, :] - la[:, :, None, :, :],
                 -_CLAMP, _CLAMP)
    )                                                        # (B,NC,C,C,HM)
    mask = jnp.tril(jnp.ones((c, c), bool))
    scores = qk[..., None] * decay_ts * mask[None, None, :, :, None]
    intra = jnp.einsum("bctsh,bcsh,bcshp->bcthp", scores, dtc, xh)
    y = inter + intra + p["d_skip"][None, None, None, :, None] * xh

    y = y.reshape(b, t, inner).astype(x.dtype) * jax.nn.silu(z)
    out = ctx.matmul(y, p["w_out"])[:, :t_in]
    if return_state:
        return out, s_end[:, -1]
    return out


def ssm_step(ctx: LayerCtx, p: Params, x: jax.Array, state: jax.Array):
    """One token. x: (B,D); state: (B,HM,P,N)."""
    cfg = ctx.cfg
    inner, hm, n = _ssm_dims(cfg)
    b, d = x.shape
    xi = ctx.matmul(x, p["w_in"]).astype(jnp.float32).reshape(b, hm, SSM_HEAD)
    z = ctx.matmul(x, p["w_gate"])
    bc = ctx.matmul(x, p["w_bc"]).astype(jnp.float32)
    bvec, cvec = bc[..., :n], bc[..., n:]
    dt_ = jax.nn.softplus(ctx.matmul(x, p["w_dt"]).astype(jnp.float32))
    dec = jnp.exp(-jnp.exp(p["a_log"])[None] * dt_)          # (B,HM)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xi, bvec, dt_)
    new_state = dec[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, cvec)
    y = y + p["d_skip"][None, :, None] * xi
    y = y.reshape(b, inner).astype(x.dtype) * jax.nn.silu(z)
    return ctx.matmul(y, p["w_out"]), new_state


# ---------------------------------------------------------------------------
# Ring-buffer sliding-window attention (decode)
# ---------------------------------------------------------------------------


def ring_decode_attention(
    ctx: LayerCtx, qd: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
    lengths: jax.Array,
):
    """qd: (B,HQ,Dh); cache: (B,W,HK,Dh) ring; lengths AFTER current write."""
    cfg = ctx.cfg
    w = cache_k.shape[1]
    hq = qd.shape[1]
    hk = cache_k.shape[2]
    groups = hq // hk
    kf = jnp.repeat(cache_k, groups, axis=2).astype(jnp.float32)
    vf = jnp.repeat(cache_v, groups, axis=2).astype(jnp.float32)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bhd,bkhd->bhk", qd.astype(jnp.float32) * scale, kf)
    slots = jnp.arange(w)[None, None]
    lens = lengths[:, None, None]
    valid = (lens >= w) | (slots < lens)
    phi_cfg = ctx.phi_cfg
    dp = ctx.plan.attention_decode
    if phi_cfg.active and dp.scheme == "unified_max":
        part = smx.async_partial(s, vf.swapaxes(1, 2), phi_cfg.phi, valid)
        out = part.num / part.den[..., None]
        if dp.fallback:
            overflow = jnp.any(part.max_centered > phi_cfg.band[1])
            sync = smx.sync_partial(s, vf.swapaxes(1, 2), valid)
            safe = sync.num / jnp.where(sync.den == 0, 1,
                                        sync.den)[..., None]
            out = jax.lax.cond(overflow, lambda: safe, lambda: out)
    else:
        part = smx.sync_partial(s, vf.swapaxes(1, 2), valid)
        out = part.num / jnp.where(part.den == 0, 1, part.den)[..., None]
    return out.astype(qd.dtype)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block(ctx: LayerCtx, p: Params, x: jax.Array, positions: jax.Array):
    cfg = ctx.cfg
    h = L.norm(cfg, p["norm"], x)
    attn_out = L.attention_block(ctx, p["attn"], h, positions)
    ssm_out = ssm_chunked(ctx, p["ssm"], h)
    x = ctx.shard(x + attn_out + ssm_out, "act_resid")
    h = L.norm(cfg, p["mlp_norm"], x)
    x = x + L.mlp_block(ctx, p["mlp"], h)
    return ctx.shard(x, "act_resid"), jnp.zeros((), jnp.float32)


def train_loss(ctx: LayerCtx, params: Params, batch: dict, *,
               unroll: bool = False, remat: bool = True):
    from repro.models import transformer as tfm
    return tfm.train_loss(
        ctx, params, batch, unroll=unroll, remat=remat, block_fn=block
    )


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, layout, dtype=None):
    layout = require_dense(layout, cfg.family)
    batch, max_seq = layout.num_slots, layout.max_seq
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    inner, hm, n = _ssm_dims(cfg)
    w = min(cfg.sliding_window or 1024, max_seq)
    kv = (cfg.num_layers, batch, w, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(kv, dtype),
        "v": jnp.zeros(kv, dtype),
        "state": jnp.zeros((cfg.num_layers, batch, hm, SSM_HEAD, n),
                           jnp.float32),
    }


def cache_spec(cfg: ModelConfig, layout, dtype=None):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, layout, dtype)),
    )


def _ring_from_prefill(k: jax.Array, lengths: jax.Array, w: int):
    """Per-row ragged ring fill: slot s holds the token at position
    p(s) = (l-1) - ((l-1-s) mod w) — the unique p in [l-w, l) with
    p % w == s; slots with p < 0 (prompt shorter than the window) zero.
    k: (B, T, H, Dh) -> (B, w, H, Dh)."""
    b, t = k.shape[:2]
    s = jnp.arange(w)[None, :]
    l = lengths[:, None]
    p = (l - 1) - ((l - 1 - s) % w)                     # (B, w)
    ok = p >= 0
    idx = jnp.clip(p, 0, t - 1)[..., None, None]
    out = jnp.take_along_axis(k, idx, axis=1)
    return jnp.where(ok[..., None, None], out, 0)


def prefill(ctx: LayerCtx, params: Params, tokens, lengths, cache, *,
            unroll: bool = False, **kw):
    """Prompt pass; fills ring KV (last W *valid* positions, per-row
    ragged) + SSM state (padding positions masked out of the recurrence)."""
    cfg = ctx.cfg
    w = cache["k"].shape[2]
    x = L.embed(ctx, params, tokens)
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    valid = jnp.arange(t)[None, :] < lengths[:, None]

    def blk(p_i, xx):
        h = L.norm(cfg, p_i["norm"], xx)
        q, k, v = L.attention_qkv(ctx, p_i["attn"], h, positions)
        from repro.kernels import ops
        o = ops.attention_prefill(
            q, k, v, phi_cfg=ctx.phi_cfg, causal=True,
            sliding_window=cfg.sliding_window, plan=ctx.plan,
        )
        o = o.reshape(b, t, cfg.q_dim)
        attn_out = ctx.matmul(o, p_i["attn"]["wo"])
        ssm_out, s_end = ssm_chunked(ctx, p_i["ssm"], h, return_state=True,
                                     valid=valid)
        xx = ctx.shard(xx + attn_out + ssm_out, "act_resid")
        h2 = L.norm(cfg, p_i["mlp_norm"], xx)
        xx = xx + L.mlp_block(ctx, p_i["mlp"], h2)
        return ctx.shard(xx, "act_resid"), {
            "k": _ring_from_prefill(k, lengths, w).astype(cache["k"].dtype),
            "v": _ring_from_prefill(v, lengths, w).astype(cache["v"].dtype),
            "state": s_end,
        }

    x, entries = stack.run_stack_collect(params["layers"], x, blk,
                                         unroll=unroll)
    x = L.norm(cfg, params["final_norm"], x)
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None].clip(0), 1)
    logits = L.lm_logits(ctx, params, last)[:, 0]
    return logits, entries


def decode_step(ctx: LayerCtx, params: Params, tokens, cache, lengths, *,
                block_tables=None, positions=None, unroll: bool = False):
    # `positions` is accepted for the uniform engine operand; in this family
    # the write position always equals `lengths`, so the operand is unused
    assert block_tables is None, "ring KV + SSM state has no paged layout"
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens[:, None])  # (B,1,D)
    b = x.shape[0]
    w = cache["k"].shape[2]

    def blk(p_i, xx, c_i):
        h = L.norm(cfg, p_i["norm"], xx)
        q, k, v = L.attention_qkv(ctx, p_i["attn"], h, lengths[:, None])
        slot = lengths % w
        ck = c_i["k"].at[jnp.arange(b), slot].set(
            k[:, 0].astype(c_i["k"].dtype))
        cv = c_i["v"].at[jnp.arange(b), slot].set(
            v[:, 0].astype(c_i["v"].dtype))
        o = ring_decode_attention(ctx, q[:, 0], ck, cv, lengths + 1)
        attn_out = ctx.matmul(o.reshape(b, 1, cfg.q_dim), p_i["attn"]["wo"])
        ssm_out, new_state = ssm_step(ctx, p_i["ssm"], h[:, 0], c_i["state"])
        xx = xx + attn_out + ssm_out[:, None]
        h2 = L.norm(cfg, p_i["mlp_norm"], xx)
        xx = xx + L.mlp_block(ctx, p_i["mlp"], h2)
        return xx, {"k": ck, "v": cv, "state": new_state}

    x, new_cache = stack.run_stack_cached(params["layers"], x, cache, blk,
                                          unroll=unroll)
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(ctx, params, x)[:, 0]
    return logits, new_cache
