"""Whisper-style encoder–decoder backbone (whisper-tiny assignment).

The conv/audio frontend is a **stub** per the assignment: ``input_specs``
provides precomputed frame embeddings (B, S_enc, D). Deviations recorded in
DESIGN.md §8: RoPE instead of learned/sinusoidal positions (backbone spec
only); encoder length is the training seq_len for train cells and the
Whisper-standard 1500 frames for serving cells.

T1 applies to decoder self-attention decode (growing KV) and cross-attention
(static KV); the encoder is a prefill-shaped workload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.kvlayout import require_dense
from repro.models.layers import LayerCtx, Params

ENC_FRAMES_SERVE = 1500  # 30 s of audio at 50 Hz — whisper standard


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def enc_layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_params(cfg, cfg.d_model),
        "attn": L.attention_params(cfg, k1),
        "mlp_norm": L.norm_params(cfg, cfg.d_model),
        "mlp": L.mlp_params(cfg, k2),
    }


def dec_layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": L.norm_params(cfg, cfg.d_model),
        "attn": L.attention_params(cfg, k1),
        "cross_norm": L.norm_params(cfg, cfg.d_model),
        "cross": L.attention_params(cfg, k2),
        "mlp_norm": L.norm_params(cfg, cfg.d_model),
        "mlp": L.mlp_params(cfg, k3),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, k1, k2 = jax.random.split(key, 3)
    n_enc = cfg.encoder_layers or cfg.num_layers
    ekeys = jax.random.split(k1, n_enc)
    dkeys = jax.random.split(k2, cfg.num_layers)
    return {
        **L.embed_params(cfg, ke),
        "enc_layers": jax.vmap(lambda k: enc_layer_params(cfg, k))(ekeys),
        "layers": jax.vmap(lambda k: dec_layer_params(cfg, k))(dkeys),
        "enc_norm": L.norm_params(cfg, cfg.d_model),
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(ctx: LayerCtx, params: Params, frames: jax.Array,
           *, unroll: bool = False) -> jax.Array:
    """frames: (B, S_enc, D) precomputed embeddings (frontend stub)."""
    cfg = ctx.cfg
    x = ctx.shard(frames.astype(jnp.dtype(cfg.activation_dtype)),
                  "act_resid")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def blk(p_i, xx):
        h = L.norm(cfg, p_i["attn_norm"], xx)
        xx = xx + L.attention_block(ctx, p_i["attn"], h, positions,
                                    causal=False)
        h = L.norm(cfg, p_i["mlp_norm"], xx)
        xx = xx + L.mlp_block(ctx, p_i["mlp"], h)
        return ctx.shard(xx, "act_resid"), jnp.zeros((), jnp.float32)

    x, _ = stack.run_stack(params["enc_layers"], x, blk, unroll=unroll)
    return L.norm(cfg, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# Decoder blocks
# ---------------------------------------------------------------------------


def _cross_kv(ctx: LayerCtx, p_cross: Params, enc_out: jax.Array):
    cfg = ctx.cfg
    b, se, _ = enc_out.shape
    k = ctx.matmul(enc_out, p_cross["wk"]).reshape(
        b, se, cfg.num_kv_heads, cfg.head_dim)
    v = ctx.matmul(enc_out, p_cross["wv"]).reshape(
        b, se, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def dec_block(ctx: LayerCtx, p: Params, x: jax.Array, positions: jax.Array,
              enc_out: jax.Array):
    cfg = ctx.cfg
    h = L.norm(cfg, p["attn_norm"], x)
    x = x + L.attention_block(ctx, p["attn"], h, positions)
    h = L.norm(cfg, p["cross_norm"], x)
    ck, cv = _cross_kv(ctx, p["cross"], enc_out)
    x = x + L.attention_block(
        ctx, p["cross"], h, positions, causal=False, use_rope=False,
        kv_override=(ck, cv),
    )
    h = L.norm(cfg, p["mlp_norm"], x)
    x = x + L.mlp_block(ctx, p["mlp"], h)
    return ctx.shard(x, "act_resid"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def train_loss(ctx: LayerCtx, params: Params, batch: dict, *,
               unroll: bool = False, remat: bool = True):
    cfg = ctx.cfg
    enc_out = encode(ctx, params, batch["frames"], unroll=unroll)
    x = L.embed(ctx, params, batch["tokens"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    blk = lambda p_i, xx: dec_block(ctx, p_i, xx, positions, enc_out)
    if remat:
        blk = jax.checkpoint(
            blk, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = stack.run_stack(params["layers"], x, blk, unroll=unroll)
    x = L.norm(cfg, params["final_norm"], x)
    return L.cross_entropy_loss(ctx, params, x, batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, layout, dtype=None,
               enc_len: int = ENC_FRAMES_SERVE):
    layout = require_dense(layout, cfg.family)
    batch, max_seq = layout.num_slots, layout.max_seq
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    lt = cfg.num_layers
    return {
        "k": jnp.zeros((lt, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((lt, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "xk": jnp.zeros((lt, batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                        dtype),
        "xv": jnp.zeros((lt, batch, enc_len, cfg.num_kv_heads, cfg.head_dim),
                        dtype),
    }


def cache_spec(cfg: ModelConfig, layout, dtype=None,
               enc_len: int = ENC_FRAMES_SERVE):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        jax.eval_shape(lambda: init_cache(cfg, layout, dtype, enc_len)),
    )


def prefill(ctx: LayerCtx, params: Params, tokens, lengths, cache, *,
            frames: jax.Array | None = None, unroll: bool = False, **kw):
    """Encode audio, run decoder prompt, fill self- and cross-KV caches."""
    cfg = ctx.cfg
    b, s = tokens.shape
    if frames is None:
        enc_len = cache["xk"].shape[2]
        frames = jnp.zeros((b, enc_len, cfg.d_model),
                           jnp.dtype(cfg.activation_dtype))
    enc_out = encode(ctx, params, frames, unroll=unroll)
    x = L.embed(ctx, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    s_max = cache["k"].shape[2]

    def blk(p_i, xx):
        h = L.norm(cfg, p_i["attn_norm"], xx)
        q, k, v = L.attention_qkv(ctx, p_i["attn"], h, positions)
        from repro.kernels import ops
        o = ops.attention_prefill(
            q, k, v, phi_cfg=ctx.phi_cfg, causal=True,
            plan=ctx.plan,
        ).reshape(b, s, cfg.q_dim)
        xx = xx + ctx.matmul(o, p_i["attn"]["wo"])
        h = L.norm(cfg, p_i["cross_norm"], xx)
        xk, xv = _cross_kv(ctx, p_i["cross"], enc_out)
        xx = xx + L.attention_block(
            ctx, p_i["cross"], h, positions, causal=False, use_rope=False,
            kv_override=(xk, xv),
        )
        h = L.norm(cfg, p_i["mlp_norm"], xx)
        xx = xx + L.mlp_block(ctx, p_i["mlp"], h)
        pad = [(0, 0), (0, s_max - s), (0, 0), (0, 0)]
        entry = {
            "k": jnp.pad(k, pad).astype(cache["k"].dtype),
            "v": jnp.pad(v, pad).astype(cache["v"].dtype),
            "xk": xk.astype(cache["xk"].dtype),
            "xv": xv.astype(cache["xv"].dtype),
        }
        return ctx.shard(xx, "act_resid"), entry

    x, entries = stack.run_stack_collect(params["layers"], x, blk,
                                         unroll=unroll)
    x = L.norm(cfg, params["final_norm"], x)
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None].clip(0), 1)
    logits = L.lm_logits(ctx, params, last)[:, 0]
    return logits, entries


def decode_step(ctx: LayerCtx, params: Params, tokens, cache, lengths, *,
                block_tables=None, positions=None, unroll: bool = False):
    # `positions` is accepted for the uniform engine operand; the decoder
    # write position always equals `lengths`, so the operand is unused
    assert block_tables is None, "enc-dec cross/self cache has no paged layout"
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens[:, None])
    b = x.shape[0]
    enc_len = cache["xk"].shape[2]
    enc_lengths = jnp.full((b,), enc_len, jnp.int32)

    def blk(p_i, xx, c_i):
        h = L.norm(cfg, p_i["attn_norm"], xx)
        a, ck, cv = L.attention_decode_block(
            ctx, p_i["attn"], h, lengths, c_i["k"], c_i["v"], lengths
        )
        xx = xx + a
        # cross attention against the static encoder KV
        h = L.norm(cfg, p_i["cross_norm"], xx)
        q = ctx.matmul(h, p_i["cross"]["wq"]).reshape(
            b, 1, cfg.num_heads, cfg.head_dim)
        from repro.kernels import ops
        o = ops.attention_decode(
            q[:, 0], c_i["xk"], c_i["xv"], enc_lengths,
            phi_cfg=ctx.phi_cfg, plan=ctx.plan,
        )
        xx = xx + ctx.matmul(o.reshape(b, 1, cfg.q_dim), p_i["cross"]["wo"])
        h = L.norm(cfg, p_i["mlp_norm"], xx)
        xx = xx + L.mlp_block(ctx, p_i["mlp"], h)
        return xx, {"k": ck, "v": cv, "xk": c_i["xk"], "xv": c_i["xv"]}

    x, new_cache = stack.run_stack_cached(params["layers"], x, cache, blk,
                                          unroll=unroll)
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(ctx, params, x)[:, 0]
    return logits, new_cache
