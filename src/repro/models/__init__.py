"""Model zoo: dense GQA / MoE / SSM (RWKV6) / hybrid (Hymba) / enc-dec.

All models are pure functions over explicit param pytrees; layer params are
stacked on a leading L axis (scan-over-layers). See :mod:`repro.models.api`
for the uniform entry points and the dry-run ``input_specs``.
"""
from repro.models.api import (  # noqa: F401
    ModelApi,
    get_model,
    make_synthetic_batch,
    serve_decode_input_specs,
    serve_prefill_input_specs,
    train_input_specs,
)
from repro.models.kvlayout import (  # noqa: F401
    DenseLayout,
    KVLayout,
    PagedLayout,
)
