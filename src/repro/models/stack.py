"""Layer-stack plumbing shared by all model families.

Uniform block interfaces:
  * train/prefill-style: ``block_fn(p_i, x) -> (x, aux)`` — aux is a scalar
    (MoE load-balance loss; 0.0 for other families), accumulated across
    layers.
  * cached decode-style: ``block_fn(p_i, x, cache_i) -> (x, new_cache_i)``
    where ``cache_i`` is the per-layer slice of a stacked cache pytree.

``unroll=False`` uses ``lax.scan`` over the stacked-L params (compact HLO —
the only while-loop in the whole program, with a known trip count);
``unroll=True`` emits a flat python loop for the cost-analysis probes.

Everything here is generic ``jax.tree`` plumbing, which is what lets the
quantized-weight representation ride through untouched: a GEMM leaf that
``models/wquant.py`` turned into a ``{"codes", "scale"}`` dict is just
two stacked leaves ``(L, K, N)`` / ``(L, N)`` to stack/unstack/scan, so
the looped decode granularity traces the identical scan-body jaxpr
whether the params are bf16 arrays or (codes, scale) pairs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def num_layers_of(layers_params) -> int:
    return jax.tree_util.tree_leaves(layers_params)[0].shape[0]


def unstack(tree):
    """Stacked-L pytree -> list of L per-layer pytrees.

    ``a[i]`` slicing only — no copy under jit, bitwise round-trip with
    :func:`restack` (the decode-fusion unrolled path relies on this:
    per-layer slabs must hold exactly the scanned values).
    """
    return [jax.tree.map(lambda a: a[i], tree)
            for i in range(num_layers_of(tree))]


def restack(trees):
    """List of L per-layer pytrees -> stacked-L pytree (``jnp.stack``
    per leaf). Inverse of :func:`unstack`, bitwise."""
    return jax.tree.map(lambda *a: jnp.stack(a), *trees)


def run_stack(layers_params, x, block_fn: Callable, *, unroll: bool = False):
    """Returns (x, total_aux)."""
    if unroll:
        aux = jnp.zeros((), jnp.float32)
        for p_i in unstack(layers_params):
            x, a = block_fn(p_i, x)
            aux = aux + a
        return x, aux

    def layer_scan_body(carry, p_i):
        x, aux = carry
        x, a = block_fn(p_i, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        layer_scan_body, (x, jnp.zeros((), jnp.float32)), layers_params
    )
    return x, aux


def run_stack_collect(layers_params, x, block_fn: Callable,
                      *, unroll: bool = False):
    """Like run_stack but blocks return (x, per_layer_output) and the
    per-layer outputs are stacked (used by prefill to build the KV cache)."""
    if unroll:
        outs = []
        for p_i in unstack(layers_params):
            x, o = block_fn(p_i, x)
            outs.append(o)
        return x, restack(outs)

    def layer_scan_body(carry, p_i):
        x, o = block_fn(p_i, carry)
        return x, o

    return jax.lax.scan(layer_scan_body, x, layers_params)


def run_stack_cached(layers_params, x, cache, block_fn: Callable,
                     *, unroll: bool = False):
    """Returns (x, new_cache) — cache leaves have leading L axis."""
    if unroll:
        news = []
        for p_i, c_i in zip(unstack(layers_params), unstack(cache)):
            x, c_new = block_fn(p_i, x, c_i)
            news.append(c_new)
        return x, restack(news)

    def layer_scan_body(carry, xs):
        p_i, c_i = xs
        x, c_new = block_fn(p_i, carry, c_i)
        return x, c_new

    return jax.lax.scan(layer_scan_body, x, (layers_params, cache))
