"""Dense GQA decoder-only transformer (qwen2 / minitron / deepseek / phi3
families) plus the VLM variant (internvl2 backbone with stub vision prefix).

Layer params are stacked on a leading L axis; ``lax.scan`` keeps the HLO
compact for 95-layer dry-run compiles, ``unroll=True`` flattens for the
cost-analysis probes. The MoE model reuses this module's plumbing with its
own block functions (see :mod:`repro.models.moe`).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import stack
from repro.models.kvlayout import KVLayout
from repro.models.layers import LayerCtx, Params

# dense-KV family: the (L, B, S, HK, Dh) cache admits the block-paged
# storage discipline (PagedLayout + block tables)
PAGED_KV = True


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_params(cfg, cfg.d_model),
        "attn": L.attention_params(cfg, k1),
        "mlp_norm": L.norm_params(cfg, cfg.d_model),
        "mlp": L.mlp_params(cfg, k2),
    }


def init_params(cfg: ModelConfig, key,
                layer_params_fn: Callable = None) -> Params:
    lp = layer_params_fn or layer_params
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.num_layers)
    stacked = jax.vmap(lambda k: lp(cfg, k))(lkeys)
    return {
        **L.embed_params(cfg, ke),
        "layers": stacked,
        "final_norm": L.norm_params(cfg, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Blocks (dense). MoE swaps the mlp half.
# ---------------------------------------------------------------------------


def block(ctx: LayerCtx, p: Params, x: jax.Array,
          positions: jax.Array):
    cfg = ctx.cfg
    h = L.norm(cfg, p["attn_norm"], x)
    x = x + L.attention_block(ctx, p["attn"], h, positions)
    x = ctx.shard(x, "act_resid")
    h = L.norm(cfg, p["mlp_norm"], x)
    x = x + L.mlp_block(ctx, p["mlp"], h)
    return ctx.shard(x, "act_resid"), jnp.zeros((), jnp.float32)


def decode_block(ctx: LayerCtx, p: Params, x: jax.Array, position: jax.Array,
                 cache_i: dict, lengths: jax.Array,
                 block_tables: Optional[jax.Array] = None,
                 decode_groups=None):
    """One-token decode block over either KV layout.

    ``block_tables is None`` means the per-layer cache slice is a dense
    (B, S, HK, Dh) slot cache; with tables it is the shared (NP, PS, HK,
    Dh) page pool, addressed through the (B, NB) logical→physical map.
    The discriminator is resolved at trace time — each engine layout
    compiles exactly one path. ``decode_groups`` (paged only) switches to
    the prefix-shared grouped attention path.

    The layer runs as three explicit stage boundaries (ingest → attend →
    epilogue, see :mod:`repro.models.layers`); the plan's
    ``decode_fusion`` granularity decides whether the ingest and
    epilogue seams are fused dispatches or the split op chain.
    """
    q, k, v = L.decode_ingest(ctx, p["attn_norm"], p["attn"], x, position)
    if block_tables is None:
        o, ck, cv = L.decode_attend(
            ctx, q, k, v, cache_i["k"], cache_i["v"], lengths
        )
        new_cache = {"k": ck, "v": cv}
    else:
        o, ck, cv, ks, vs = L.decode_attend_paged(
            ctx, q, k, v, cache_i["k"], cache_i["v"],
            block_tables, lengths, decode_groups=decode_groups,
            k_scale=cache_i.get("k_scale"), v_scale=cache_i.get("v_scale"),
        )
        new_cache = {"k": ck, "v": cv}
        if ks is not None:   # quantized layout: scale pools ride along
            new_cache["k_scale"] = ks
            new_cache["v_scale"] = vs
    x = L.decode_epilogue(ctx, p["attn"], o, x)
    x = L.decode_mlp(ctx, p["mlp_norm"], p["mlp"], x)
    return ctx.shard(x, "act_resid"), new_cache


def chunk_block(ctx: LayerCtx, p: Params, x: jax.Array, cache_i: dict,
                lengths: jax.Array, chunk_lens: jax.Array,
                block_tables: Optional[jax.Array] = None):
    """Chunked-prefill block (decode-shaped path) over either KV layout."""
    cfg = ctx.cfg
    h = L.norm(cfg, p["attn_norm"], x)
    if block_tables is None:
        a, ck, cv = L.attention_chunk_block(
            ctx, p["attn"], h, cache_i["k"], cache_i["v"], lengths,
            chunk_lens
        )
        new_cache = {"k": ck, "v": cv}
    else:
        a, ck, cv, ks, vs = L.attention_chunk_block_paged(
            ctx, p["attn"], h, cache_i["k"], cache_i["v"], block_tables,
            lengths, chunk_lens,
            k_scale=cache_i.get("k_scale"), v_scale=cache_i.get("v_scale"),
        )
        new_cache = {"k": ck, "v": cv}
        if ks is not None:
            new_cache["k_scale"] = ks
            new_cache["v_scale"] = vs
    x = x + a
    h = L.norm(cfg, p["mlp_norm"], x)
    x = x + L.mlp_block(ctx, p["mlp"], h)
    return ctx.shard(x, "act_resid"), new_cache


def prefill_block(ctx: LayerCtx, p: Params, x: jax.Array,
                  positions: jax.Array, s_max: int):
    """Like ``block`` but also emits this layer's (padded) KV cache entry."""
    cfg = ctx.cfg
    b, s, _ = x.shape
    h = L.norm(cfg, p["attn_norm"], x)
    q, k, v = L.attention_qkv(ctx, p["attn"], h, positions)
    from repro.kernels import ops
    o = ops.attention_prefill(
        q, k, v, phi_cfg=ctx.phi_cfg, causal=True,
        sliding_window=cfg.sliding_window, plan=ctx.plan,
    )
    o = ctx.shard(o.reshape(b, s, cfg.q_dim), "act_attn_out")
    x = x + ctx.matmul(o, p["attn"]["wo"])
    h = L.norm(cfg, p["mlp_norm"], x)
    x = x + L.mlp_block(ctx, p["mlp"], h)
    pad = [(0, 0), (0, s_max - s), (0, 0), (0, 0)]
    entry = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    return ctx.shard(x, "act_resid"), entry


# ---------------------------------------------------------------------------
# Forward passes (parameterized over block fns so MoE can reuse them)
# ---------------------------------------------------------------------------


def _maybe_remat(fn, remat: bool):
    if not remat:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def forward_hidden(
    ctx: LayerCtx, params: Params, tokens: jax.Array,
    *, prefix_embeds: Optional[jax.Array] = None,
    unroll: bool = False, remat: bool = False,
    block_fn: Callable = block,
):
    """Token (+ optional embedding prefix) -> (hidden (B,S,D), aux loss)."""
    x = L.embed(ctx, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        x = ctx.shard(x, "act_resid")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    body = _maybe_remat(
        lambda p_i, xx: block_fn(ctx, p_i, xx, positions), remat
    )
    x, aux = stack.run_stack(params["layers"], x, body, unroll=unroll)
    return L.norm(ctx.cfg, params["final_norm"], x), aux


def train_loss(
    ctx: LayerCtx, params: Params, batch: dict,
    *, unroll: bool = False, remat: bool = True,
    block_fn: Callable = block, aux_weight: float = 0.0,
) -> jax.Array:
    x, aux = forward_hidden(
        ctx, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        unroll=unroll, remat=remat, block_fn=block_fn,
    )
    if batch.get("prefix_embeds") is not None:
        npfx = batch["prefix_embeds"].shape[1]
        x = x[:, npfx:]
    loss = L.cross_entropy_loss(ctx, params, x, batch["labels"])
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


def _cache_shapes(cfg: ModelConfig, layout: KVLayout, dtype=None):
    """(pool shape, pool dtype, scale shape or None) for a layout.

    Quantized paged layouts (``layout.kv_dtype`` != bf16) store code pools
    in the spec's code dtype plus per-(layer, page, kv head) f32 step
    pools as extra ``k_scale``/``v_scale`` leaves."""
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    shape = layout.kv_shape(cfg.num_layers, cfg.num_kv_heads, cfg.head_dim)
    kv_dtype = getattr(layout, "kv_dtype", "bf16")
    if kv_dtype == "bf16":
        return shape, dtype, None
    from repro.kernels import quant
    spec = quant.spec_for(kv_dtype)
    sshape = layout.scale_shape(cfg.num_layers, cfg.num_kv_heads)
    return shape, spec.code_dtype, sshape


def init_cache(cfg: ModelConfig, layout: KVLayout, dtype=None):
    """KV storage for any :class:`~repro.models.kvlayout.KVLayout` — the
    dense (L, B, S, HK, Dh) slot cache or the block-paged (L, NP, PS, HK,
    Dh) pool (per-sequence addressing then lives in the engine's block
    tables — see :mod:`repro.serving.blockpool`). Quantized paged layouts
    add ``k_scale``/``v_scale`` step-pool leaves."""
    shape, dtype, sshape = _cache_shapes(cfg, layout, dtype)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if sshape is not None:
        cache["k_scale"] = jnp.zeros(sshape, jnp.float32)
        cache["v_scale"] = jnp.zeros(sshape, jnp.float32)
    return cache


def cache_spec(cfg: ModelConfig, layout: KVLayout, dtype=None):
    shape, dtype, sshape = _cache_shapes(cfg, layout, dtype)
    spec = {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}
    if sshape is not None:
        spec["k_scale"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
        spec["v_scale"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
    return spec


def prefill(
    ctx: LayerCtx, params: Params, tokens: jax.Array, lengths: jax.Array,
    cache: dict, *, prefix_embeds: Optional[jax.Array] = None,
    unroll: bool = False, prefill_block_fn: Callable = prefill_block,
):
    """Process the prompt, fill the KV cache, return last-token logits."""
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    s_max = cache["k"].shape[2]

    x, entries = stack.run_stack_collect(
        params["layers"], x,
        lambda p_i, xx: prefill_block_fn(ctx, p_i, xx, positions, s_max),
        unroll=unroll,
    )
    x = L.norm(cfg, params["final_norm"], x)
    last = jnp.take_along_axis(
        x, (lengths - 1)[:, None, None].clip(0), axis=1
    )
    logits = L.lm_logits(ctx, params, last)[:, 0]
    cache = {"k": entries["k"].astype(cache["k"].dtype),
             "v": entries["v"].astype(cache["v"].dtype)}
    return logits, cache


def decode_step(
    ctx: LayerCtx, params: Params, tokens: jax.Array, cache: dict,
    lengths: jax.Array, *, block_tables: Optional[jax.Array] = None,
    decode_groups=None, positions: Optional[jax.Array] = None,
    unroll: Optional[bool] = None,
    decode_block_fn: Callable = decode_block,
):
    """One decode step. tokens: (B,) -> logits (B, V_padded), new cache.

    One signature for both KV layouts: with ``block_tables=None`` the cache
    leaves are dense (L, B, S, HK, Dh) slot caches; with a (B, NB)
    logical→physical page map they are (L, NP, PS, HK, Dh) page pools (the
    scan carries the pool, the table rides in closure). ``decode_groups``
    rides along the same way and activates prefix-shared grouped attention
    on the paged layout.

    ``positions`` is the per-row absolute position operand (defaults to
    ``lengths``; the engine passes its device-cached copy). ``unroll=None``
    lets the plan's ``decode_fusion`` granularity pick the depth-loop
    strategy: ``fused`` python-unrolls into L traced layer bodies;
    ``split``/``looped`` run the stacked depth under one ``lax.scan``
    (an explicit bool overrides the plan). Scan and unroll apply the same
    per-layer math to the same leading-axis slabs, so the choice never
    changes outputs — bit-identity across granularities is tier-1
    enforced.
    """
    cfg = ctx.cfg
    if unroll is None:
        unroll = ctx.plan.decode_fusion.granularity == "fused"
    x = L.embed(ctx, params, tokens[:, None])  # (B, 1, D)
    position = lengths if positions is None else positions

    x, new_cache = stack.run_stack_cached(
        params["layers"], x, cache,
        lambda p_i, xx, c_i: decode_block_fn(ctx, p_i, xx, position, c_i,
                                             lengths, block_tables,
                                             decode_groups),
        unroll=unroll,
    )
    x = L.norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(ctx, params, x)[:, 0]
    return logits, new_cache


def prefill_chunk(
    ctx: LayerCtx, params: Params, tokens: jax.Array,
    chunk_lens: jax.Array, cache: dict, lengths: jax.Array,
    *, block_tables: Optional[jax.Array] = None, unroll: bool = False,
    chunk_block_fn: Callable = chunk_block,
):
    """Process one prompt chunk for a whole (possibly ragged) batch.

    tokens: (B, C); row b consumes its first ``chunk_lens[b]`` entries at
    absolute positions ``lengths[b]..lengths[b]+chunk_lens[b]-1``; rows with
    ``chunk_lens[b] == 0`` are spectators (nothing written, outputs garbage).
    Returns per-row logits at each row's last chunk position and the updated
    cache — long prompts stream through this in fixed-size chunks, and a
    whole admission batch prefills in one call (chunked + batched prefill).
    Starting from ``lengths == 0`` this subsumes single-shot prefill.
    Like :func:`decode_step`, ``block_tables`` selects the KV layout.
    """
    cfg = ctx.cfg
    x = L.embed(ctx, params, tokens)           # (B, C, D)

    x, new_cache = stack.run_stack_cached(
        params["layers"], x, cache,
        lambda p_i, xx, c_i: chunk_block_fn(ctx, p_i, xx, c_i, lengths,
                                            chunk_lens, block_tables),
        unroll=unroll,
    )
    x = L.norm(cfg, params["final_norm"], x)
    last = jnp.take_along_axis(
        x, (chunk_lens - 1)[:, None, None].clip(0), axis=1
    )
    logits = L.lm_logits(ctx, params, last)[:, 0]
    return logits, new_cache
