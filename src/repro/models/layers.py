"""Shared building blocks for the model zoo.

Everything is a pure function over explicit param pytrees (dicts of arrays):
no framework magic, scan-compatible (layer params are stacked on a leading L
axis by the model constructors), and shardable with `with_sharding_constraint`
through the rules in :mod:`repro.distributed.sharding`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SoftmaxPhiConfig
from repro.core.plan import DEFAULT_PLAN, ExecutionPlan
from repro.kernels import ops, quant

Params = dict
ShardFn = Callable[[jax.Array, str], jax.Array]  # (x, logical role) -> x


def no_shard(x: jax.Array, role: str) -> jax.Array:  # default: no constraints
    return x


@dataclasses.dataclass(frozen=True)
class LayerCtx:
    """Per-call context threaded through every layer."""

    cfg: ModelConfig
    shard: ShardFn = no_shard
    # every implementation decision — GEMM routing, softmax scheme, decode
    # block_k, fallback branches, Pallas vs. XLA backend — lives in the
    # plan (repro.core.plan); the untuned default is the XLA reference path
    plan: ExecutionPlan = DEFAULT_PLAN
    # MoE routing group count (= data-parallel shard count at scale)
    moe_groups: int = 1
    # attention combine override, set by the distributed decode path
    decode_attention_fn: Optional[Callable] = None
    # mesh + sharding rules enable the manual (shard_map) dispatch paths
    # (MoE dispatch locality, split-KV attention); None on single-host
    mesh: Optional[Any] = None
    rules: Optional[Any] = None

    @property
    def phi_cfg(self) -> SoftmaxPhiConfig:
        return self.cfg.softmax_phi

    def matmul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        return ops.matmul(x, w, plan=self.plan)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_params(cfg: ModelConfig, d: int) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), _pdt(cfg)),
                "bias": jnp.zeros((d,), _pdt(cfg))}
    return {"scale": jnp.ones((d,), _pdt(cfg))}


def _pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _adt(cfg: ModelConfig):
    return jnp.dtype(cfg.activation_dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Attention block (GQA, RoPE, optional sliding window)
# ---------------------------------------------------------------------------


def attention_params(cfg: ModelConfig, key) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, dt = cfg.d_model, _pdt(cfg)
    p = {
        "wq": dense_init(k1, (d, cfg.q_dim), dt),
        "wk": dense_init(k2, (d, cfg.kv_dim), dt),
        "wv": dense_init(k3, (d, cfg.kv_dim), dt),
        "wo": dense_init(k4, (cfg.q_dim, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    return p


def attention_qkv(
    ctx: LayerCtx, p: Params, x: jax.Array, positions: jax.Array,
    *, use_rope: bool = True,
):
    """Project to q, k, v. x: (B, S, D) -> q (B,S,HQ,Dh), k/v (B,S,HK,Dh)."""
    cfg = ctx.cfg
    b, s, _ = x.shape
    q = ctx.matmul(x, p["wq"])
    k = ctx.matmul(x, p["wk"])
    v = ctx.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = ctx.shard(q, "act_qkv")
    k = ctx.shard(k, "act_kv")
    v = ctx.shard(v, "act_kv")
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(
    ctx: LayerCtx, p: Params, x: jax.Array, positions: jax.Array,
    *, causal: bool = True, use_rope: bool = True,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
) -> jax.Array:
    """Full-sequence (train / prefill / encoder) attention.

    ``kv_override`` feeds cross-attention (keys/values from the encoder).
    """
    cfg = ctx.cfg
    b, s, _ = x.shape
    if kv_override is None:
        q, k, v = attention_qkv(ctx, p, x, positions, use_rope=use_rope)
    else:
        q = ctx.matmul(x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
        k, v = kv_override
    o = ops.attention_prefill(
        q, k, v,
        phi_cfg=ctx.phi_cfg if cfg.has_softmax_attention else
        SoftmaxPhiConfig(enabled=False),
        causal=causal,
        sliding_window=cfg.sliding_window,
        plan=ctx.plan,
    )
    o = ctx.shard(o.reshape(b, s, cfg.q_dim), "act_attn_out")
    return ctx.matmul(o, p["wo"])


# --- decode-layer stage boundaries -----------------------------------------
#
# One decode layer is four explicit stages a fusion backend can claim
# (DecodeFusionPlan.granularity, see repro.core.plan):
#
#   A. ingest   — norm → QKV → bias → rope          (decode_ingest)
#   B. attend   — KV scatter → decode attention      (decode_attend[_paged])
#   C. epilogue — o_proj → residual add              (decode_epilogue)
#   D. mlp      — norm → gate/up → act → down → res  (decode_mlp)
#
# `split` composes each stage from today's op chain; `fused`/`looped`
# dispatch the A, C and D seams through ops.decode_ingest /
# ops.oproj_residual / ops.ffn_norm (one kernel per seam on the Pallas
# backend, the bit-identical oracle composition on XLA). Stage B keeps
# its own plan-governed dispatch (attention scheme/paging/quantization
# are orthogonal axes).


def decode_ingest(
    ctx: LayerCtx, norm_p: Params, p: Params, x: jax.Array,
    position: jax.Array, *, use_rope: bool = True,
):
    """Stage A: pre-attention ingest on the residual stream.

    x: (B, 1, D) un-normed; position: (B,). Returns q (B,1,HQ,Dh),
    k/v (B,1,HK,Dh). The fused seam claims rmsnorm models only —
    layernorm families keep the split composition (documented fallback).
    """
    cfg = ctx.cfg
    if (ctx.plan.decode_fusion.granularity != "split"
            and cfg.norm == "rmsnorm"):
        q, k, v = ops.decode_ingest(
            x, norm_p["scale"], p["wq"], p["wk"], p["wv"], position,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            use_rope=use_rope,
            bq=p.get("bq"), bk=p.get("bk"), bv=p.get("bv"),
            plan=ctx.plan,
        )
        return (ctx.shard(q, "act_qkv"), ctx.shard(k, "act_kv"),
                ctx.shard(v, "act_kv"))
    h = norm(cfg, norm_p, x)
    return attention_qkv(ctx, p, h, position[:, None], use_rope=use_rope)


def decode_attend(
    ctx: LayerCtx, q: jax.Array, k: jax.Array, v: jax.Array,
    cache_k: jax.Array, cache_v: jax.Array, lengths: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stage B (dense layout): append this token's KV at each row's
    length, attend over the cache. Returns (o (B,1,HQ*Dh), new caches)."""
    cfg = ctx.cfg
    b = q.shape[0]
    # single-token q/k/v are tiny: replicate over `model` (the sharded
    # resource is the cache sequence — T1's split-KV layout)
    k_new = ctx.shard(k[:, 0], "act_decode_rep")
    v_new = ctx.shard(v[:, 0], "act_decode_rep")
    qd = ctx.shard(q[:, 0], "act_decode_rep")  # (B, HQ, Dh)
    # append at each sequence's own length (in place, S-sharded cache)
    cache_k = ctx.shard(_scatter_kv(cache_k, k_new, lengths),
                        "act_cache_slice")
    cache_v = ctx.shard(_scatter_kv(cache_v, v_new, lengths),
                        "act_cache_slice")
    new_len = lengths + 1
    if ctx.decode_attention_fn is not None:
        o = ctx.decode_attention_fn(qd, cache_k, cache_v, new_len)
    else:
        o = ops.attention_decode(
            qd, cache_k, cache_v, new_len,
            phi_cfg=ctx.phi_cfg if cfg.has_softmax_attention else
            SoftmaxPhiConfig(enabled=False),
            plan=ctx.plan,
            shard=ctx.shard,
        )
    o = ctx.shard(o.reshape(b, 1, cfg.q_dim), "act_attn_out")
    return o, cache_k, cache_v


def decode_epilogue(ctx: LayerCtx, p: Params, o: jax.Array,
                    resid: jax.Array) -> jax.Array:
    """Stage C: ``resid + o @ wo`` — one fused dispatch when claimed."""
    if ctx.plan.decode_fusion.granularity != "split":
        return ops.oproj_residual(o, p["wo"], resid, plan=ctx.plan)
    return resid + ctx.matmul(o, p["wo"])


def decode_mlp(ctx: LayerCtx, norm_p: Params, p: Params,
               x: jax.Array) -> jax.Array:
    """Stage D: the full MLP half — mlp_norm → gate/up → activation →
    down-projection → residual add.

    When claimed, two fused dispatches: ``ops.ffn_norm`` (norm pulled
    inside the gate/up pair) and ``ops.oproj_residual`` reused for
    ``x + h @ w_down`` (the same GEMM-into-residual shape as stage C).
    The seam claims rmsnorm + glu families only — others keep the split
    composition (same documented fallback as stage A).
    """
    cfg = ctx.cfg
    if (ctx.plan.decode_fusion.granularity != "split"
            and cfg.norm == "rmsnorm"
            and cfg.activation in ("swiglu", "geglu")):
        h = ops.ffn_norm(x, norm_p["scale"], p["w_gate"], p["w_up"],
                         activation=cfg.activation, plan=ctx.plan)
        h = ctx.shard(h, "act_ffn")
        return ops.oproj_residual(h, p["w_down"], x, plan=ctx.plan)
    h = norm(cfg, norm_p, x)
    return x + mlp_block(ctx, p, h)


def attention_decode_block(
    ctx: LayerCtx, p: Params, x: jax.Array, position: jax.Array,
    cache_k: jax.Array, cache_v: jax.Array, lengths: jax.Array,
    *, use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode with KV cache (split stage composition — callers
    that norm outside and own the residual, e.g. encdec, use this).

    x: (B, 1, D); cache_k/v: (B, S, HK, Dh); lengths: (B,) current lengths.
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    q, k, v = attention_qkv(
        ctx, p, x, position[:, None], use_rope=use_rope
    )  # q: (B,1,HQ,Dh), k/v: (B,1,HK,Dh)
    o, cache_k, cache_v = decode_attend(ctx, q, k, v, cache_k, cache_v,
                                        lengths)
    return ctx.matmul(o, p["wo"]), cache_k, cache_v


def _scatter_kv(cache: jax.Array, new: jax.Array, lengths: jax.Array):
    """cache: (B, S, H, D), new: (B, H, D) — write at per-row position."""
    b = cache.shape[0]
    return cache.at[jnp.arange(b), lengths].set(new.astype(cache.dtype))


def _scatter_kv_chunk(cache: jax.Array, new: jax.Array, lengths: jax.Array,
                      chunk_lens: jax.Array) -> jax.Array:
    """cache: (B, S, H, D), new: (B, C, H, D) — row b writes its first
    ``chunk_lens[b]`` chunk entries at positions ``lengths[b] + i``; the
    rest (chunk padding / rows not prefilling this tick) are dropped via
    an out-of-bounds sentinel index."""
    b, c = new.shape[:2]
    s = cache.shape[1]
    pos = lengths[:, None] + jnp.arange(c)[None, :]
    pos = jnp.where(jnp.arange(c)[None, :] < chunk_lens[:, None], pos, s)
    return cache.at[jnp.arange(b)[:, None], pos].set(
        new.astype(cache.dtype), mode="drop")


def _paged_scatter_chunk(pool: jax.Array, new: jax.Array,
                         block_tables: jax.Array, lengths: jax.Array,
                         chunk_lens: jax.Array) -> jax.Array:
    """Scatter a chunk of new KV into the shared block pool.

    pool: (NP, PS, H, D); new: (B, C, H, D); block_tables: (B, NB).
    Logical position ``lengths[b] + i`` lands at physical page
    ``block_tables[b, pos // PS]`` offset ``pos % PS``. Entries past a row's
    ``chunk_lens`` are redirected to page NP (out of bounds) and dropped;
    unassigned block-table entries already hold the NP sentinel, so writes
    from empty slots in a partially occupied batch are dropped too.
    """
    num_pages, ps = pool.shape[0], pool.shape[1]
    b, c = new.shape[:2]
    pos = lengths[:, None] + jnp.arange(c)[None, :]
    valid = jnp.arange(c)[None, :] < chunk_lens[:, None]
    page = jnp.clip(pos // ps, 0, block_tables.shape[1] - 1)
    phys = jnp.take_along_axis(block_tables, page, axis=1)
    phys = jnp.where(valid, phys, num_pages)
    return pool.at[phys, pos % ps].set(new.astype(pool.dtype), mode="drop")


def decode_attend_paged(
    ctx: LayerCtx, q: jax.Array, k: jax.Array, v: jax.Array,
    pool_k: jax.Array, pool_v: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, *, decode_groups=None,
    k_scale: jax.Array | None = None, v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array | None,
           jax.Array | None]:
    """Stage B (paged layout): append this token's KV through the block
    tables (quantized scatter when scale pools ride along), attend over
    the page pool. Returns (o (B,1,HQ*Dh), pools, scale pools)."""
    cfg = ctx.cfg
    b = q.shape[0]
    ones = jnp.ones_like(lengths)
    if k_scale is not None:
        from repro.serving import kvquant  # deferred: serving imports models

        spec = quant.spec_for_dtype(pool_k.dtype)
        pool_k, k_scale = kvquant.scatter_chunk_quantized(
            pool_k, k_scale, k, block_tables, lengths, ones, spec)
        pool_v, v_scale = kvquant.scatter_chunk_quantized(
            pool_v, v_scale, v, block_tables, lengths, ones, spec)
    else:
        pool_k = _paged_scatter_chunk(pool_k, k, block_tables, lengths, ones)
        pool_v = _paged_scatter_chunk(pool_v, v, block_tables, lengths, ones)
    new_len = lengths + 1
    o = ops.attention_decode_paged(
        q[:, 0], pool_k, pool_v, block_tables, new_len,
        phi_cfg=ctx.phi_cfg if cfg.has_softmax_attention else
        SoftmaxPhiConfig(enabled=False),
        plan=ctx.plan,
        shard=ctx.shard,
        groups=decode_groups,
        k_scale=k_scale, v_scale=v_scale,
    )
    o = ctx.shard(o.reshape(b, 1, cfg.q_dim), "act_attn_out")
    return o, pool_k, pool_v, k_scale, v_scale


def attention_decode_block_paged(
    ctx: LayerCtx, p: Params, x: jax.Array, position: jax.Array,
    pool_k: jax.Array, pool_v: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, *, use_rope: bool = True, decode_groups=None,
    k_scale: jax.Array | None = None, v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array | None,
           jax.Array | None]:
    """One-token decode against a block-paged KV cache (split stage
    composition).

    x: (B, 1, D); pool_k/v: (NP, PS, HK, Dh) shared page pools;
    block_tables: (B, NB) int32. Empty slots in a partially occupied batch
    write nothing — their block-table entries are the out-of-bounds
    sentinel, so the scatter drops them. ``decode_groups`` (a
    :class:`~repro.kernels.group_attention.DecodeGroups`) activates the
    prefix-shared grouped attention path.

    With ``k_scale``/``v_scale`` (the (NP, HK) f32 step pools of a
    quantized layout) the new token is appended through the quantized
    scatter and attention dequantizes in place; returns the updated scale
    pools alongside the code pools (``None``/``None`` when bf16).
    """
    q, k, v = attention_qkv(
        ctx, p, x, position[:, None], use_rope=use_rope
    )
    o, pool_k, pool_v, k_scale, v_scale = decode_attend_paged(
        ctx, q, k, v, pool_k, pool_v, block_tables, lengths,
        decode_groups=decode_groups, k_scale=k_scale, v_scale=v_scale,
    )
    return ctx.matmul(o, p["wo"]), pool_k, pool_v, k_scale, v_scale


def attention_chunk_block(
    ctx: LayerCtx, p: Params, x: jax.Array,
    cache_k: jax.Array, cache_v: jax.Array,
    lengths: jax.Array, chunk_lens: jax.Array, *, use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill step: C prompt tokens append to a dense slot cache.

    x: (B, C, D); cache_k/v: (B, S, HK, Dh). Row b's tokens sit at absolute
    positions ``lengths[b] + i`` for ``i < chunk_lens[b]``; the chunk's KV is
    scattered first, then the chunk attends causally to prefix + chunk.
    """
    cfg = ctx.cfg
    b, c, _ = x.shape
    positions = lengths[:, None] + jnp.arange(c)[None, :]
    q, k, v = attention_qkv(ctx, p, x, positions, use_rope=use_rope)
    cache_k = _scatter_kv_chunk(cache_k, k, lengths, chunk_lens)
    cache_v = _scatter_kv_chunk(cache_v, v, lengths, chunk_lens)
    o = ops.attention_chunk(
        q, cache_k, cache_v, lengths,
        phi_cfg=ctx.phi_cfg if cfg.has_softmax_attention else
        SoftmaxPhiConfig(enabled=False),
        plan=ctx.plan,
    )
    o = ctx.shard(o.reshape(b, c, cfg.q_dim), "act_attn_out")
    return ctx.matmul(o, p["wo"]), cache_k, cache_v


def attention_chunk_block_paged(
    ctx: LayerCtx, p: Params, x: jax.Array,
    pool_k: jax.Array, pool_v: jax.Array, block_tables: jax.Array,
    lengths: jax.Array, chunk_lens: jax.Array, *, use_rope: bool = True,
    k_scale: jax.Array | None = None, v_scale: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array | None,
           jax.Array | None]:
    """Chunked-prefill step against the block-paged pool (paged twin of
    :func:`attention_chunk_block`). Quantized layouts (``k_scale``/
    ``v_scale`` step pools) write the chunk through the quantized scatter
    — quantization happens in the chunk epilogue, so the full-precision
    slab never lands in HBM — and return the updated scale pools."""
    cfg = ctx.cfg
    b, c, _ = x.shape
    positions = lengths[:, None] + jnp.arange(c)[None, :]
    q, k, v = attention_qkv(ctx, p, x, positions, use_rope=use_rope)
    if k_scale is not None:
        from repro.serving import kvquant  # deferred: serving imports models

        spec = quant.spec_for_dtype(pool_k.dtype)
        pool_k, k_scale = kvquant.scatter_chunk_quantized(
            pool_k, k_scale, k, block_tables, lengths, chunk_lens, spec)
        pool_v, v_scale = kvquant.scatter_chunk_quantized(
            pool_v, v_scale, v, block_tables, lengths, chunk_lens, spec)
    else:
        pool_k = _paged_scatter_chunk(pool_k, k, block_tables, lengths,
                                      chunk_lens)
        pool_v = _paged_scatter_chunk(pool_v, v, block_tables, lengths,
                                      chunk_lens)
    o = ops.attention_chunk_paged(
        q, pool_k, pool_v, block_tables, lengths,
        phi_cfg=ctx.phi_cfg if cfg.has_softmax_attention else
        SoftmaxPhiConfig(enabled=False),
        plan=ctx.plan,
        k_scale=k_scale, v_scale=v_scale,
    )
    o = ctx.shard(o.reshape(b, c, cfg.q_dim), "act_attn_out")
    return ctx.matmul(o, p["wo"]), pool_k, pool_v, k_scale, v_scale


# ---------------------------------------------------------------------------
# Feed-forward (dense)
# ---------------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    d, f, dt = cfg.d_model, d_ff or cfg.d_ff, _pdt(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f), dt),
            "w_up": dense_init(k2, (d, f), dt),
            "w_down": dense_init(k3, (f, d), dt),
        }
    return {
        "w_up": dense_init(k1, (d, f), dt),
        "w_down": dense_init(k2, (f, d), dt),
    }


def mlp_block(ctx: LayerCtx, p: Params, x: jax.Array) -> jax.Array:
    cfg = ctx.cfg
    if cfg.activation in ("swiglu", "geglu"):
        if ctx.plan.fused_ffn.fused:
            # T2 extension: single fused kernel for gate+up+epilogue —
            # the (M, F) gate/up tensors never round-trip HBM
            h = ops.fused_ffn(x, p["w_gate"], p["w_up"],
                              activation=cfg.activation, plan=ctx.plan)
            h = ctx.shard(h, "act_ffn")
        else:
            g = ctx.matmul(x, p["w_gate"])
            u = ctx.matmul(x, p["w_up"])
            g = ctx.shard(g, "act_ffn")
            u = ctx.shard(u, "act_ffn")
            act = (jax.nn.silu(g) if cfg.activation == "swiglu"
                   else jax.nn.gelu(g))
            h = act * u
    else:
        h = ctx.matmul(x, p["w_up"])
        h = ctx.shard(h, "act_ffn")
        h = jax.nn.gelu(h)
    return ctx.matmul(h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding / LM head / loss
# ---------------------------------------------------------------------------


def vocab_padded(cfg: ModelConfig, multiple: int = 256) -> int:
    v = cfg.vocab_size
    return (v + multiple - 1) // multiple * multiple


def embed_params(cfg: ModelConfig, key) -> Params:
    vp = vocab_padded(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (vp, cfg.d_model), _pdt(cfg), in_axis=1)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, vp), _pdt(cfg))
    return p


def embed(ctx: LayerCtx, p: Params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    return ctx.shard(x.astype(_adt(ctx.cfg)), "act_resid")


def lm_logits(ctx: LayerCtx, p: Params, x: jax.Array) -> jax.Array:
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    logits = ctx.matmul(x, w)
    return ctx.shard(logits, "act_logits")


def cross_entropy_loss(
    ctx: LayerCtx, p: Params, x: jax.Array, labels: jax.Array,
    *, seq_chunks: int = 8,
) -> jax.Array:
    """Memory-sane LM loss: the (B,S,V) logits tensor is never materialized
    at full sequence length — a *python-unrolled* loop over sequence chunks
    keeps HLO flat (counted exactly by cost_analysis; see EXPERIMENTS.md
    §Methodology) while bounding live logits to (B, S/chunks, V).
    """
    cfg = ctx.cfg
    b, s, _ = x.shape
    vp = vocab_padded(cfg)
    seq_chunks = min(seq_chunks, s)
    assert s % seq_chunks == 0
    cs = s // seq_chunks
    total = jnp.zeros((), jnp.float32)
    for i in range(seq_chunks):
        xc = jax.lax.dynamic_slice_in_dim(x, i * cs, cs, axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
        logits = lm_logits(ctx, p, xc).astype(jnp.float32)
        if vp != cfg.vocab_size:  # mask padded vocab tail
            pad_mask = jnp.arange(vp) >= cfg.vocab_size
            logits = jnp.where(pad_mask, -1e9, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(logz - gold)
    return total / (b * s)
