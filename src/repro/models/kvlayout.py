"""KV-storage layouts: the one cache-surface abstraction shared by the
model API and the serving engine.

A :class:`KVLayout` describes *how per-layer KV tensors are stored and
addressed*, so every family exposes exactly one ``init_cache`` /
``cache_spec`` / ``prefill_chunk`` / ``decode_step`` surface instead of a
dense/paged fork of ``*_paged`` twins:

  * :class:`DenseLayout` — the classic slot cache: ``(L, num_slots,
    max_seq, HK, Dh)``; logical position ``p`` of slot ``s`` lives at
    physical ``(s, p)``. No indirection operand.

  * :class:`PagedLayout` — a block-paged pool: ``(L, num_pages, page_size,
    HK, Dh)`` shared by all sequences; logical position ``p`` of slot ``s``
    lives at ``(block_tables[s, p // page_size], p % page_size)``. The
    layout's *operand* is the per-tick block-table array produced by the
    slot manager (``None`` for dense) — model steps take it as an optional
    ``block_tables`` argument and select the gather/scatter discipline on
    whether it is present.

The layout objects are pure shape/addressing descriptors (hashable,
host-side); device allocation stays in the family modules, free-list
bookkeeping stays in :mod:`repro.serving.blockpool`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple, Union


def pages_for(positions: int, page_size: int) -> int:
    """Pages needed to store ``positions`` KV entries — the one definition
    of the page ceil-div, shared by the allocator, the engine's pool
    sizing, and the benchmarks."""
    return -(-max(positions, 0) // page_size)


def pow2_bucket(n: int, lo: int = 1, hi: Union[int, None] = None) -> int:
    """Round ``n`` up to a power-of-two bucket (floor ``lo``, capped at
    ``hi``) — the one rounding that keeps jit shape families logarithmic
    (the engine's resident-bounded block tables and batched-prefill
    padding) and lets the benchmarks mirror the engine's bucketing
    exactly."""
    b = lo
    while b < n:
        b *= 2
    return b if hi is None else min(b, hi)


@dataclasses.dataclass(frozen=True)
class DenseLayout:
    """Slot-dense KV storage: every slot reserves ``max_seq`` positions."""

    num_slots: int
    max_seq: int

    kind = "dense"
    is_paged = False

    def kv_shape(self, num_layers: int, kv_heads: int,
                 head_dim: int) -> Tuple[int, int, int, int, int]:
        return (num_layers, self.num_slots, self.max_seq, kv_heads, head_dim)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Block-paged KV storage: a shared pool of fixed-size pages addressed
    through per-sequence block tables.

    ``kv_dtype`` is the page storage precision (``plan.KV_DTYPES``):
    ``"bf16"`` stores full-precision pages; ``"int8"`` / ``"fp8"`` store
    quantized codes plus parallel per-(page, kv head) f32 scale pools as
    extra cache leaves (see :mod:`repro.serving.kvquant`)."""

    num_pages: int
    page_size: int
    kv_dtype: str = "bf16"

    kind = "paged"
    is_paged = True

    def kv_shape(self, num_layers: int, kv_heads: int,
                 head_dim: int) -> Tuple[int, int, int, int, int]:
        return (num_layers, self.num_pages, self.page_size, kv_heads,
                head_dim)

    def scale_shape(self, num_layers: int,
                    kv_heads: int) -> Tuple[int, int, int]:
        """Shape of one scale pool leaf (quantized layouts only)."""
        return (num_layers, self.num_pages, kv_heads)

    def pages_for(self, positions: int) -> int:
        return pages_for(positions, self.page_size)


KVLayout = Union[DenseLayout, PagedLayout]


def require_dense(layout: KVLayout, family: str) -> DenseLayout:
    """Families without a dense-KV cache (recurrent / ring / encdec state)
    can only host the slot layout; give them a uniform error."""
    if getattr(layout, "is_paged", False):
        raise ValueError(
            f"family {family!r} has no paged-KV path (recurrent/ring state "
            "caches); use a DenseLayout")
    return layout
