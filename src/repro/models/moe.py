"""Token-choice top-k MoE (grok-1 / dbrx families).

Dispatch strategy (1000-node posture, documented in DESIGN.md §5):
  * routing + slotting are **group-local**: tokens are reshaped to
    ``(G, T/G, D)`` where G = the data-parallel shard count, so the argsort /
    capacity bookkeeping never crosses a shard boundary (no collectives from
    routing itself).
  * expert FFN weights are stored **unfactored** ``(E, D, F)`` and sharded
    TP-style: F over ``model``, D over the fsdp(data) axes in training. Every
    shard computes its own tokens through all experts' F-slices — compute is
    perfectly balanced regardless of routing skew, and the only collectives
    are the standard TP all-reduce after the down-projection (plus FSDP
    weight gathers in training). This avoids the all-to-all latency wall at
    pod scale at the cost of weight gathers — the trade is analyzed in
    EXPERIMENTS.md §Roofline for grok/dbrx.
  * capacity: ``C = ceil(T_g*k/E * capacity_factor)`` (train; overflow
    dropped, standard token-dropping semantics) or zero-drop full capacity
    for decode.

The decode-phase expert GEMMs are *flatter* than dense ones (M_eff ≈
M·k/E) — exactly the paper's T2/T3 regime; ``core.dispatch`` carries
per-expert [K, N] entries for them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.layers import LayerCtx, Params


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def moe_params(cfg: ModelConfig, key) -> Params:
    assert cfg.moe is not None
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std_in, std_out = d ** -0.5, f ** -0.5

    def init(k, shape, std):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)

    p = {
        "router": init(k1, (d, e), std_in).astype(jnp.float32),
        "w_up": init(k3, (e, d, f), std_in),
        "w_down": init(k4, (e, f, d), std_out),
    }
    if cfg.activation in ("swiglu", "geglu"):  # gated: 3 expert matrices
        p["w_gate"] = init(k2, (e, d, f), std_in)
    return p


def layer_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.norm_params(cfg, cfg.d_model),
        "attn": L.attention_params(cfg, k1),
        "mlp_norm": L.norm_params(cfg, cfg.d_model),
        "moe": moe_params(cfg, k2),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    return tfm.init_params(cfg, key, layer_params_fn=layer_params)


# ---------------------------------------------------------------------------
# The MoE FFN
# ---------------------------------------------------------------------------


def moe_block(
    ctx: LayerCtx, p: Params, x: jax.Array,
    *, groups: int = 1, capacity_factor: float = 1.25,
    zero_drop: bool = False,
):
    """x: (B, S, D) -> (out (B,S,D), aux load-balance loss).

    When ``ctx.mesh`` is set, the dispatch/combine runs *manually* over the
    data axes (see :func:`_moe_block_manual`) — GSPMD cannot prove the
    slot gather/scatter is group-local and inserts slot-granularity
    collectives otherwise (EXPERIMENTS.md §Perf, grok train iteration 2).
    """
    cfg = ctx.cfg
    assert cfg.moe is not None
    e, k = cfg.moe.num_experts, cfg.moe.num_experts_per_tok
    b, s, d = x.shape
    t = b * s
    g = groups
    while t % g:
        g //= 2
    tg = t // g
    if zero_drop:
        cap = tg * k
    else:
        cap = int(-(-tg * k * capacity_factor // e))
        cap = max(8, -(-cap // 8) * 8)
        cap = min(cap, tg * k)
    xg = x.reshape(g, tg, d)

    if ctx.mesh is not None and ctx.rules is not None:
        manual = _moe_block_manual(ctx, p, xg, e=e, k=k, cap=cap)
        if manual is not None:
            out, aux = manual
            return ctx.shard(out.reshape(b, s, d), "act_resid"), aux

    xg = ctx.shard(xg, "act_moe_grouped")
    out, aux = _dispatch_ffn_combine(
        cfg, p, xg, e=e, k=k, cap=cap, shard=ctx.shard)
    return ctx.shard(out.reshape(b, s, d), "act_resid"), aux


def _dispatch_ffn_combine(cfg, p: Params, xg: jax.Array, *,
                          e: int, k: int, cap: int, shard):
    """Routing -> slotting -> expert FFN -> combine, on (G, Tg, D) groups.
    Pure group-local math apart from the TP einsums."""
    g, tg, d = xg.shape

    # ---- routing (f32) ----
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)                  # (G,Tg,E)
    weights, idx = jax.lax.top_k(probs, k)                   # (G,Tg,k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # ---- slotting (group-local; no collectives) ----
    eflat = idx.reshape(g, tg * k)                           # (G, T*k)
    wflat = weights.reshape(g, tg * k)
    order = jnp.argsort(eflat, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(eflat, order, axis=1)
    sorted_w = jnp.take_along_axis(wflat, order, axis=1)
    sorted_tok = order // k
    counts = jnp.sum(
        jax.nn.one_hot(eflat, e, dtype=jnp.int32), axis=1
    )                                                        # (G, E)
    starts = jnp.cumsum(counts, axis=1) - counts             # exclusive
    ranks = (
        jnp.arange(tg * k)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=1)
    )
    keep = ranks < cap
    dest = jnp.where(keep, sorted_e * cap + ranks, e * cap)  # dump slot

    def scatter_slots(dest_g, tok_g, w_g):
        slot_tok = jnp.zeros((e * cap + 1,), jnp.int32).at[dest_g].set(tok_g)
        slot_w = jnp.zeros((e * cap + 1,), jnp.float32).at[dest_g].set(w_g)
        slot_valid = jnp.zeros((e * cap + 1,), jnp.bool_).at[dest_g].set(True)
        return slot_tok[:-1], slot_w[:-1], slot_valid[:-1]

    slot_tok, slot_w, slot_valid = jax.vmap(scatter_slots)(
        dest, sorted_tok, sorted_w
    )                                                        # (G, E*cap)

    # ---- gather tokens into (G, E, cap, D) slots ----
    xs = jnp.take_along_axis(xg, slot_tok[..., None], axis=1)
    xs = xs * slot_valid[..., None].astype(xg.dtype)
    xs = xs.reshape(g, e, cap, d)
    xs = shard(xs, "act_moe_slots")

    # ---- expert FFN (TP over model axis on F) ----
    if "w_gate" in p:   # gated (swiglu/geglu): 3 expert matrices
        gate = jnp.einsum("gecd,edf->gecf", xs, p["w_gate"])
        up = jnp.einsum("gecd,edf->gecf", xs, p["w_up"])
        gate = shard(gate, "act_moe_hidden")
        up = shard(up, "act_moe_hidden")
        act = (jax.nn.silu(gate) if cfg.activation == "swiglu"
               else jax.nn.gelu(gate))
        h = act * up
    else:               # plain MLP experts (grok-style gelu): 2 matrices
        up = jnp.einsum("gecd,edf->gecf", xs, p["w_up"])
        up = shard(up, "act_moe_hidden")
        h = jax.nn.gelu(up)
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    # NOTE: no sharding constraint on y here. The down-proj contracts the
    # model-sharded F axis, so y is a *partial sum* across the model axis;
    # constraining it at slot granularity forces GSPMD to resolve (psum or
    # worse, all-gather h) over E*cap slots = k*capacity x the token count.
    # The slot->token combine below is linear, so the reduction commutes:
    # deferring the constraint to the (B, S, D) output reduces wire bytes
    # by k*capacity (dbrx: 8x) — EXPERIMENTS.md §Perf, dbrx iteration 2.

    # ---- combine back to tokens ----
    y = y.reshape(g, e * cap, d) * (
        slot_w[..., None].astype(y.dtype)
        * slot_valid[..., None].astype(y.dtype)
    )

    def combine(y_g, tok_g):
        return jnp.zeros((tg, d), y_g.dtype).at[tok_g].add(y_g)

    out = jax.vmap(combine)(y, slot_tok)

    # ---- GShard load-balance aux ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def _moe_block_manual(ctx: LayerCtx, p: Params, xg: jax.Array, *,
                      e: int, k: int, cap: int):
    """Dispatch locality by construction (grok/dbrx hillclimb iteration).

    The slot gather/scatter of token-choice MoE is *group-local*, but
    GSPMD cannot prove it and materializes slot-granularity collectives
    (observed: E*cap-sized all-gathers in fwd+bwd). Running the whole
    routing->dispatch->FFN->combine under a ``shard_map`` manual over the
    data axes makes cross-group traffic impossible by construction; the
    ``model`` axis stays auto, so the expert einsums keep their TP
    sharding, and the FSDP weight gather over data becomes one explicit
    tiled all-gather per weight (weights << activations).

    Returns None when shapes don't divide the data axes (falls back to
    the GSPMD path).
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    cfg = ctx.cfg
    mesh, rules = ctx.mesh, ctx.rules
    g, tg, d = xg.shape
    data_axes = tuple(a for a in rules.act_batch_axes
                      if a in mesh.axis_names)
    if not data_axes:
        return None
    nshards = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                           for a in data_axes]))
    if g % nshards:
        return None
    # weights' FSDP (data) placement, from the same rules that shard them
    moe_specs = {
        name: rules.param_spec(("layers", "moe", name), p[name].shape)
        for name in p
    }

    def data_only(spec: P) -> P:
        ents = []
        for s_ in spec:
            axes = s_ if isinstance(s_, tuple) else (s_,)
            kept = tuple(a for a in axes if a in data_axes)
            ents.append(kept if kept else None)
        return P(*ents)

    w_specs = {n: data_only(s) for n, s in moe_specs.items()}

    def body(xg_l, p_l):
        # un-FSDP the weights: one explicit tiled gather per data-sharded
        # dim (the manual mirror of GSPMD's FSDP gather)
        p_full = {}
        for name, w in p_l.items():
            spec = w_specs[name]
            for dim, s_ in enumerate(spec):
                if s_ is not None:
                    w = jax.lax.all_gather(w, s_, axis=dim, tiled=True)
            p_full[name] = w
        out, aux = _dispatch_ffn_combine(
            cfg, p_full, xg_l, e=e, k=k, cap=cap,
            shard=lambda a, _role: a,
        )
        return out, jax.lax.pmean(aux, data_axes)

    dspec = data_axes if len(data_axes) > 1 else data_axes[0]
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dspec), w_specs),
        out_specs=(P(dspec), P()),
        axis_names=set(data_axes),
    )
    return fn(xg, p)


# ---------------------------------------------------------------------------
# Blocks (reusing the dense attention halves)
# ---------------------------------------------------------------------------


def make_block(groups: int = 0, capacity_factor: float = 1.25):
    def block(ctx: LayerCtx, p: Params, x: jax.Array, positions: jax.Array):
        cfg = ctx.cfg
        h = L.norm(cfg, p["attn_norm"], x)
        x = x + L.attention_block(ctx, p["attn"], h, positions)
        x = ctx.shard(x, "act_resid")
        h = L.norm(cfg, p["mlp_norm"], x)
        y, aux = moe_block(
            ctx, p["moe"], h, groups=groups or ctx.moe_groups,
            capacity_factor=capacity_factor
        )
        return ctx.shard(x + y, "act_resid"), aux

    return block


def make_decode_block(groups: int = 0):
    def decode_block(ctx: LayerCtx, p: Params, x, position, cache_i, lengths,
                     block_tables=None, decode_groups=None):
        # same ingest → attend → epilogue stage boundaries as the dense
        # family (repro.models.layers); only the FFN half differs — the
        # routed expert dispatch is not a fusable seam, so the MoE block
        # shares the attention-side fused stages and keeps its own tail
        cfg = ctx.cfg
        q, k, v = L.decode_ingest(ctx, p["attn_norm"], p["attn"], x,
                                  position)
        if block_tables is None:
            o, ck, cv = L.decode_attend(
                ctx, q, k, v, cache_i["k"], cache_i["v"], lengths
            )
            new_cache = {"k": ck, "v": cv}
        else:
            o, ck, cv, ks, vs = L.decode_attend_paged(
                ctx, q, k, v, cache_i["k"], cache_i["v"],
                block_tables, lengths, decode_groups=decode_groups,
                k_scale=cache_i.get("k_scale"),
                v_scale=cache_i.get("v_scale"),
            )
            new_cache = {"k": ck, "v": cv}
            if ks is not None:
                new_cache["k_scale"] = ks
                new_cache["v_scale"] = vs
        x = L.decode_epilogue(ctx, p["attn"], o, x)
        h = L.norm(cfg, p["mlp_norm"], x)
        y, _ = moe_block(ctx, p["moe"], h, groups=groups or ctx.moe_groups,
                         zero_drop=True)
        return ctx.shard(x + y, "act_resid"), new_cache

    return decode_block


def _moe_chunk_mlp(ctx: LayerCtx, p: Params, h, groups: int):
    """Chunk-sized MoE half: zero-drop below the group-token cap (chunks are
    decode-adjacent sizes, so this is almost always the exact path)."""
    gr = groups or ctx.moe_groups
    b, c, _ = h.shape
    small = (b * c) // max(gr, 1) <= ZERO_DROP_MAX_GROUP_TOKENS
    y, _ = moe_block(ctx, p["moe"], h, groups=gr, zero_drop=small,
                     capacity_factor=PREFILL_CAPACITY_FACTOR)
    return y


def make_chunk_block(groups: int = 0):
    def chunk_block(ctx: LayerCtx, p: Params, x, cache_i, lengths,
                    chunk_lens, block_tables=None):
        cfg = ctx.cfg
        h = L.norm(cfg, p["attn_norm"], x)
        if block_tables is None:
            a, ck, cv = L.attention_chunk_block(
                ctx, p["attn"], h, cache_i["k"], cache_i["v"], lengths,
                chunk_lens,
            )
            new_cache = {"k": ck, "v": cv}
        else:
            a, ck, cv, ks, vs = L.attention_chunk_block_paged(
                ctx, p["attn"], h, cache_i["k"], cache_i["v"], block_tables,
                lengths, chunk_lens,
                k_scale=cache_i.get("k_scale"),
                v_scale=cache_i.get("v_scale"),
            )
            new_cache = {"k": ck, "v": cv}
            if ks is not None:
                new_cache["k_scale"] = ks
                new_cache["v_scale"] = vs
        x = x + a
        h = L.norm(cfg, p["mlp_norm"], x)
        x = ctx.shard(x + _moe_chunk_mlp(ctx, p, h, groups), "act_resid")
        return x, new_cache

    return chunk_block


# Zero-drop slots cost cap = tg·k *per expert* (worst-case all-to-one
# routing) — exact but E× over-allocated. Fine for decode ticks and
# single-request engine prefill (tiny tg); catastrophic for a 1M-token
# batched prefill (the dbrx prefill_32k hillclimb, EXPERIMENTS.md §Perf).
# Above this per-group token count, batched prefill switches to a bounded
# 2.0x capacity: drops need >2x average skew on a 64k-token group.
ZERO_DROP_MAX_GROUP_TOKENS = 4096
PREFILL_CAPACITY_FACTOR = 2.0


def make_prefill_block(groups: int = 0):
    def prefill_blk(ctx: LayerCtx, p: Params, x, positions, s_max):
        from repro.kernels import ops
        cfg = ctx.cfg
        b, s, _ = x.shape
        h = L.norm(cfg, p["attn_norm"], x)
        q, kk, v = L.attention_qkv(ctx, p["attn"], h, positions)
        o = ops.attention_prefill(
            q, kk, v, phi_cfg=ctx.phi_cfg, causal=True,
            sliding_window=cfg.sliding_window, plan=ctx.plan,
        )
        o = ctx.shard(o.reshape(b, s, cfg.q_dim), "act_attn_out")
        x = x + ctx.matmul(o, p["attn"]["wo"])
        h = L.norm(cfg, p["mlp_norm"], x)
        gr = groups or ctx.moe_groups
        small = (b * s) // max(gr, 1) <= ZERO_DROP_MAX_GROUP_TOKENS
        y, _ = moe_block(ctx, p["moe"], h, groups=gr,
                         zero_drop=small,
                         capacity_factor=PREFILL_CAPACITY_FACTOR)
        x = ctx.shard(x + y, "act_resid")
        pad = [(0, 0), (0, s_max - s), (0, 0), (0, 0)]
        return x, {"k": jnp.pad(kk, pad), "v": jnp.pad(v, pad)}

    return prefill_blk


# ---------------------------------------------------------------------------
# Public API (same signatures as transformer.*)
# ---------------------------------------------------------------------------


def train_loss(ctx: LayerCtx, params: Params, batch: dict, *,
               unroll: bool = False, remat: bool = True, groups: int = 0,
               capacity_factor: float = 1.25):
    aux_w = ctx.cfg.moe.router_aux_loss_coef if ctx.cfg.moe else 0.0
    return tfm.train_loss(
        ctx, params, batch, unroll=unroll, remat=remat,
        block_fn=make_block(groups=groups, capacity_factor=capacity_factor),
        aux_weight=aux_w,
    )


def prefill(ctx: LayerCtx, params: Params, tokens, lengths, cache, *,
            unroll: bool = False, groups: int = 0, **kw):
    return tfm.prefill(
        ctx, params, tokens, lengths, cache, unroll=unroll,
        prefill_block_fn=make_prefill_block(groups=groups), **kw
    )


def decode_step(ctx: LayerCtx, params: Params, tokens, cache, lengths, *,
                block_tables=None, decode_groups=None, positions=None,
                unroll=None, groups: int = 0):
    return tfm.decode_step(
        ctx, params, tokens, cache, lengths, block_tables=block_tables,
        decode_groups=decode_groups, positions=positions, unroll=unroll,
        decode_block_fn=make_decode_block(groups=groups),
    )


def prefill_chunk(ctx: LayerCtx, params: Params, tokens, chunk_lens, cache,
                  lengths, *, block_tables=None, unroll: bool = False,
                  groups: int = 0):
    return tfm.prefill_chunk(
        ctx, params, tokens, chunk_lens, cache, lengths,
        block_tables=block_tables, unroll=unroll,
        chunk_block_fn=make_chunk_block(groups=groups),
    )


PAGED_KV = True
init_cache = tfm.init_cache
cache_spec = tfm.cache_spec
