"""Configuration system.

Three config kinds compose a run:
  * :class:`ModelConfig` — architecture definition (one per ``--arch``).
  * :class:`ShapeConfig` — the assigned input-shape cells.
  * :class:`MeshConfig` / :class:`RunConfig` — distribution + run options.

``ModelConfig`` covers every assigned family (dense GQA / MoE / SSM / hybrid /
enc-dec) so a single model zoo consumes it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    # DBRX-style fine-grained: router jitter etc. kept minimal.
    router_aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """RWKV6 / Mamba-style state config (per-head linear recurrence)."""

    state_size: int = 16       # recurrent state per channel (hymba) / head (rwkv)
    head_dim: int = 64         # rwkv6 head size
    expand: int = 2            # mamba-style inner expansion for hybrid heads


@dataclasses.dataclass(frozen=True)
class SoftmaxPhiConfig:
    """T1: unified-max softmax parameters (paper §3).

    ``phi`` is the static scaling factor; ``band=(a, b)`` is the safe range for
    ``x - phi`` (paper's Example uses (-3, 3); defaults here are wider because
    f32 exp is safe up to ~88). ``phi=None`` disables T1 (the paper does this
    for OPT-6.7B whose logit range is too wide) and the engine uses the
    synchronized two-pass softmax everywhere.
    """

    phi: Optional[float] = 0.0
    band: Tuple[float, float] = (-40.0, 40.0)
    enabled: bool = True

    @property
    def active(self) -> bool:
        return self.enabled and self.phi is not None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    max_seq_len: int = 1 << 20
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder_layers: int = 0     # enc-dec only
    sliding_window: int = 0     # 0 = full attention; >0 = sliding window (hybrid)
    frontend: Optional[str] = None  # None | audio | vision  (stub frontends)
    # T1 config
    softmax_phi: SoftmaxPhiConfig = dataclasses.field(default_factory=SoftmaxPhiConfig)
    # dtypes
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # source annotation (public literature reference)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0 and self.family != "ssm":
            raise ValueError(
                f"{self.name}: num_heads={self.num_heads} not divisible by "
                f"num_kv_heads={self.num_kv_heads}"
            )

    # -- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM/hybrid/windowed)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_softmax_attention(self) -> bool:
        return self.family != "ssm"

    def param_count(self) -> int:
        """Analytical parameter count (embedding + per-layer + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d
        head = 0 if self.tie_embeddings else v * d
        per_layer = 0
        if self.family in ("dense", "moe", "hybrid", "encdec"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                attn += self.q_dim + 2 * self.kv_dim
            per_layer += attn
        if self.family == "moe":
            assert self.moe is not None
            gates = 3 if self.activation in ("swiglu", "geglu") else 2
            per_layer += self.moe.num_experts * gates * d * f + d * self.moe.num_experts
        else:
            gates = 3 if self.activation in ("swiglu", "geglu") else 2
            per_layer += gates * d * f
        if self.family == "ssm":
            assert self.ssm is not None
            # rwkv6: r,k,v,g,o projections + time-mix lora + decay params
            per_layer += 5 * d * d + 2 * d * self.ssm.head_dim + 4 * d
        if self.family == "hybrid":
            assert self.ssm is not None
            # mamba head in/out projections (parallel to attention)
            inner = self.ssm.expand * d
            per_layer += d * inner * 2 + inner * self.ssm.state_size * 2 + inner
        per_layer += 2 * d  # norms
        n_layers = self.num_layers + self.encoder_layers
        return emb + head + n_layers * per_layer

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        total = self.param_count()
        gates = 3 if self.activation in ("swiglu", "geglu") else 2
        expert_p = gates * self.d_model * self.d_ff
        inactive = (self.moe.num_experts - self.moe.num_experts_per_tok) * expert_p
        return total - self.num_layers * inactive


# ---------------------------------------------------------------------------
# Shapes (the assigned input-shape cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(model: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells that are well-defined for this arch.

    ``long_500k`` requires sub-quadratic attention — skipped for pure
    full-attention archs per the assignment (recorded in DESIGN.md §4).
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if model.is_subquadratic:
        out.append(LONG_500K)
    return out


# ---------------------------------------------------------------------------
# Mesh / run
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Knobs for a training/serving run (also the perf-hillclimb surface)."""

    microbatch: int = 0              # 0 = no gradient accumulation
    remat: str = "selective"         # none | selective | full
    # kernel dispatch: a repro.core.plan.ExecutionPlan (None = untuned
    # default); hosts with hard constraints override knobs on top of it
    # (the dry-run forces backend="xla", fallback=False)
    plan: Optional[object] = None
    sync_softmax: bool = False       # force the pre-T1 synchronized scheme
    seq_shard_attention: bool = True  # T1-enabled split-KV decode sharding
    zero1: bool = True               # shard optimizer state over data axis
    grad_compression: str = "none"   # none | int8_ef
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    # serving
    max_decode_steps: int = 32
    temperature: float = 0.0
    # shape-dependent scheduling knobs used by the perf loop
    # (decode block_k lives in the plan: plan.attention_decode.block_k)
    flat_gemm_bn: int = 0            # 0 = auto (cost model picks)
    vocab_chunk: int = 0             # 0 = no chunking of the LM head / loss
