"""Write-time quantization of block-paged KV — the pool-side half of the
kv_dtype subsystem (:mod:`repro.kernels.quant` holds the elementwise code
math; this module owns the page/step pool algebra).

Storage layout: alongside the code pools (NP, PS, HK, D) in the code
dtype, each of K and V carries a parallel f32 *step pool* (NP, HK) — one
symmetric scale per (page, kv head). Both ride in the cache pytree as
extra leaves (``k_scale`` / ``v_scale``), so every page-indexed bulk op
the engine already has (COW page copy, tier demotion gather, promotion
scatter) moves scales with slabs for free via tree mapping.

The scatter below is the quantized twin of the layers' bf16
``_paged_scatter_chunk``: appended tokens land as codes, and the step of
every touched page is the running amax/qmax over everything written to it
while live. Two properties make this deterministic and safe across page
reuse, chunk partitioning, and COW sharing:

  * **enters-at-zero reset** — a write that covers a page's position 0
    (i.e. the page's first token in this sequence) zeroes the page's step
    first. Fresh pages are always first written at their position 0, so a
    reused physical page can never inherit a stale step (or stale codes:
    the rescale ratio from a zero step launders them to zero codes).
  * **monotone rescale** — when a later write raises a page's amax, the
    page's existing codes are re-expressed under the new step
    (``rescale_codes``); a ratio of exactly 1 is a bitwise no-op, so
    pages whose amax didn't move are untouched.

Codes are therefore a pure function of (page content, write partition):
for page-aligned writes (prefill chunks with chunk % page_size == 0, and
every page written by exactly one chunk) the codes equal one-shot
quantization of the full page, making greedy decode bitwise identical
across {gather, fused, grouped} x {sharing on/off} x {tier round-trip}
at a fixed write history. Token-by-token decode appends may double-round
relative to a chunked replay of the same tokens — within the dtype
tolerance the plan's logits-closeness guard enforces.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import quant

# cache-pytree leaf names for the step pools (present iff quantized)
K_SCALE = "k_scale"
V_SCALE = "v_scale"


def cache_is_quantized(cache: dict) -> bool:
    return K_SCALE in cache


def scatter_chunk_quantized(codes, steps, new, block_tables, lengths,
                            chunk_lens, spec: quant.QuantSpec):
    """Append a (possibly ragged) token chunk into quantized page pools.

    codes:  (NP, PS, HK, D) code pool (one layer's K or V)
    steps:  (NP, HK) f32 step pool
    new:    (B, C, HK, D) full-precision values; row b contributes its
            first chunk_lens[b] tokens at positions lengths[b]..
    block_tables: (B, NB) logical->physical page map
    Returns (codes, steps) updated. Invalid/out-of-span lanes scatter to
    the sentinel index NP and drop, mirroring the bf16 scatter.
    """
    np_, ps = codes.shape[0], codes.shape[1]
    b, c = new.shape[:2]
    nb = block_tables.shape[1]

    pos = lengths[:, None] + jnp.arange(c)[None, :]            # (B, C)
    valid = jnp.arange(c)[None, :] < chunk_lens[:, None]
    page = jnp.clip(pos // ps, 0, nb - 1)
    phys = jnp.take_along_axis(block_tables, page, axis=1)
    phys = jnp.where(valid, phys, np_)

    # logical pages this write can touch: static span bound
    nspan = (c + ps - 2) // ps + 1
    span_log = (lengths // ps)[:, None] + jnp.arange(nspan)[None, :]
    end = lengths + chunk_lens
    touched = ((span_log * ps < end[:, None]) & (chunk_lens[:, None] > 0)
               & (span_log < nb))
    span_phys = jnp.take_along_axis(
        block_tables, jnp.clip(span_log, 0, nb - 1), axis=1)
    span_phys = jnp.where(touched, span_phys, np_)             # (B, nspan)
    span_safe = jnp.clip(span_phys, 0, np_ - 1)

    # 1) enters-at-zero reset: page's position 0 falls inside the write
    entered = (span_log * ps >= lengths[:, None]) & touched
    steps = steps.at[jnp.where(entered, span_phys, np_)].set(
        0.0, mode="drop")

    # 2) each touched page's step as its current codes were encoded
    old_step = steps[span_safe]                                # (B,S,HK)

    # 3) fold this chunk's per-token amax into the step pool (scatter-max
    # is order-free, so partitioning tokens across chunks can't change
    # the final step of a page)
    contrib = jnp.max(jnp.abs(new.astype(jnp.float32)), axis=-1) / spec.qmax
    contrib = jnp.where(valid[..., None], contrib, 0.0)        # (B,C,HK)
    steps = steps.at[phys].max(contrib, mode="drop")

    # 4) the settled step per touched page / per appended token
    new_step = steps[span_safe]                                # (B,S,HK)
    tok_step = steps[jnp.clip(phys, 0, np_ - 1)]               # (B,C,HK)

    # 5) re-express each touched page's existing codes under its new step
    # (ratio 1 -> bitwise no-op; old_step 0 -> stale codes launder to 0)
    old_codes = codes[span_safe]                       # (B,S,PS,HK,D)
    requant = quant.rescale_codes(
        old_codes, old_step[:, :, None, :], new_step[:, :, None, :], spec)

    # 6) write rescaled pages back, then 7) the new tokens on top
    codes = codes.at[span_phys].set(requant, mode="drop")
    codes = codes.at[phys, pos % ps].set(
        quant.encode(new, tok_step, spec), mode="drop")
    return codes, steps


# ---------------------------------------------------------------------------
# Whole-page helpers (tests, benchmarks, oracles)
# ---------------------------------------------------------------------------


def quantize_pages(x, spec: quant.QuantSpec):
    """One-shot quantization of full page slabs.

    x: (..., PS, HK, D) -> (codes same shape in code dtype, steps (..., HK)).
    Matches what the scatter above produces for a page written in a single
    page-aligned chunk.
    """
    step = quant.compute_step(x, spec, axes=(-3, -1))
    return quant.encode(x, step[..., None, :], spec), step


def dequantize_pages(codes, steps):
    """f32 view of quantized page slabs: codes (..., PS, HK, D) * steps."""
    return quant.decode(codes, steps[..., None, :])
