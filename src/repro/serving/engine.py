"""Streaming continuous-batching engine: pluggable scheduling, lazy paged
KV growth, preemption, and one cache-agnostic model surface.

The serving realization of the paper's heuristic-dataflow argument
(Sec. 5): throughput comes from *adapting* to input dynamics, so the
engine's request lifecycle, memory discipline, and admission policy are
all first-class and swappable:

  * **One model surface, two KV layouts.** The engine holds a single
    :class:`~repro.models.kvlayout.KVLayout` (``DenseLayout`` slot cache
    or ``PagedLayout`` block pool) and exactly one jitted
    ``prefill_chunk``/``decode_step`` pair; the layout's optional
    block-table operand (``slots.block_tables()``, ``None`` for dense)
    selects the addressing discipline inside the model. There is no
    dense/paged code fork anywhere in the tick loop.

  * **Request lifecycle.** Each submission is a
    :class:`~repro.serving.request.RequestState` walking WAITING →
    PREFILLING → RUNNING → FINISHED``{stop,length,abort}``, with
    PREEMPTED as the detour back to the queue. Sampling knobs ride in an
    immutable :class:`~repro.serving.request.SamplingParams` (temperature
    / top-k / top-p / per-request seed / stop tokens with explicit
    ``include_stop``), and every request owns a private PRNG key — no
    request's sampling order can perturb another's.

  * **Lazy pages + preemption.** Paged admission reserves pages only for
    the tokens about to be prefilled; each decode tick grows tables
    page-by-page. When the (possibly overcommitted) pool runs dry, the
    :class:`~repro.serving.scheduler.Scheduler` picks a victim: its pages
    are freed and its state re-queued, and on re-admission the engine
    re-prefills ``prompt + generated`` — block tables make the eviction
    relocation-free, and the rebuilt KV is exactly what an uninterrupted
    run would hold, so greedy outputs are preemption-invariant.

  * **Prefix sharing + copy-on-write.** With ``prefix_sharing=True``
    (paged only), admission consults a
    :class:`~repro.serving.prefix.PrefixIndex` mapping page-aligned
    token-chunk hash chains to live pages: a request whose prompt prefix
    is already resident bumps refcounts instead of allocating, and
    prefills only the unshared suffix. Shared pages are immutable — the
    first write into one forks it (fresh page + device slab copy +
    block-table patch), a victim's release only drops refs (surviving
    sharers keep the pages), and chunk boundaries stay on the share-less
    grid, so greedy outputs are bit-identical with sharing on or off.

  * **Tiered KV + session cache.** With ``host_pages``/``session_cache``
    (paged + sharing only), the pool becomes tier 0 of a memory
    hierarchy (:mod:`repro.serving.tiers`): retiring or preempting a
    sequence *retains* its full KV pages in a tier-0 session set instead
    of freeing them, pool pressure demotes those pages host-ward (one
    bulk device→host gather per reclaim batch), and a returning
    conversation whose prefix matches a demoted span *promotes* the
    slabs back (one bulk host→device scatter before its prefill) —
    re-prefilling only what was truly evicted. Whether promoting beats
    re-prefilling is the plan-tuned ``PagedPlan.swap_threshold``
    (:func:`repro.core.dispatch.find_swap_threshold`). Demoted bytes are
    the originally computed bytes, so resumed decode stays bit-identical
    with never-preempted and re-prefilled runs.

  * **One dispatch surface.** Every kernel decision — GEMM routing,
    softmax scheme, decode ``block_k``, backend — rides in the single
    ``plan=`` operand (:class:`~repro.core.plan.ExecutionPlan`, tuned
    offline by :func:`repro.core.plan.tune`); the engine never consults
    per-op flags, and plans change which kernel runs, never the tokens.

  * **Streaming surface.** ``generate(prompt, params)`` yields
    :class:`~repro.serving.request.TokenEvent` as ticks produce them,
    ``abort(rid)`` cancels at any phase, and the classic blocking
    ``run(requests) -> dict`` is a thin loop over ``submit``/``step``.

Prefill remains chunked + batched for dense-KV families (every admitted
prompt streams through ``api.prefill_chunk`` in fixed-size chunks, the
whole admission wave in one ``(num_slots, chunk)`` call) and batched
single-shot for recurrent/ring families; decode runs the whole slot batch
every tick (continuous batching), keeping the decode-phase GEMMs at
M = num_slots — the regime the paper's T2/T3 optimize.

With a paged cache and ``plan.paged.gather_chunk == "fused"``, waves whose
prompts reach the tuned ``fused_threshold`` run the fused chunk-attention
discipline: the block-table operand is bounded to a bucketed
O(resident pages) width per step (``_chunk_tables``), the Pallas backend
reads K/V pages in place through the fused chunk kernel, and the XLA
backend's gather shrinks to the bounded width — bitwise identical to the
full gather (trailing masked pages contribute exact zeros), so greedy
outputs match across {dense, gather, fused} × {sharing on/off}.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.plan import (DEFAULT_PLAN, FUSION_MODES, KV_DTYPES,
                             WEIGHT_DTYPES, ExecutionPlan)
from repro.kernels import quant
from repro.models import wquant
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout, KVLayout, PagedLayout, \
    pages_for, pow2_bucket
from repro.models.layers import LayerCtx
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.kvcache import SlotManager
from repro.serving.prefix import PrefixIndex
from repro.serving.tiers import TieredPool
from repro.serving.request import (FinishReason, Phase, RequestState,
                                   SamplingParams, TokenEvent)
from repro.serving.sampling import sample
from repro.serving.scheduler import Scheduler, get_scheduler

PROMPT_BUCKET = 64
DEFAULT_PREFILL_CHUNK = 64
DEFAULT_PAGE_SIZE = 64

PromptLike = Union[np.ndarray, Sequence[int]]


@dataclasses.dataclass
class EngineStats:
    """Counters for the CLI summary line and the scheduler benchmarks.
    (Tick count lives on ``Engine.ticks`` — the loop's one clock.)"""

    admitted: int = 0
    finished: int = 0
    aborted: int = 0
    preemptions: int = 0
    peak_pages_used: int = 0
    # prefix sharing (all zero unless Engine(prefix_sharing=True))
    shared_prefix_pages: int = 0     # page mappings served by refcount
    #                                  bumps instead of fresh allocations
    saved_prefill_tokens: int = 0    # prompt positions admission skipped
    #                                  because their KV was already resident
    cow_forks: int = 0               # shared pages privatized by a write
    # grouped decode (plan.paged.decode_group == "grouped")
    grouped_requests: int = 0        # decode-row ticks served through a
    #                                  shared-prefix group
    prefix_kv_bytes_saved: int = 0   # prefix KV bytes read once per group
    #                                  instead of once per member
    # tiered KV / session cache (all zero without host_pages/session_cache)
    demoted_pages: int = 0           # pages pushed device→host(→disk)
    #                                  instead of being discarded
    promoted_pages: int = 0          # demoted pages copied back to fresh
    #                                  tier-0 pages at re-admission
    session_hits: int = 0            # admissions that re-mapped at least
    #                                  one retained session page (tier-0
    #                                  refcount bump or promotion)
    host_evicted_pages: int = 0      # pages that fell off the bottom tier
    #                                  (KV lost; those spans re-prefill)
    # quantized KV pages (zero / bf16-sized unless kv_dtype != "bf16")
    kv_page_bytes: int = 0           # one page's K+V slab across all layers
    #                                  (code pools + scale rows as stored)
    kv_bytes_decode_read: int = 0    # cumulative KV bytes decode ticks
    #                                  streamed (resident pages x slab
    #                                  bytes) — the paper's decode
    #                                  bandwidth term, at stored width
    # quantized GEMM weights (bf16-sized unless weight_dtype != "bf16")
    weight_bytes_decode_read: int = 0  # cumulative GEMM weight bytes
    #                                  decode ticks streamed: every layer's
    #                                  projection leaves once per tick,
    #                                  codes + scales at stored width
    #                                  (embedding/lm_head excluded — not
    #                                  per-layer streams)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 8,
        max_seq: int = 2048,
        cache_kind: str = "dense",
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = DEFAULT_PREFILL_CHUNK,
        scheduler: Union[str, Scheduler] = "fcfs",
        plan: Optional[ExecutionPlan] = None,
        kv_dtype: Optional[str] = None,
        weight_dtype: Optional[str] = None,
        decode_fusion: Optional[str] = None,
        prefix_sharing: bool = False,
        host_pages: Optional[int] = None,
        session_cache: Optional[bool] = None,
        disk_dir: Optional[str] = None,
        disk_pages: int = 0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.plan = plan if plan is not None else DEFAULT_PLAN
        # decode-stage fusion granularity: explicit arg wins, else the
        # plan's tuned knob rides along untouched (same precedence as
        # kv_dtype). The override lands *in the plan* because the model
        # stages read ctx.plan.decode_fusion at trace time.
        if decode_fusion is not None:
            if decode_fusion not in FUSION_MODES:
                raise ValueError(
                    f"decode_fusion {decode_fusion!r} not in {FUSION_MODES}")
            self.plan = dataclasses.replace(
                self.plan,
                decode_fusion=dataclasses.replace(
                    self.plan.decode_fusion, granularity=decode_fusion))
        self.decode_fusion = self.plan.decode_fusion.granularity
        # GEMM weight storage precision: explicit arg wins, else the
        # plan's tuned matmul.weight_dtype rides along (same precedence
        # as kv_dtype/decode_fusion). The resolved value lands in the
        # plan before LayerCtx so describe()/downstream readers agree;
        # the kernels themselves key off the (codes, scale) leaf
        # structure, not the knob.
        if weight_dtype is None:
            weight_dtype = getattr(self.plan.matmul, "weight_dtype", "bf16")
        if weight_dtype not in WEIGHT_DTYPES:
            raise ValueError(
                f"weight_dtype {weight_dtype!r} not in {WEIGHT_DTYPES}")
        if weight_dtype == "fp8" and not quant.fp8_supported():
            raise ValueError(
                "weight_dtype='fp8' needs ml_dtypes float8_e4m3fn; "
                "use 'int8' on this runtime")
        if weight_dtype != self.plan.matmul.weight_dtype:
            self.plan = dataclasses.replace(
                self.plan,
                matmul=dataclasses.replace(self.plan.matmul,
                                           weight_dtype=weight_dtype))
        self.weight_dtype = weight_dtype
        self.ctx = LayerCtx(cfg=cfg, plan=self.plan)
        # quantize-at-load: convert each GEMM weight leaf to a
        # (codes, scale) pair once, before any trace sees the params.
        # bf16 leaves the pytree untouched (the bitwise path).
        if weight_dtype != "bf16":
            params = wquant.quantize_params(
                params, quant.spec_for(weight_dtype))
        self.params = params
        # one decode tick's GEMM weight stream, at stored width (codes +
        # scales; embedding/lm_head excluded — not per-layer streams)
        self._weight_bytes_per_tick = (
            wquant.gemm_weight_bytes(params)
            if isinstance(params, dict) else 0)
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.scheduler = get_scheduler(scheduler)
        # chunked prefill needs the chunk-append model path (dense-KV
        # families); others fall back to batched single-shot prefill.
        # prefill_chunk=None adopts the plan's tuned chunk size.
        if prefill_chunk is None:
            prefill_chunk = self.plan.paged.chunk_block
        self.prefill_chunk = (
            prefill_chunk if self.api.supports_chunked_prefill else 0)

        # KV page storage precision: explicit arg wins, else the plan's
        # tuned kv_dtype (paged engines only — a dense engine never reads
        # plan.paged). Quantized pools need the paged layout: the
        # per-(page, head) scale rows are page-pool leaves.
        if kv_dtype is None:
            kv_dtype = (getattr(self.plan.paged, "kv_dtype", "bf16")
                        if cache_kind == "paged" else "bf16")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype {kv_dtype!r} not in {KV_DTYPES}")
        if kv_dtype != "bf16":
            if cache_kind != "paged":
                raise ValueError(
                    "kv_dtype quantization stores per-page scales in the "
                    "block pool; use cache_kind='paged'")
            if kv_dtype == "fp8" and not quant.fp8_supported():
                raise ValueError(
                    "kv_dtype='fp8' needs ml_dtypes float8_e4m3fn; "
                    "use 'int8' on this runtime")
        self.kv_dtype = kv_dtype

        # tiered KV store: any of the knobs turns the hierarchy on
        tiered = (host_pages is not None or disk_pages > 0
                  or bool(session_cache))
        self.layout: KVLayout
        self.prefix: Optional[PrefixIndex] = None
        self.tiers: Optional[TieredPool] = None
        # retain finished sequences' KV in the session cache? defaults to
        # on whenever the hierarchy exists (the session cache is its
        # point); session_cache=False keeps preemption-demotion only
        self.session_cache = (tiered if session_cache is None
                              else bool(session_cache))
        if cache_kind == "dense":
            if prefix_sharing:
                raise ValueError(
                    "prefix_sharing needs refcounted pages; "
                    "use cache_kind='paged'")
            if tiered:
                raise ValueError(
                    "tiered KV (host_pages/session_cache/disk_pages) "
                    "needs cache_kind='paged'")
            self.layout = DenseLayout(num_slots, max_seq)
            self.slots: SlotManager = SlotManager(num_slots, max_seq)
            self.pool = None
        elif cache_kind == "paged":
            if not self.api.supports_paged:
                raise ValueError(
                    f"family {cfg.family!r} has no paged-KV path "
                    "(recurrent/ring state caches); use cache_kind='dense'")
            if not self.prefill_chunk:
                raise ValueError(
                    "cache_kind='paged' requires chunked prefill "
                    "(prefill_chunk > 0)")
            if prefix_sharing and page_size % self.prefill_chunk:
                # shared prefixes are page-aligned; keeping every prefill
                # chunk boundary on the same global c-grid as a share-less
                # run is what makes outputs bit-identical (fp reductions
                # split at identical positions), and that needs c | PS
                raise ValueError(
                    f"prefix_sharing requires page_size ({page_size}) to "
                    f"be a multiple of prefill_chunk ({self.prefill_chunk})")
            # default pool = same KV bytes as the dense cache; size it
            # smaller to overcommit (lazy growth then preempts on dry pool)
            pool = BlockPool(
                num_pages if num_pages is not None
                else num_slots * pages_for(max_seq, page_size),
                page_size,
            )
            self.layout = PagedLayout(pool.num_pages, page_size, kv_dtype)
            if prefix_sharing:
                self.prefix = PrefixIndex(page_size)
            if tiered:
                if self.prefix is None:
                    raise ValueError(
                        "tiered KV needs prefix_sharing=True — the "
                        "prefix index is the cross-tier map that makes "
                        "retained/demoted pages matchable")
                self.tiers = TieredPool(
                    host_pages if host_pages is not None else 0,
                    index=self.prefix,
                    disk_dir=disk_dir, disk_pages=disk_pages)
            self.slots = PagedSlotManager(num_slots, max_seq, pool,
                                          prefix_index=self.prefix,
                                          tiers=self.tiers)
            if self.tiers is not None:
                self.slots.swap_threshold = self.plan.paged.swap_threshold
                self.slots.reclaim_cb = self._reclaim_session
            self.pool = pool
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")
        self.cache_kind = cache_kind
        self.cache = self.api.init_cache(self.layout)

        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self.requests: dict[int, RequestState] = {}
        self.waiting: list[RequestState] = []
        self.by_slot: dict[int, RequestState] = {}
        self.stats = EngineStats()
        self.ticks = 0
        self._next_rid = 0
        self._arrival = 0

        # the single jitted pair: the layout's block-table operand (None
        # for dense) is just another argument, so dense and paged engines
        # trace the same lambdas
        self._decode = jax.jit(
            lambda p, t, c, bt, le, po: self.api.decode_step(
                self.ctx, p, t, c, le, block_tables=bt, positions=po),
            donate_argnums=(2,),
        )
        self._chunk = jax.jit(
            lambda p, t, cl, c, bt, le: self.api.prefill_chunk(
                self.ctx, p, t, cl, c, le, block_tables=bt),
            donate_argnums=(3,),
        ) if self.prefill_chunk else None
        # COW fork: copy one page's (layers, page_size, kv_heads, head_dim)
        # slab to a privately owned destination page (donated in-place
        # update; src/dst trace as scalars so every fork reuses one
        # compile)
        self._copy_page = jax.jit(
            lambda c, src, dst: jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), c),
            donate_argnums=(0,),
        ) if cache_kind == "paged" else None
        # tiered promotion: scatter a batch of host slabs (stacked to
        # (layers, n, page_size, kv_heads, head_dim) per leaf) into fresh
        # tier-0 pages in one donated update; padding rows carry the OOB
        # sentinel destination and are dropped, so slab batches share a
        # pow2 family of compiles
        self._promote_upload = jax.jit(
            lambda c, s, d: jax.tree.map(
                lambda a, b: a.at[:, d].set(
                    b.astype(a.dtype), mode="drop"), c, s),
            donate_argnums=(0,),
        ) if self.tiers is not None else None
        # prefix-shared grouped decode: when the tuned plan asks for it
        # (and refcounted sharing is on so groups can exist), decode ticks
        # with a qualifying group dispatch through a second jitted lambda
        # that threads the DecodeGroups operand down to the attention op
        self._group_decode = (
            cache_kind == "paged" and prefix_sharing
            and self.plan.paged.decode_group == "grouped")
        self._decode_grouped = jax.jit(
            lambda p, t, c, bt, le, gr, po: self.api.decode_step(
                self.ctx, p, t, c, le, block_tables=bt, decode_groups=gr,
                positions=po),
            donate_argnums=(2,),
        ) if self._group_decode else None
        # one page's K+V slab across all layers — the unit of both the
        # COW copy and the grouped-decode bytes-saved accounting
        self._kv_bytes_per_page = (
            sum(a.nbytes for a in jax.tree.leaves(self.cache))
            // self.pool.num_pages) if cache_kind == "paged" else 0
        self.stats.kv_page_bytes = self._kv_bytes_per_page
        self._prefill_cache = {}  # bucketed P -> jitted batched prefill
        # last-uploaded device copies of the small int operands the chunk
        # loop would otherwise re-upload every step (chunk_lens is usually
        # identical across a wave's steps; lengths only moves wave rows)
        self._operand_cache: dict[str, tuple[bytes, jax.Array]] = {}

    # -- public API -----------------------------------------------------------

    def submit(self, prompt: PromptLike,
               params: Optional[SamplingParams] = None,
               *, rid: Optional[int] = None) -> int:
        """Queue a request; returns its id (auto-assigned if not given).

        Unservable requests are rejected here, not mid-admission: a raise
        inside the admission wave would leave already-slotted batch-mates
        half admitted (slots claimed, no prefill).
        """
        params = params if params is not None else SamplingParams()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        worst = len(prompt) + params.max_new_tokens
        if worst > self.max_seq:
            raise ValueError(
                f"request needs {worst} positions > max_seq {self.max_seq}")
        if self.pool is not None and (
                pages_for(worst, self.pool.page_size) > self.pool.num_pages):
            raise ValueError(
                f"request needs {pages_for(worst, self.pool.page_size)} "
                f"pages > pool size {self.pool.num_pages} "
                f"(page_size {self.pool.page_size})")
        if rid is None:
            while self._next_rid in self.requests:
                self._next_rid += 1
            rid = self._next_rid
            self._next_rid += 1
        elif rid in self.requests:
            raise ValueError(f"request id {rid} already submitted")
        key = (jax.random.PRNGKey(params.seed) if params.seed is not None
               else jax.random.fold_in(self._base_key, rid))
        state = RequestState(
            rid=rid, prompt=prompt,
            params=params, arrival=self._arrival, key=key,
            submit_time=time.perf_counter())
        self._arrival += 1
        self.requests[rid] = state
        self.waiting.append(state)
        return rid

    def generate(self, prompt: PromptLike,
                 params: Optional[SamplingParams] = None,
                 *, rid: Optional[int] = None) -> Iterator[TokenEvent]:
        """Stream one request: submit it and yield its ``TokenEvent``s as
        engine ticks produce them (driving the shared tick loop, so
        concurrent submissions keep decoding alongside). The final event
        has ``finished=True`` and a ``finish_reason``; aborting mid-stream
        ends the iterator with an ``abort`` event."""
        rid = self.submit(prompt, params, rid=rid)
        state = self.requests[rid]
        cursor = 0
        while True:
            while cursor < len(state.events):
                ev = state.events[cursor]
                cursor += 1
                yield ev
                if ev.finished:
                    return
            if state.finished:
                return   # finished without a terminal event (defensive)
            self.step()

    def abort(self, rid: int) -> bool:
        """Cancel a request in any phase; frees its slot/pages at once.
        Returns False if unknown or already finished."""
        state = self.requests.get(rid)
        if state is None or state.finished:
            return False
        if state.slot is not None:
            self.by_slot.pop(state.slot, None)
            self.slots.release(state.slot)
        if state in self.waiting:
            self.waiting.remove(state)
        state.finish(FinishReason.ABORT)
        state.events.append(TokenEvent(
            rid, None, state.generated, finished=True,
            finish_reason=FinishReason.ABORT))
        self.stats.aborted += 1
        return True

    def finish_reason(self, rid: int) -> Optional[FinishReason]:
        return self.requests[rid].finish_reason

    def evict(self, rid: int) -> list[int]:
        """Drop a *finished* request's retained state (tokens, events,
        prompt) and return its tokens. A long-lived server must call this
        (or ``evict_finished``) after consuming results — the engine keeps
        every RequestState for post-run inspection and would otherwise
        grow without bound.

        This does **not** discard the conversation's KV: with the session
        cache on, the finished sequence's pages were already retained at
        retire time (tier-0 session set, demoted host-ward under pool
        pressure), so evicting the bookkeeping record leaves the prefix
        matchable for the conversation's next turn."""
        state = self.requests[rid]
        if not state.finished:
            raise ValueError(f"request {rid} is not finished; abort() it "
                             "first to evict early")
        del self.requests[rid]
        return state.tokens

    def evict_finished(self, *, flush: bool = False) -> int:
        """Evict every finished request's bookkeeping record; returns how
        many were dropped. Their KV stays cached (see :meth:`evict`);
        ``flush=True`` additionally demotes the whole tier-0 session
        cache host-ward right now (:meth:`flush_sessions`) instead of
        waiting for pool pressure."""
        done = [r for r, s in self.requests.items() if s.finished]
        for r in done:
            del self.requests[r]
        if flush:
            self.flush_sessions()
        return len(done)

    def run(self, requests, *, max_ticks: int = 10_000
            ) -> dict[int, list[int]]:
        """Blocking batch API on top of the streaming engine.

        ``requests`` is a list of prompts or ``(prompt, SamplingParams)``
        pairs; returns ``{rid: generated tokens}`` keyed by submission
        order."""
        rids = []
        for item in requests:
            if isinstance(item, tuple):
                prompt, sp = item
            else:
                prompt, sp = item, None
            rids.append(self.submit(prompt, sp))
        start = self.ticks
        while (any(not self.requests[r].finished for r in rids)
               and self.ticks - start < max_ticks):
            self.step()
        return {r: list(self.requests[r].tokens) for r in rids}

    # -- engine tick ------------------------------------------------------------

    def step(self) -> list[TokenEvent]:
        """Admit + prefill per the scheduler's order, then one decode tick
        (growing/preempting paged sequences first). Returns this tick's
        token events."""
        events = self._admit()
        if not self.by_slot:
            if self.waiting and not events:
                # no admission progress and nothing resident to free
                # resources for the queue — a true stall, not
                # back-pressure; surface it instead of spinning. (Events
                # with an empty batch = the whole admitted wave finished
                # during prefill; the freed slots admit the queue next
                # step.)
                raise RuntimeError(
                    "admission stalled: empty batch but "
                    f"{len(self.waiting)} requests cannot be admitted")
            return events
        events += self._decode_tick()
        self.ticks += 1
        return events

    # -- admission ---------------------------------------------------------------

    def _admit(self) -> list[TokenEvent]:
        """Offer slots (and prefill pages) to waiting requests in the
        scheduler's order; prefill the admitted wave in one batch.

        With prefix sharing, admission hands the slot manager the exact
        prefill tokens so the prefix index can map page-aligned shared
        prefixes onto live pages (refcount bumps instead of allocations).
        A request whose match includes pages *promised by an earlier
        request in this same wave* is assigned a later prefill level —
        the wave then prefills level by level, so shared pages are always
        written before any sharer computes attention over them.
        """
        if not self.waiting:
            return []
        admitted: list[tuple[int, RequestState]] = []
        levels: dict[int, int] = {}
        for state in self.scheduler.admission_order(self.waiting):
            toks = state.prefill_tokens()
            idx = self.slots.try_assign(
                state.rid, len(toks),
                max(state.params.max_new_tokens - state.generated, 1),
                tokens=toks if self.prefix is not None else None)
            if idx is None:
                if not self.scheduler.allow_skip:
                    break      # head-of-line blocking (FCFS no-starvation)
                continue
            state.phase = Phase.PREFILLING
            state.slot = idx
            self.by_slot[idx] = state
            admitted.append((idx, state))
            self.stats.admitted += 1
            levels[idx] = 0
            if self.prefix is not None:
                slot = self.slots.slots[idx]
                levels[idx] = slot.prefill_level
                # the COW-fork destination is private, not shared
                state.shared_len = slot.shared_len - (
                    self.pool.page_size if slot.pending_fork else 0)
                # refcount-bump pages only; promoted pages are fresh
                # allocations counted under promoted_pages instead
                self.stats.shared_prefix_pages += (
                    state.shared_len // self.pool.page_size
                    - len(slot.pending_promotions))
                self.stats.saved_prefill_tokens += \
                    self._chunk_start(idx, len(toks))
                if slot.session_mapped:
                    # re-mapped a retired/preempted session's retained KV
                    # (tier-0 refcount bump and/or promotion from host)
                    self.stats.session_hits += 1
                    slot.session_mapped = 0
        if not admitted:
            return []
        self.waiting = [s for s in self.waiting if s.slot is None]
        self._note_page_pressure()
        if self.tiers is not None:
            self._apply_pending_promotions(admitted)
        if self.prefix is not None:
            self._apply_pending_forks(admitted)
        if self.prefill_chunk:
            events: list[TokenEvent] = []
            for lv in sorted(set(levels.values())):
                events += self._prefill_chunked(
                    [(i, s) for i, s in admitted if levels[i] == lv])
            return events
        return self._prefill_batched(admitted)

    def _apply_pending_forks(
            self, admitted: list[tuple[int, RequestState]]) -> None:
        """Perform the slab copies admission promised: a fully-covered
        prompt forked its last shared page so the final-chunk re-run (the
        write that recovers the last-token logits) lands in a private
        copy. Sources are always committed pages, so copying before any
        prefill of this wave is safe."""
        for idx, _state in admitted:
            slot = self.slots.slots[idx]
            fork = getattr(slot, "pending_fork", None)
            if fork:
                src, dst = fork
                self.cache = self._copy_page(self.cache, src, dst)
                slot.pending_fork = None
                self.stats.cow_forks += 1

    def _apply_pending_promotions(
            self, admitted: list[tuple[int, RequestState]]) -> None:
        """Perform the host→device uploads admission promised: each
        promoted prefix page's slab (popped from the tiered store at
        match time) lands in its freshly allocated tier-0 page. One
        donated scatter for the whole wave's batch, before any fork or
        prefill of this wave reads those pages. The slabs hold the
        originally computed KV bytes, so the resumed sequence's attention
        reads are bit-identical to a never-demoted run's."""
        ups: list[tuple] = []           # (slab, dst_page)
        for idx, _state in admitted:
            slot = self.slots.slots[idx]
            if slot.pending_promotions:
                ups.extend(slot.pending_promotions)
                slot.pending_promotions = []
        if not ups:
            return
        n = len(ups)
        nb = pow2_bucket(n)
        dst = np.full((nb,), self.pool.num_pages, np.int32)  # pad = OOB
        dst[:n] = [d for _slab, d in ups]
        leaves, treedef = jax.tree.flatten(self.cache)
        stacked = []
        for j in range(len(leaves)):
            rows = [slab[j] for slab, _d in ups]
            rows += [rows[0]] * (nb - n)     # dropped via sentinel dst
            stacked.append(np.stack(rows, axis=1))
        self.cache = self._promote_upload(
            self.cache, jax.tree.unflatten(treedef, stacked),
            jnp.asarray(dst))
        self.stats.promoted_pages += n

    def _chunk_start(self, idx: int, n_prefill: int) -> int:
        """First position slot ``idx``'s chunked prefill must process.

        The shared prefix is skipped, except that at least the final
        prompt token must run (its logits seed decode). The start is
        floored to the global chunk grid so every chunk boundary matches
        a share-less run exactly — identical fp-reduction splits are what
        keep greedy outputs bit-identical with sharing on vs off (the
        re-run positions rewrite byte-identical KV, into the COW fork
        when they fall inside a shared page).
        """
        start = getattr(self.slots.slots[idx], "prefill_start", 0)
        if start <= 0:
            return 0
        start = min(start, max(n_prefill - 1, 0))
        return (start // self.prefill_chunk) * self.prefill_chunk

    # -- chunked + batched prefill (dense-KV families) -------------------------

    def _upload_i32(self, name: str, arr: np.ndarray) -> jax.Array:
        """Device copy of a small int operand, re-uploaded only when its
        contents changed since the previous call under the same name —
        the chunk loop's ``chunk_lens`` is usually identical across a
        wave's steps and would otherwise round-trip every step."""
        prev = self._operand_cache.get(name)
        key = arr.tobytes()
        if prev is not None and prev[0] == key:
            return prev[1]
        dev = jnp.asarray(arr)
        self._operand_cache[name] = (key, dev)
        return dev

    def _chunk_tables(self, fused: bool, hi: int):
        """Block-table operand for one chunk step.

        In the plan's fused chunk mode the dense table is sliced to a
        power-of-two page bound covering ``hi`` (the wave's highest
        position written or read this step), so the chunk op's KV side is
        O(resident pages) instead of O(max table width): the fused Pallas
        kernel grids over exactly those pages, and the XLA gather
        materializes only them. Trailing pages carry only causally-masked
        positions, so the truncation is bitwise-neutral (spectator rows
        whose resident KV exceeds the bound produce garbage either way —
        their logits are dropped and nothing is written). Bucketing keeps
        the number of distinct compiled shapes logarithmic.
        """
        bt = self.slots.block_tables()
        if bt is None or not fused:
            return bt
        full = self.slots.max_pages_per_seq
        bound = pow2_bucket(pages_for(hi, self.pool.page_size), hi=full)
        if bound >= full:
            return bt
        return bt[:, :bound]

    def _prefill_chunked(
            self, items: list[tuple[int, RequestState]]) -> list[TokenEvent]:
        """Stream all admitted prompts through the chunk-append path.

        Each step processes one ``(num_slots, chunk)`` call: admitted rows
        consume their next chunk, every other slot is a spectator
        (``chunk_lens == 0`` — nothing written). One compiled shape total
        in the dense-gather mode; the fused mode trades that for a
        log-bounded family of resident-bounded table widths
        (``_chunk_tables``) so admission stops paying O(max table width)
        KV materialization per step. Re-admitted (preempted) requests
        prefill ``prompt + generated``, rebuilding exactly the KV an
        uninterrupted run would hold — unless the prefix index still maps
        their prefix, in which case prefill starts at the first unshared
        chunk boundary (``_chunk_start``) and the shared pages are simply
        read through the block table.
        """
        c = self.prefill_chunk
        seqs = {idx: state.prefill_tokens() for idx, state in items}
        progress = {idx: self._chunk_start(idx, len(seqs[idx]))
                    for idx, _ in items}
        plens = {idx: max(len(seqs[idx]), 1) for idx, _ in items}
        # gather-vs-fused inflection by prompt length (plan-tuned): short
        # waves keep the one-compile full-width gather
        pp = self.plan.paged
        fused = (self.pool is not None and pp.gather_chunk == "fused"
                 and max(plens.values()) >= pp.fused_threshold)
        final_logits: dict[int, jax.Array] = {}
        n_steps = max(-(-(plens[idx] - progress[idx]) // c)
                      for idx, _ in items)
        for _ in range(n_steps):
            tokens = np.zeros((self.num_slots, c), np.int32)
            chunk_lens = np.zeros((self.num_slots,), np.int32)
            lengths = self.slots.lengths()
            hi = 0
            for idx, _state in items:
                done = progress[idx]
                cl = min(plens[idx] - done, c)
                if cl <= 0:
                    continue
                avail = min(max(len(seqs[idx]) - done, 0), cl)
                if avail:
                    tokens[idx, :avail] = seqs[idx][done:done + avail]
                chunk_lens[idx] = cl          # p=0 feeds one pad token
                lengths[idx] = done           # prefill progress, not final P
                hi = max(hi, done + cl)
            logits, self.cache = self._chunk(
                self.params, jnp.asarray(tokens),
                self._upload_i32("chunk_lens", chunk_lens),
                self.cache, self._chunk_tables(fused, hi),
                self._upload_i32("chunk_lengths", lengths))
            for idx, _state in items:
                if chunk_lens[idx]:
                    progress[idx] += int(chunk_lens[idx])
                    if progress[idx] == plens[idx]:
                        final_logits[idx] = logits[idx:idx + 1]
        for idx, _state in items:
            # full prompt pages now hold real KV: flip this slot's pending
            # index entries so later arrivals (and later levels of this
            # wave) may map them
            self.slots.commit_prefix(idx, seqs[idx])
        events = []
        for idx, state in items:
            tok = int(self._sample(final_logits[idx], state)[0])
            state.phase = Phase.RUNNING
            events.append(self._emit(idx, state, tok, wrote_kv=False))
        return events

    # -- batched single-shot prefill (recurrent/ring families) ------------------

    def _prefill_fn(self, padded: int):
        if padded not in self._prefill_cache:
            spec = self.api.cache_spec(
                DenseLayout(self.num_slots, self.max_seq))

            def fn(params, tokens, lengths):
                cache = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), spec)
                return self.api.prefill(
                    self.ctx, params, tokens, lengths, cache)

            self._prefill_cache[padded] = jax.jit(fn)
        return self._prefill_cache[padded]

    def _prefill_batched(
            self, items: list[tuple[int, RequestState]]) -> list[TokenEvent]:
        """One padded prefill call for the whole admission wave; each row's
        cache entry is inserted at its slot index afterwards. Prompts pad
        to a power-of-two bucket (min ``PROMPT_BUCKET``) so distinct tail
        lengths share a logarithmic family of compiles instead of
        re-jitting at every 64-token multiple."""
        seqs = {idx: state.prefill_tokens() for idx, state in items}
        pmax = max(len(s) for s in seqs.values())
        # never pad past what plain 64-multiple rounding could reach
        # (pmax <= max_seq is enforced at submit)
        padded = pow2_bucket(
            pmax, lo=PROMPT_BUCKET,
            hi=-(-self.max_seq // PROMPT_BUCKET) * PROMPT_BUCKET)
        toks = np.zeros((self.num_slots, padded), np.int32)
        lens = np.zeros((self.num_slots,), np.int32)
        for row, (idx, _state) in enumerate(items):
            toks[row, :len(seqs[idx])] = seqs[idx]
            lens[row] = len(seqs[idx])
        logits, cache_new = self._prefill_fn(padded)(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        events = []
        for row, (idx, state) in enumerate(items):
            row_cache = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1),
                cache_new)
            self.cache = jax.tree.map(
                lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), idx, axis=1),
                self.cache, row_cache,
            )
            tok = int(self._sample(logits[row:row + 1], state)[0])
            state.phase = Phase.RUNNING
            events.append(self._emit(idx, state, tok, wrote_kv=False))
        return events

    # -- decode ----------------------------------------------------------------

    def _grow_or_preempt(self) -> None:
        """Lazy page growth (and COW forks) for every resident sequence:
        each decode tick writes one KV position, so slot ``i`` must cover
        ``length + 1`` — and if the page holding position ``length`` is
        shared (refcount > 1), it must be forked before the scatter so
        the write can never leak into a prefix other sequences read.
        When the pool is dry (growth or fork), the scheduler names a
        victim — possibly the growing sequence itself, so e.g. FCFS
        really does evict the newest arrival rather than whichever old
        resident happens to share the tick. The victim's refs are dropped
        (shared pages survive through their other owners) and its state
        goes back to the queue (relocation-free — re-admission
        re-prefills through fresh block tables, re-mapping any shared
        prefix that survived)."""
        for idx, state in list(self.by_slot.items()):
            if self.by_slot.get(idx) is not state:
                continue                      # became a victim this tick
            while True:
                length = self.slots.slots[idx].length
                forks = None
                if self.slots.ensure(idx, length + 1):
                    forks = self.slots.fork_for_write(
                        idx, length, length + 1)
                if forks is not None:
                    for src, dst in forks:
                        self.cache = self._copy_page(self.cache, src, dst)
                        self.stats.cow_forks += 1
                    break
                self._refresh_shared_lens()
                victim = self.scheduler.pick_victim(list(self.by_slot.values()))
                if victim is None or (victim is state
                                      and len(self.by_slot) == 1):
                    # admission's whole-footprint bound makes a lone
                    # sequence always satisfiable — defensive only
                    raise RuntimeError(
                        "page pool exhausted with no preemptable victim")
                self._preempt(victim)
                if victim is state:
                    break                     # evicted itself; skip growth
        self._note_page_pressure()

    def _decode_tick(self) -> list[TokenEvent]:
        self._grow_or_preempt()
        if not self.by_slot:
            return []
        lengths = self.slots.lengths_device()
        positions = self.slots.positions_device()
        tokens = np.zeros((self.num_slots,), np.int32)
        for idx, state in self.by_slot.items():
            tokens[idx] = state.tokens[-1]
        gplan = self.slots.group_plan(
            self.plan.paged.group_threshold) if self._group_decode else None
        if gplan is not None:
            logits, self.cache = self._decode_grouped(
                self.params, jnp.asarray(tokens), self.cache,
                self.slots.block_tables(), lengths, gplan.operands(),
                positions)
            self.stats.grouped_requests += gplan.n_grouped
            self.stats.prefix_kv_bytes_saved += (
                gplan.pages_deduped * self._kv_bytes_per_page)
        else:
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                self.slots.block_tables(), lengths, positions)
        if self.pool is not None:
            # decode streams every resident page once per tick, at the
            # stored width — the term kv_dtype shrinks
            pages_read = sum(len(self.slots.slots[i].pages)
                             for i in self.by_slot)
            self.stats.kv_bytes_decode_read += (
                pages_read * self._kv_bytes_per_page)
        # every decode tick streams the full per-layer GEMM weight stack
        # once, at stored width — the term weight_dtype shrinks
        self.stats.weight_bytes_decode_read += self._weight_bytes_per_tick
        events = []
        for idx in list(self.by_slot):
            state = self.by_slot[idx]
            tok = int(self._sample(logits[idx:idx + 1], state)[0])
            events.append(self._emit(idx, state, tok))
        return events

    # -- tiered store dataflow (the only tier-crossing copies) -----------------

    def _gather_pages(self, pages: list[int]) -> dict[int, tuple]:
        """Bulk device→host copy of the named pages' KV slabs: one
        bucketed gather per cache leaf for the whole batch, returning
        ``{page: slab}`` where a slab is the per-leaf tuple of
        ``(layers, page_size, kv_heads, head_dim)`` numpy arrays the
        :class:`~repro.serving.tiers.TieredPool` stores."""
        if not pages:
            return {}
        n = len(pages)
        nb = pow2_bucket(n)
        idx = np.full((nb,), pages[0], np.int32)   # pad rows discarded
        idx[:n] = pages
        idxd = jnp.asarray(idx)
        host = [np.asarray(leaf[:, idxd])
                for leaf in jax.tree.leaves(self.cache)]
        return {p: tuple(np.ascontiguousarray(h[:, i]) for h in host)
                for i, p in enumerate(pages)}

    def _reclaim_session(self, need: int) -> bool:
        """Slot-manager callback when an allocation finds the pool dry:
        demote LRU session pages (device→host gather included) until
        ``need`` pages are free. The session cache never wins a page
        fight against live admission or growth."""
        if self.slots.session_pages() == 0:
            return False
        before = dataclasses.replace(self.tiers.stats)
        freed = self.slots.reclaim_session(max(need, 1), self._gather_pages)
        st = self.tiers.stats
        self.stats.demoted_pages += st.demoted - before.demoted
        self.stats.host_evicted_pages += st.evicted - before.evicted
        return freed >= need

    def flush_sessions(self) -> int:
        """Demote the *entire* tier-0 session cache host-ward now (one
        bulk gather), returning how many device pages were freed. The
        demand-driven path (:meth:`_reclaim_session`) makes this
        unnecessary in steady state; it exists for checkpoints and for
        benchmarks that want host-resident sessions without first
        running the pool dry."""
        if self.tiers is None or self.slots.session_pages() == 0:
            return 0
        before = dataclasses.replace(self.tiers.stats)
        freed = self.slots.reclaim_session(
            self.slots.session_pages(), self._gather_pages)
        st = self.tiers.stats
        self.stats.demoted_pages += st.demoted - before.demoted
        self.stats.host_evicted_pages += st.evicted - before.evicted
        return freed

    # -- bookkeeping -----------------------------------------------------------

    def _preempt(self, state: RequestState) -> None:
        idx = state.slot
        self.by_slot.pop(idx, None)
        if self.tiers is not None:
            # demote, don't discard: the victim's full KV pages move to
            # the session cache (demoted host-ward only under pressure),
            # so re-admission promotes instead of re-prefilling them
            length = self.slots.slots[idx].length
            self.slots.retain_session(
                idx, state.prefill_tokens()[:length])
        else:
            self.slots.release(idx)
        state.phase = Phase.PREEMPTED
        state.slot = None
        state.shared_len = 0          # recomputed if re-admission re-maps
        state.persistable_len = 0
        state.preemptions += 1
        self.stats.preemptions += 1
        self.waiting.append(state)

    def _sample(self, logits: jax.Array, state: RequestState) -> jax.Array:
        p = state.params
        return sample(
            logits, state.next_key(), temperature=p.temperature,
            top_k=p.top_k, top_p=p.top_p, vocab_size=self.cfg.vocab_size,
        )

    def _emit(self, idx: int, state: RequestState, tok: int,
              *, wrote_kv: bool = True) -> TokenEvent:
        """Account one sampled token: stop/budget checks, event record,
        slot release on finish. The stop token itself joins the output
        only when ``SamplingParams.include_stop`` asks for it, and never
        burns ``max_new_tokens`` budget."""
        p = state.params
        if state.first_token_time is None:
            state.first_token_time = time.perf_counter()
            state.first_token_tick = self.ticks
        if tok in p.stop_tokens:
            if p.include_stop:
                state.tokens.append(tok)
                self.slots.tick(idx, wrote_kv=wrote_kv)
            return self._retire(idx, state, FinishReason.STOP)
        state.tokens.append(tok)
        self.slots.tick(idx, wrote_kv=wrote_kv)
        if (state.generated >= p.max_new_tokens
                or self.slots.slots[idx].length >= self.max_seq):
            return self._retire(idx, state, FinishReason.LENGTH)
        ev = TokenEvent(state.rid, tok, state.generated - 1)
        state.events.append(ev)
        return ev

    def _retire(self, idx: int, state: RequestState,
                reason: FinishReason) -> TokenEvent:
        """Release the slot and record the terminal event. The event
        mirrors ``state.tokens`` exactly: it carries the last *kept*
        token (so a stop token excluded by ``include_stop=False`` never
        reaches the stream either), or ``token=None`` at the next index
        when the request ends without keeping one.

        With the session cache on, the finished sequence's full KV pages
        are retained (registered in the prefix index + held by the
        manager's session set) instead of freed — the conversation's next
        turn re-maps or promotes them rather than re-prefilling."""
        if self.tiers is not None and self.session_cache:
            length = self.slots.slots[idx].length
            self.slots.retain_session(
                idx, state.prefill_tokens()[:length])
        else:
            self.slots.release(idx)
        self.by_slot.pop(idx, None)
        state.finish(reason)
        self.stats.finished += 1
        if reason is FinishReason.STOP and not state.params.include_stop:
            ev = TokenEvent(state.rid, None, state.generated,
                            finished=True, finish_reason=reason)
        else:
            ev = TokenEvent(state.rid, state.tokens[-1] if state.tokens
                            else None, max(state.generated - 1, 0),
                            finished=True, finish_reason=reason)
        state.events.append(ev)
        return ev

    def _refresh_shared_lens(self) -> None:
        """Recompute every resident's ``shared_len`` from live refcounts
        right before the scheduler ranks victims: sharing drifts after
        admission (a leader finishing makes its follower the sole owner;
        a later arrival makes a loner's pages shared), and a stale signal
        would mis-rank eviction cost — ``exclusive_len`` must mean "pages
        an eviction actually reclaims" at the moment of the decision."""
        if self.prefix is None:
            return
        ps = self.pool.page_size
        for idx, state in self.by_slot.items():
            state.shared_len = ps * sum(
                1 for p in self.slots.slots[idx].pages
                if self.pool.refcount(p) > 1)
            if self.tiers is not None:
                # with a tiered store, preemption retains every full
                # page — so the re-admission cost signal is only the
                # partial tail past the last page boundary
                state.persistable_len = (
                    self.slots.slots[idx].length // ps) * ps

    def _note_page_pressure(self) -> None:
        if self.pool is not None:
            self.stats.peak_pages_used = max(
                self.stats.peak_pages_used, self.pool.used_pages)
