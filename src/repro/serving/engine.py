"""Continuous-batching inference engine (chunked/batched prefill + decode).

The serving realization of the paper's dataflow (Fig. 2), upgraded past the
static-allocation regime the paper argues against:

  * **KV storage** is either the classic dense ``(slots, max_seq)`` cache
    (``cache_kind="dense"``) or a **block-paged pool** shared by all
    sequences (``cache_kind="paged"``, see :mod:`repro.serving.blockpool`):
    fixed-size pages, per-sequence block tables, explicit free-list. Paging
    decouples admission from worst-case sequence length — the pool can be
    sized to *expected* occupancy instead of ``slots x max_seq``.

  * **Prefill** is chunked + batched for dense-KV families: every admitted
    prompt streams through the decode-shaped chunk path
    (``api.prefill_chunk``) in fixed-size chunks, and the whole admission
    batch rides in one ``(num_slots, chunk)`` call — a single compiled
    shape, instead of one ``jax.jit`` per (request, prompt-bucket).
    Families without a dense KV cache (ssm / hybrid ring / encdec) use a
    batched single-shot prefill (one padded call per admission wave).

  * **Decode** runs over the whole slot batch every tick; new requests
    claim slots (and pages) as soon as finished sequences release them, so
    decode batches stay full (continuous batching) and the decode-phase
    GEMMs stay at M = num_slots, the regime T2/T3 optimize.

Dense and paged engines are an apples-to-apples switch: with
``page_size`` dividing ``max_seq`` the paged gather view is bitwise
identical to the dense cache, so greedy outputs are token-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RunConfig
from repro.core.dispatch import DispatchTable
from repro.models.api import get_model
from repro.models.layers import LayerCtx
from repro.serving.blockpool import BlockPool, PagedSlotManager, pages_for
from repro.serving.kvcache import SlotManager
from repro.serving.sampling import sample

PROMPT_BUCKET = 64
DEFAULT_PREFILL_CHUNK = 64
DEFAULT_PAGE_SIZE = 64


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray               # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    eos_token: Optional[int] = None


@dataclasses.dataclass
class _Done:
    tokens: list


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 8,
        max_seq: int = 2048,
        cache_kind: str = "dense",
        page_size: int = DEFAULT_PAGE_SIZE,
        num_pages: Optional[int] = None,
        prefill_chunk: int = DEFAULT_PREFILL_CHUNK,
        table: Optional[DispatchTable] = None,
        use_pallas: bool = False,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.ctx = LayerCtx(cfg=cfg, table=table, use_pallas=use_pallas)
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.cache_kind = cache_kind
        # chunked prefill needs the chunk-append model path (dense-KV
        # families); others fall back to batched single-shot prefill
        self.prefill_chunk = (
            prefill_chunk if self.api.supports_chunked_prefill else 0)

        if cache_kind == "dense":
            self.slots: SlotManager = SlotManager(num_slots, max_seq)
            self.cache = self.api.init_cache(num_slots, max_seq)
        elif cache_kind == "paged":
            if not self.api.supports_paged:
                raise ValueError(
                    f"family {cfg.family!r} has no paged-KV path "
                    "(recurrent/ring state caches); use cache_kind='dense'")
            if not self.prefill_chunk:
                raise ValueError(
                    "cache_kind='paged' requires chunked prefill "
                    "(prefill_chunk > 0)")
            # default pool = same KV bytes as the dense cache; size it
            # smaller to overcommit (admission then queues on free pages)
            pool = BlockPool(
                num_pages if num_pages is not None
                else num_slots * pages_for(max_seq, page_size),
                page_size,
            )
            self.slots = PagedSlotManager(num_slots, max_seq, pool)
            self.pool = pool
            self.cache = self.api.init_paged_cache(pool.num_pages, page_size)
        else:
            raise ValueError(f"unknown cache_kind {cache_kind!r}")

        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.by_slot: dict[int, Request] = {}
        self.results: dict[int, _Done] = {}
        self.ticks = 0

        if cache_kind == "paged":
            self._decode = jax.jit(
                lambda p, t, c, bt, l: self.api.decode_step_paged(
                    self.ctx, p, t, c, bt, l),
                donate_argnums=(2,),
            )
            self._chunk = jax.jit(
                lambda p, t, cl, c, bt, l: self.api.prefill_chunk_paged(
                    self.ctx, p, t, cl, c, bt, l),
                donate_argnums=(3,),
            )
        else:
            self._decode = jax.jit(
                lambda p, t, c, l: self.api.decode_step(self.ctx, p, t, c, l),
                donate_argnums=(2,),
            )
            self._chunk = jax.jit(
                lambda p, t, cl, c, l: self.api.prefill_chunk(
                    self.ctx, p, t, cl, c, l),
                donate_argnums=(3,),
            ) if self.prefill_chunk else None
        self._prefill_cache = {}  # bucketed P -> jitted batched prefill

    # -- public API -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, requests: list[Request], *, max_ticks: int = 10_000
            ) -> dict[int, list[int]]:
        for r in requests:
            self.submit(r)
        while (self.queue or self.by_slot) and self.ticks < max_ticks:
            self.step()
        return {rid: d.tokens for rid, d in self.results.items()}

    # -- engine tick ------------------------------------------------------------

    def step(self) -> list[tuple[int, int]]:
        """Admit + prefill waiting requests, then one decode tick."""
        self._admit()
        if not self.by_slot:
            return []
        emitted = self._decode_tick()
        self.ticks += 1
        return emitted

    # -- internals ---------------------------------------------------------------

    def _admit(self) -> None:
        """Claim slots (and pages) for waiting requests; prefill the whole
        admission wave in one batch."""
        admitted: list[tuple[int, Request]] = []
        still_waiting = []
        for req in self.queue:
            idx = self.slots.try_assign(req.id, len(req.prompt),
                                        req.max_new_tokens)
            if idx is None:
                still_waiting.append(req)
                continue
            self.by_slot[idx] = req
            self.results[req.id] = _Done(tokens=[])
            admitted.append((idx, req))
        self.queue = still_waiting
        if not admitted:
            return
        if self.prefill_chunk:
            self._prefill_chunked(admitted)
        else:
            self._prefill_batched(admitted)

    # -- chunked + batched prefill (dense-KV families) -------------------------

    def _prefill_chunked(self, items: list[tuple[int, Request]]) -> None:
        """Stream all admitted prompts through the chunk-append path.

        Each step processes one ``(num_slots, chunk)`` call: admitted rows
        consume their next chunk, every other slot is a spectator
        (``chunk_lens == 0`` — nothing written). One compiled shape total.
        """
        c = self.prefill_chunk
        progress = {idx: 0 for idx, _ in items}
        plens = {idx: max(len(req.prompt), 1) for idx, req in items}
        final_logits: dict[int, jax.Array] = {}
        n_steps = -(-max(plens.values()) // c)
        for step in range(n_steps):
            tokens = np.zeros((self.num_slots, c), np.int32)
            chunk_lens = np.zeros((self.num_slots,), np.int32)
            lengths = self.slots.lengths()
            for idx, req in items:
                done = progress[idx]
                cl = min(plens[idx] - done, c)
                if cl <= 0:
                    continue
                avail = min(max(len(req.prompt) - done, 0), cl)
                if avail:
                    tokens[idx, :avail] = req.prompt[done:done + avail]
                chunk_lens[idx] = cl          # p=0 feeds one pad token
                lengths[idx] = done           # prefill progress, not final P
            args = [self.params, jnp.asarray(tokens), jnp.asarray(chunk_lens),
                    self.cache]
            if self.cache_kind == "paged":
                args.append(jnp.asarray(self.slots.block_tables()))
            args.append(jnp.asarray(lengths))
            logits, self.cache = self._chunk(*args)
            for idx, req in items:
                if chunk_lens[idx]:
                    progress[idx] += int(chunk_lens[idx])
                    if progress[idx] == plens[idx]:
                        final_logits[idx] = logits[idx:idx + 1]
        for idx, req in items:
            tok = int(self._sample(final_logits[idx], req)[0])
            self._emit(idx, req, tok, wrote_kv=False)

    # -- batched single-shot prefill (recurrent/ring families) ------------------

    def _prefill_fn(self, padded: int):
        if padded not in self._prefill_cache:
            spec = self.api.cache_spec(self.num_slots, self.max_seq)

            def fn(params, tokens, lengths):
                cache = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), spec)
                return self.api.prefill(
                    self.ctx, params, tokens, lengths, cache)

            self._prefill_cache[padded] = jax.jit(fn)
        return self._prefill_cache[padded]

    def _prefill_batched(self, items: list[tuple[int, Request]]) -> None:
        """One padded prefill call for the whole admission wave; each row's
        cache entry is inserted at its slot index afterwards."""
        pmax = max(len(req.prompt) for _, req in items)
        padded = -(-max(pmax, 1) // PROMPT_BUCKET) * PROMPT_BUCKET
        toks = np.zeros((self.num_slots, padded), np.int32)
        lens = np.zeros((self.num_slots,), np.int32)
        for row, (idx, req) in enumerate(items):
            toks[row, :len(req.prompt)] = req.prompt
            lens[row] = len(req.prompt)
        logits, cache_new = self._prefill_fn(padded)(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        for row, (idx, req) in enumerate(items):
            row_cache = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1),
                cache_new)
            self.cache = jax.tree.map(
                lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), idx, axis=1),
                self.cache, row_cache,
            )
            tok = int(self._sample(logits[row:row + 1], req)[0])
            self._emit(idx, req, tok, wrote_kv=False)

    # -- decode ----------------------------------------------------------------

    def _decode_tick(self) -> list[tuple[int, int]]:
        lengths = jnp.asarray(self.slots.lengths())
        tokens = np.zeros((self.num_slots,), np.int32)
        for idx, req in self.by_slot.items():
            tokens[idx] = self.results[req.id].tokens[-1]
        if self.cache_kind == "paged":
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(self.slots.block_tables()), lengths)
        else:
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, lengths)
        emitted = []
        for idx in list(self.by_slot):
            req = self.by_slot[idx]
            tok = int(self._sample(logits[idx:idx + 1], req)[0])
            emitted.append((req.id, tok))
            self._emit(idx, req, tok)
        return emitted

    # -- bookkeeping -----------------------------------------------------------

    def _sample(self, logits: jax.Array, req: Request) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sample(
            logits, sub, temperature=req.temperature, top_k=req.top_k,
            vocab_size=self.cfg.vocab_size,
        )

    def _emit(self, idx: int, req: Request, tok: int,
              *, wrote_kv: bool = True) -> None:
        self.results[req.id].tokens.append(tok)
        self.slots.tick(idx, wrote_kv=wrote_kv)
        eos = req.eos_token is not None and tok == req.eos_token
        if self.slots.done(idx, eos):
            self.slots.release(idx)
            del self.by_slot[idx]
