"""Continuous-batching inference engine (prefill + decode over slot caches).

The serving realization of the paper's dataflow (Fig. 2): prefill is the
GEMM-shaped phase (one request at a time, bucketed prompt lengths), decode
is the flat-GEMM/GEMV-shaped phase executed over the *whole* slot batch
every tick. New requests claim slots as soon as finished sequences release
them — decode batches stay full (continuous batching), which is what keeps
the decode-phase GEMMs at M = num_slots, the regime T2/T3 optimize.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, RunConfig
from repro.core.dispatch import DispatchTable
from repro.models.api import get_model
from repro.models.layers import LayerCtx
from repro.serving.kvcache import SlotManager
from repro.serving.sampling import sample

PROMPT_BUCKET = 64


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray               # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    eos_token: Optional[int] = None


@dataclasses.dataclass
class _Done:
    tokens: list


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 8,
        max_seq: int = 2048,
        table: Optional[DispatchTable] = None,
        use_pallas: bool = False,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.api = get_model(cfg)
        self.ctx = LayerCtx(cfg=cfg, table=table, use_pallas=use_pallas)
        self.params = params
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.slots = SlotManager(num_slots, max_seq)
        self.cache = self.api.init_cache(num_slots, max_seq)
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.by_slot: dict[int, Request] = {}
        self.results: dict[int, _Done] = {}
        self.ticks = 0

        self._decode = jax.jit(
            lambda p, t, c, l: self.api.decode_step(self.ctx, p, t, c, l),
            donate_argnums=(2,),
        )
        self._prefill_cache = {}  # bucketed P -> jitted fn

    # -- public API -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, requests: list[Request], *, max_ticks: int = 10_000
            ) -> dict[int, list[int]]:
        for r in requests:
            self.submit(r)
        while (self.queue or self.by_slot) and self.ticks < max_ticks:
            self.step()
        return {rid: d.tokens for rid, d in self.results.items()}

    # -- engine tick ------------------------------------------------------------

    def step(self) -> list[tuple[int, int]]:
        """Admit + prefill waiting requests, then one decode tick."""
        self._admit()
        if not self.by_slot:
            return []
        emitted = self._decode_tick()
        self.ticks += 1
        return emitted

    # -- internals ---------------------------------------------------------------

    def _admit(self) -> None:
        still_waiting = []
        for req in self.queue:
            idx = self.slots.try_assign(req.id, len(req.prompt),
                                        req.max_new_tokens)
            if idx is None:
                still_waiting.append(req)
                continue
            self.by_slot[idx] = req
            self.results[req.id] = _Done(tokens=[])
            self._prefill_into(idx, req)
        self.queue = still_waiting

    def _prefill_fn(self, padded: int):
        if padded not in self._prefill_cache:
            cache1 = self.api.cache_spec(1, self.max_seq)

            def fn(params, tokens, lengths):
                cache = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), cache1)
                return self.api.prefill(
                    self.ctx, params, tokens, lengths, cache)

            self._prefill_cache[padded] = jax.jit(fn)
        return self._prefill_cache[padded]

    def _prefill_into(self, idx: int, req: Request) -> None:
        p = len(req.prompt)
        padded = -(-max(p, 1) // PROMPT_BUCKET) * PROMPT_BUCKET
        toks = np.zeros((1, padded), np.int32)
        toks[0, :p] = req.prompt
        logits, cache1 = self._prefill_fn(padded)(
            self.params, jnp.asarray(toks), jnp.array([p], jnp.int32))
        # insert the single-sequence cache into slot idx (batch axis 1)
        self.cache = jax.tree.map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), idx, axis=1),
            self.cache, cache1,
        )
        tok = self._sample(logits, req)
        self._emit(idx, req, int(tok[0]), wrote_kv=False)

    def _decode_tick(self) -> list[tuple[int, int]]:
        lengths = jnp.asarray(self.slots.lengths())
        tokens = np.zeros((self.num_slots,), np.int32)
        for idx, req in self.by_slot.items():
            tokens[idx] = self.results[req.id].tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache, lengths)
        emitted = []
        for idx in list(self.by_slot):
            req = self.by_slot[idx]
            tok = int(self._sample(logits[idx:idx + 1], req)[0])
            emitted.append((req.id, tok))
            self._emit(idx, req, tok)
        return emitted

    def _sample(self, logits: jax.Array, req: Request) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sample(
            logits, sub, temperature=req.temperature, top_k=req.top_k,
            vocab_size=self.cfg.vocab_size,
        )

    def _emit(self, idx: int, req: Request, tok: int,
              *, wrote_kv: bool = True) -> None:
        self.results[req.id].tokens.append(tok)
        self.slots.tick(idx, wrote_kv=wrote_kv)
        eos = req.eos_token is not None and tok == req.eos_token
        if self.slots.done(idx, eos):
            self.slots.release(idx)
            del self.by_slot[idx]
