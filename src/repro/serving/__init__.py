"""Serving substrate: one cache-agnostic engine over pluggable pieces.

KV storage is a :class:`~repro.models.kvlayout.KVLayout` (dense slots or a
block-paged pool with lazy growth), admission/preemption policy is a
:class:`~repro.serving.scheduler.Scheduler` (FCFS / SJF / page-budget
fair), and each request is a :class:`~repro.serving.request.RequestState`
with its own :class:`~repro.serving.request.SamplingParams` and PRNG key.
The :class:`~repro.serving.engine.Engine` ties them together behind a
streaming surface — ``generate()`` yields ``TokenEvent``s, ``abort()``
cancels, blocking ``run()`` rides on top.
"""
from repro.models.kvlayout import (  # noqa: F401
    DenseLayout,
    KVLayout,
    PagedLayout,
)
from repro.serving.blockpool import BlockPool, PagedSlotManager  # noqa: F401
from repro.serving.engine import Engine, EngineStats  # noqa: F401
from repro.serving.kvcache import SlotManager  # noqa: F401
from repro.serving.request import (  # noqa: F401
    FinishReason,
    Phase,
    RequestState,
    SamplingParams,
    TokenEvent,
)
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    FCFS,
    PageBudgetFair,
    Scheduler,
    ShortestJobFirst,
    get_scheduler,
)
