"""Serving substrate: KV-cache management (dense slots or a block-paged
pool), continuous-batching engine with chunked + batched prefill, sampling.
The engine is the end-to-end realization of the paper's system: admitted
prompts stream through the decode-shaped chunk path (or a batched
single-shot prefill for recurrent families), decode steps run the
T1/T2/T3-optimized ``decode_step`` over the whole active batch every tick,
and ``cache_kind="paged"`` swaps the dense slot cache for fixed-size pages
addressed through per-sequence block tables.
"""
from repro.serving.blockpool import BlockPool, PagedSlotManager  # noqa: F401
from repro.serving.engine import Engine, Request  # noqa: F401
from repro.serving.kvcache import SlotManager  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
