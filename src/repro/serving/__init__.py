"""Serving substrate: KV-cache management, continuous-batching engine,
sampling. The engine is the end-to-end realization of the paper's system:
prefill fills slot caches, decode steps run the T1/T2/T3-optimized
``decode_step`` over the whole active batch every tick.
"""
from repro.serving.engine import Engine, Request  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
