"""Prefix index: page-aligned token-chunk hashes -> physical page ids.

The CoDec observation (PAPERS.md) applied to our block pool: N requests
that share a system prompt or few-shot header hold N identical copies of
the same KV pages, so admission capacity — the thing lazy paging and the
scheduler exist to maximize — is spent on duplicate bytes. This module is
the lookup structure that lets admission *map* a new request's
page-aligned prompt prefix onto pages some resident sequence already
wrote, instead of allocating and re-prefilling them.

Keys are a **hash chain over page-sized token chunks**: chunk ``i``'s key
folds the exact tokens of positions ``[i*PS, (i+1)*PS)`` into the key of
chunk ``i-1``, so a page is shared only when *every* preceding position
matches too (position-dependent KV — RoPE, causal attention — makes a
mid-sequence chunk non-reusable on its own). Entries also retain the raw
chunk tokens and are compared exactly on lookup, so the *current* chunk
can never alias; ancestry, however, rides in the key only as a 64-bit
hash, so two different histories alias only on a full ``hash()``
collision between their chains (~2^-64 per pair) — accepted odds, not an
impossibility.

Lifecycle contract (enforced by :meth:`check` and the property tests):

  * Only **full** pages are ever registered — a partially written tail
    page still receives decode writes and must stay private.
  * An entry is ``pending`` from admission (pages promised, content not
    yet written) until its owner's prefill completes (:meth:`commit`).
    Same-wave followers may map pending pages but must prefill *after*
    the level that writes them — ``pending_level`` carries the wave
    ordering (see ``Engine._admit``).
  * The index holds **no refcount** of its own: entries live exactly as
    long as the page has owners. When the last owner releases and the
    page returns to the free list, :meth:`drop_page` purges its entry —
    a key can therefore never resolve to a recycled page.

**Tiered entries.** With a :class:`~repro.serving.tiers.TieredPool`
behind the pool, an entry outlives its tier-0 page: when a session-cache
page's last device owner lets go, the manager demotes the slab to the
host store and :meth:`demote_page` rebinds the entry from its page id to
the store's ``hid`` handle (``tier`` 1 = host RAM, 2 = disk). The chain
hash stays matchable — :meth:`match` reports each hit's tier so
admission can decide share (tier 0), promote (``promote_hid`` rebinds
back onto a fresh tier-0 page once the engine uploads the slab), or
ignore it (below the plan's ``swap_threshold`` re-prefill wins). Only a
**true eviction** — the slab falling off the bottom of the hierarchy —
purges a demoted entry (:meth:`purge_hid`); demotion alone never does.
Demoted entries are always committed: only written, full pages are ever
demoted.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class _Entry:
    """One registered full page of prefix KV (tier 0: a live device page;
    tier >= 1: a slab handle in the tiered store)."""

    page: Optional[int]               # tier-0 page id; None when demoted
    chunk: Tuple[int, ...]            # exact tokens (collision guard)
    pending_level: Optional[int]      # None = content committed
    tier: int = 0                     # 0 device, 1 host, 2 disk
    hid: Optional[int] = None         # tiered-store handle; None at tier 0


@dataclasses.dataclass
class Match:
    """Result of :meth:`PrefixIndex.match` for one prompt."""

    pages: List[int]                  # matched pages, position order
    #                                   (-1 placeholder for demoted entries)
    pending_level: int                # max pending level matched; -1 if all
    #                                   matched pages are committed
    tail_pending: bool                # is the *last* matched page pending?
    tiers: List[int] = dataclasses.field(default_factory=list)
    hids: List[Optional[int]] = dataclasses.field(default_factory=list)
    pending: List[Optional[int]] = dataclasses.field(default_factory=list)
    #                                   per-entry pending level (admission
    #                                   recomputes the wave level after
    #                                   truncating the match)

    def __len__(self) -> int:
        return len(self.pages)


class PrefixIndex:
    """Chain-hashed map of page-aligned prompt chunks to live pages."""

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self._entries: Dict[Tuple[int, Tuple[int, ...]], _Entry] = {}
        self._by_page: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self._by_hid: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        # one admission derives the chain three times (match at slot
        # build, register at assignment, commit after prefill) — a small
        # LRU keyed on the canonical token bytes collapses that to one
        # O(prompt) pass
        self._chain_cache: "OrderedDict[bytes, list]" = OrderedDict()
        # observability counters (engine stats / benchmark read these)
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    # -- key derivation ------------------------------------------------------

    def _chunks(self, tokens: Sequence[int]) -> list:
        """Chain keys, one per FULL page of ``tokens``, cached."""
        toks = np.asarray(tokens).reshape(-1)
        n_full = len(toks) // self.page_size
        if not n_full:
            return []
        toks = np.asarray(toks[:n_full * self.page_size], np.int64)
        blob = toks.tobytes()          # canonical dtype: no value aliasing
        keys = self._chain_cache.get(blob)
        if keys is None:
            keys = []
            parent = 0
            for i in range(n_full):
                chunk = tuple(
                    int(t) for t in
                    toks[i * self.page_size:(i + 1) * self.page_size])
                key = (hash((parent, chunk)), chunk)
                parent = key[0]
                keys.append(key)
            self._chain_cache[blob] = keys
            if len(self._chain_cache) > 16:
                self._chain_cache.popitem(last=False)
        else:
            self._chain_cache.move_to_end(blob)
        return keys

    # -- lookup / registration ----------------------------------------------

    def match(self, tokens: Sequence[int]) -> Match:
        """Longest indexed page-aligned prefix of ``tokens``.

        Stops at the first missing chunk — the chain key makes any later
        hit unreachable anyway. Returns the pages in position order plus
        the pending-wave metadata admission needs.
        """
        pages: List[int] = []
        tiers: List[int] = []
        hids: List[Optional[int]] = []
        per_pending: List[Optional[int]] = []
        pending = -1
        tail_pending = False
        for key in self._chunks(tokens):
            e = self._entries.get(key)
            if e is None or e.chunk != key[1]:
                break
            pages.append(e.page if e.page is not None else -1)
            tiers.append(e.tier)
            hids.append(e.hid)
            per_pending.append(e.pending_level)
            tail_pending = e.pending_level is not None
            if e.pending_level is not None:
                pending = max(pending, e.pending_level)
        if pages:
            self.hits += 1
        else:
            self.misses += 1
        return Match(pages=pages, pending_level=pending,
                     tail_pending=tail_pending, tiers=tiers, hids=hids,
                     pending=per_pending)

    def register(self, tokens: Sequence[int], pages: Sequence[int],
                 *, level: int = 0) -> int:
        """Register every full page of ``tokens`` that is not indexed yet.

        ``pages[i]`` must be the physical page holding chunk ``i``.
        New entries are ``pending`` at ``level`` (promised at admission);
        :meth:`commit` flips them once the owner's prefill wrote them.
        Returns how many new entries were added.
        """
        added = 0
        for i, key in enumerate(self._chunks(tokens)):
            if key in self._entries:
                continue                  # first registrant wins
            page = int(pages[i])
            if page in self._by_page:
                # a page holds exactly one chunk of content; re-keying it
                # would alias two prefixes onto one slab
                continue
            self._entries[key] = _Entry(page, key[1], pending_level=level)
            self._by_page[page] = key
            added += 1
        return added

    def commit(self, tokens: Sequence[int]) -> None:
        """Mark every indexed full page of ``tokens`` as written.

        Idempotent, and safe for a follower to call on chunks another
        slot registered: wave ordering guarantees the content is on the
        page by the time anyone whose prefill covered it completes.
        """
        for key in self._chunks(tokens):
            e = self._entries.get(key)
            if e is not None:
                e.pending_level = None

    def drop_page(self, page: int) -> None:
        """Purge the entry for a page returning to the free list."""
        key = self._by_page.pop(page, None)
        if key is not None:
            del self._entries[key]

    # -- tier transitions ----------------------------------------------------

    def demote_page(self, page: int, hid: int, tier: int = 1) -> bool:
        """Rebind a tier-0 entry onto a tiered-store handle: the device
        page is about to be freed but its slab lives on as ``hid``, so
        the chain-hash key stays matchable. Returns False (no-op) when
        the page was never indexed."""
        key = self._by_page.pop(page, None)
        if key is None:
            return False
        e = self._entries[key]
        e.page = None
        e.tier = tier
        e.hid = hid
        self._by_hid[hid] = key
        return True

    def promote_hid(self, hid: int, page: int) -> None:
        """Rebind a demoted entry back onto a fresh tier-0 ``page`` (the
        engine uploads the slab; demoted content is always committed)."""
        key = self._by_hid.pop(hid)
        e = self._entries[key]
        e.page = page
        e.tier = 0
        e.hid = None
        e.pending_level = None
        self._by_page[page] = key

    def set_tier(self, hid: int, tier: int) -> None:
        """Record an intra-hierarchy move (host -> disk spill)."""
        key = self._by_hid.get(hid)
        if key is not None:
            self._entries[key].tier = tier

    def rebind_hid(self, old: int, new: int) -> None:
        """Point a demoted entry at a fresh store handle (an aborted
        promotion pushed the slab back down and got a new hid)."""
        key = self._by_hid.pop(old)
        self._by_hid[new] = key
        self._entries[key].hid = new

    def purge_hid(self, hid: int) -> None:
        """True eviction: the slab fell off the bottom tier, so the key
        must stop matching (re-prefill is the only way back)."""
        key = self._by_hid.pop(hid, None)
        if key is not None:
            del self._entries[key]

    def demoted_ids(self) -> set:
        return set(self._by_hid)

    # -- invariants ----------------------------------------------------------

    def shared_page_ids(self) -> set:
        return set(self._by_page)

    def check(self, live_pages: set, live_hids: set = frozenset()) -> None:
        """Index invariants (called from ``PagedSlotManager.check``):
        bijection between entries and pages/hids, every indexed page
        alive (or its hid resident in the tiered store), chunks exactly
        one page long, demoted entries committed."""
        assert len(self._entries) == len(self._by_page) + len(self._by_hid), \
            "entry/page/hid maps out of sync"
        for key, e in self._entries.items():
            assert len(e.chunk) == self.page_size, \
                "registered chunk is not exactly one page"
            if e.tier == 0:
                assert e.hid is None, "tier-0 entry carries a hid"
                assert self._by_page.get(e.page) == key, \
                    "page -> key back-pointer broken"
                assert e.page in live_pages, \
                    f"index maps to freed page {e.page}"
            else:
                assert e.page is None, "demoted entry still names a page"
                assert self._by_hid.get(e.hid) == key, \
                    "hid -> key back-pointer broken"
                assert e.hid in live_hids, \
                    f"index maps to evicted hid {e.hid}"
                assert e.pending_level is None, \
                    "demoted entry is pending (unwritten content demoted)"


# -- decode-time group enumeration -------------------------------------------

def shared_prefix_groups(slots, refcount):
    """Group resident slots by the physical pages of their shared prefix.

    A slot's group key is the **maximal leading run** of its block table
    whose pages have ``refcount(page) > 1`` — i.e. the prefix positions
    whose KV is physically deduplicated with at least one other owner.
    Two slots land in the same group iff those runs are *identical page
    lists*: same physical pages in the same order, hence byte-identical
    shared-prefix KV. Slots whose runs diverge in length get different
    keys (grouped attention needs one prefix length per group).

    Deriving the key from refcounts alone (no index lookup) makes the
    plan self-healing across the whole page lifecycle: a COW fork
    replaces the writer's page (its run shortens, it leaves the group
    next tick), a release that kills a page drops every former sharer's
    run at that point, and re-admission after preemption re-maps the
    prefix and rejoins automatically.

    ``slots`` is any sequence with ``.free`` and ``.pages``; ``refcount``
    maps page id -> owner count. Returns ``[(key, member_indices)]`` for
    every key with >= 2 members, in first-seen slot order.
    """
    runs: dict = {}
    order: list = []
    for i, s in enumerate(slots):
        if s.free or not s.pages:
            continue
        n = 0
        for p in s.pages:
            if refcount(p) > 1:
                n += 1
            else:
                break
        if not n:
            continue
        key = tuple(s.pages[:n])
        if key not in runs:
            runs[key] = []
            order.append(key)
        runs[key].append(i)
    return [(k, runs[k]) for k in order if len(runs[k]) >= 2]
