"""Tiered KV page store: demote, don't discard.

The block pool (:mod:`repro.serving.blockpool`) is **tier 0** — device
HBM, the only tier kernels can address. This module adds the rest of the
hierarchy behind one interface:

    tier 0 (device pool)  →  tier 1 (host RAM, numpy slabs)
                          →  tier 2 (disk, optional)

The capacity argument (LIMINAL's limit study; "Inference Optimization of
Foundation Models on AI Accelerators", PAPERS.md): once attention reads
are paged, decode throughput is bounded jointly by HBM *capacity* and
bandwidth — and host DRAM is ~2 orders of magnitude larger than HBM at a
PCIe-class link cost that a roofline can price against re-prefill
(:func:`repro.core.dispatch.find_swap_threshold`). So instead of freeing
a victim's KV pages (preemption) or a finished conversation's prefix
pages (retire), the serving stack **demotes** them here and the
:class:`~repro.serving.prefix.PrefixIndex` keeps their chain-hash keys
matchable with a tier tag — a returning session *promotes* its persisted
prefix back into freshly allocated tier-0 pages (one bulk host→device
copy) instead of recomputing it.

Division of labor:

  * :class:`TieredPool` (this module) owns the **slabs** — host-side
    copies of one page's per-layer K/V arrays, keyed by a monotonically
    increasing host id (``hid``). It is content-agnostic: a slab is
    whatever tuple of numpy arrays the engine gathered. Capacity is
    bounded (``host_pages`` / ``disk_pages``); overflow spills LRU-first
    down the hierarchy and **truly evicts** — purging the index entry —
    only when the bottom tier is full (or absent).
  * The :class:`~repro.serving.prefix.PrefixIndex` owns the **keys**:
    ``demote_page``/``promote_hid`` rebind an entry between a tier-0
    page id and a tiered ``hid`` so one chain-hash lookup spans the whole
    hierarchy.
  * The engine owns the **copies**: one bulk device→host gather per
    demotion batch, one bulk host→device scatter per promotion batch
    (the only tier that ever touches jax is tier 0).

Nothing here imports jax — the store is plain host memory + files, and
the property tests drive it with dummy slabs.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
from collections import OrderedDict
from typing import Optional


@dataclasses.dataclass
class TierStats:
    """Counters for the engine summary / benchmarks."""

    demoted: int = 0          # pages accepted into the hierarchy (tier >= 1)
    promoted: int = 0         # pages popped back toward tier 0
    disk_demotions: int = 0   # host -> disk spills (tier 1 -> 2)
    evicted: int = 0          # pages that fell off the bottom (KV lost;
    #                           the index entry is purged — re-prefill)


class TieredPool:
    """Bounded host(+disk) store for demoted KV page slabs.

    ``demote(slab)`` accepts one page's host-side slab and returns its
    ``hid`` handle (or ``None`` when the hierarchy has nowhere to put it
    — zero host pages and no disk tier). Admission of a new slab never
    fails by *rejecting the new page*: capacity pressure spills the
    **least-recently-used** resident slab downward instead (host → disk,
    disk → gone), because the page being demoted right now belongs to the
    most recently active session. ``pop(hid)`` removes and returns a slab
    for promotion; ``drop(hid)`` discards without copying.

    The optional ``index`` (a :class:`~repro.serving.prefix.PrefixIndex`)
    is kept consistent on every internal movement: host→disk retags the
    entry (``set_tier``), a true eviction purges it (``purge_hid``) so a
    chain-hash key can never resolve to a slab that no longer exists.
    """

    def __init__(self, host_pages: int, *, index=None,
                 disk_dir: Optional[str] = None, disk_pages: int = 0):
        if host_pages < 0 or disk_pages < 0:
            raise ValueError("tier capacities must be >= 0")
        if disk_pages and not disk_dir:
            raise ValueError("disk_pages > 0 requires disk_dir")
        self.host_pages = host_pages
        self.disk_pages = disk_pages if disk_dir else 0
        self.disk_dir = disk_dir
        self.index = index
        self._host: "OrderedDict[int, tuple]" = OrderedDict()  # hid -> slab
        self._disk: "OrderedDict[int, str]" = OrderedDict()    # hid -> path
        self._next_hid = 0
        self.stats = TierStats()
        if self.disk_pages:
            os.makedirs(disk_dir, exist_ok=True)

    def __len__(self) -> int:
        return len(self._host) + len(self._disk)

    @property
    def host_used(self) -> int:
        return len(self._host)

    @property
    def disk_used(self) -> int:
        return len(self._disk)

    def ids(self) -> set:
        """Live hids across every tier (the index-check ground truth)."""
        return set(self._host) | set(self._disk)

    def tier_of(self, hid: int) -> int:
        if hid in self._host:
            return 1
        if hid in self._disk:
            return 2
        raise KeyError(f"unknown hid {hid}")

    # -- downward dataflow ---------------------------------------------------

    def demote(self, slab) -> Optional[int]:
        """Admit one page slab into the hierarchy; returns its ``hid`` or
        ``None`` when there is no capacity anywhere (the caller then
        treats the page as truly evicted and purges its index entry)."""
        hid = self._next_hid
        self._next_hid += 1
        if self.host_pages > 0:
            while len(self._host) >= self.host_pages:
                self._spill_lru()
            self._host[hid] = slab
            self.stats.demoted += 1
            return hid
        if self._disk_store(hid, slab):
            self.stats.demoted += 1
            if self.index is not None:
                self.index.set_tier(hid, 2)
            return hid
        return None

    def _spill_lru(self) -> None:
        """Push the least-recently-used host slab down one tier."""
        hid, slab = self._host.popitem(last=False)
        if self._disk_store(hid, slab):
            self.stats.disk_demotions += 1
            if self.index is not None:
                self.index.set_tier(hid, 2)
        else:
            self.stats.evicted += 1
            if self.index is not None:
                self.index.purge_hid(hid)

    def _disk_store(self, hid: int, slab) -> bool:
        if not self.disk_pages:
            return False
        while len(self._disk) >= self.disk_pages:
            old, path = self._disk.popitem(last=False)
            os.remove(path)
            self.stats.evicted += 1
            if self.index is not None:
                self.index.purge_hid(old)
        # pickle, not np.savez: slabs may be extension dtypes (ml_dtypes
        # bfloat16) that the npy format round-trips unreliably; pickle
        # preserves bytes + dtype exactly, which the bit-identity
        # invariant needs
        path = os.path.join(self.disk_dir, f"page-{hid}.kv")
        with open(path, "wb") as f:
            pickle.dump(slab, f, protocol=pickle.HIGHEST_PROTOCOL)
        self._disk[hid] = path
        return True

    # -- upward dataflow -----------------------------------------------------

    def pop(self, hid: int):
        """Remove and return a slab for promotion back to tier 0."""
        slab = self._host.pop(hid, None)
        if slab is None:
            path = self._disk.pop(hid)   # KeyError on unknown hid
            with open(path, "rb") as f:
                slab = pickle.load(f)
            os.remove(path)
        self.stats.promoted += 1
        return slab

    def touch(self, hid: int) -> None:
        """Refresh LRU recency (a session re-matched this slab)."""
        if hid in self._host:
            self._host.move_to_end(hid)
        elif hid in self._disk:
            self._disk.move_to_end(hid)

    def drop(self, hid: int) -> None:
        """Discard a slab without promoting it (entry superseded)."""
        if self._host.pop(hid, None) is None:
            path = self._disk.pop(hid, None)
            if path is not None:
                os.remove(path)

    # -- invariants ----------------------------------------------------------

    def check(self) -> None:
        assert len(self._host) <= max(self.host_pages, 0), \
            "host tier over capacity"
        assert len(self._disk) <= self.disk_pages, "disk tier over capacity"
        assert not (set(self._host) & set(self._disk)), \
            "hid resident in two tiers at once"
        for path in self._disk.values():
            assert os.path.exists(path), f"disk slab file missing: {path}"
        if self.index is not None:
            # every index entry pointing into the hierarchy must resolve
            assert self.index.demoted_ids() <= self.ids(), \
                "index maps a hid the tiered store no longer holds"
