"""Request lifecycle: sampling parameters, the per-request state machine,
and the streaming token event record.

This replaces the old flat ``Request``/``_Done`` pair with the three
objects the scheduler/engine redesign needs:

  * :class:`SamplingParams` — immutable generation knobs (temperature,
    top-k, top-p, per-request seed, stop tokens, explicit stop-token
    inclusion, token budget).

  * :class:`RequestState` — one mutable record per submitted request,
    walking the machine::

        WAITING -> PREFILLING -> RUNNING -> FINISHED{stop,length,abort}
                        ^            |
                        '- PREEMPTED <'   (pages freed, re-queued,
                                           re-prefilled on re-admission)

    The state owns everything needed to restart after preemption: the
    prompt, every generated token, and the request's own PRNG key — so a
    resumed sequence continues bit-identically (re-prefilling
    ``prompt + generated`` reconstructs exactly the KV a never-preempted
    run would hold, and the private key means no other request's sampling
    order can perturb this one).

  * :class:`TokenEvent` — one streamed token (or terminal marker) from
    ``Engine.generate()`` / ``Engine.step()``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import jax
import numpy as np


class FinishReason(str, enum.Enum):
    STOP = "stop"        # sampled a stop token
    LENGTH = "length"    # max_new_tokens reached or cache/max_seq exhausted
    ABORT = "abort"      # Engine.abort(rid)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return self.value


class Phase(enum.Enum):
    WAITING = enum.auto()
    PREFILLING = enum.auto()
    RUNNING = enum.auto()
    PREEMPTED = enum.auto()
    FINISHED = enum.auto()


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters (immutable, hashable)."""

    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None       # None -> derived from engine seed + rid
    stop_tokens: Tuple[int, ...] = ()
    include_stop: bool = False       # append the stop token to the output?

    def __post_init__(self):
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")


@dataclasses.dataclass
class RequestState:
    """Mutable lifecycle record for one submitted request."""

    rid: int
    prompt: np.ndarray               # (P,) int32
    params: SamplingParams
    arrival: int                     # admission-order sequence number
    key: jax.Array                   # private PRNG key, split per sample
    phase: Phase = Phase.WAITING
    tokens: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)  # TokenEvents
    finish_reason: Optional[FinishReason] = None
    slot: Optional[int] = None
    preemptions: int = 0
    shared_len: int = 0              # resident prefix positions backed by
    #                                  shared (refcount > 1 at admission)
    #                                  pages — set by the engine at
    #                                  admission, cleared on preemption
    persistable_len: int = 0         # page-aligned resident positions whose
    #                                  KV survives a preemption through the
    #                                  tiered session cache (retained /
    #                                  demoted, not discarded) — refreshed
    #                                  by the engine before victim ranking;
    #                                  stays 0 without a TieredPool
    submit_time: float = 0.0
    first_token_time: Optional[float] = None
    first_token_tick: Optional[int] = None

    # -- scheduler-facing cost signals --------------------------------------

    @property
    def generated(self) -> int:
        return len(self.tokens)

    @property
    def remaining_new(self) -> int:
        """Upper bound on decode work left (SJF's cost signal)."""
        return max(self.params.max_new_tokens - self.generated, 0)

    @property
    def total_len(self) -> int:
        """KV positions this request occupies if resident now — the page
        footprint signal (PageBudgetFair)."""
        return len(self.prompt) + self.generated

    @property
    def exclusive_len(self) -> int:
        """Positions backed by pages only this request owns — the
        positions a preemption actually returns to the pool (shared
        prefix pages survive the victim's release, and a re-admission
        re-maps them instead of re-prefilling), so this is both the
        reclaim value and the re-prefill cost of evicting this request."""
        return max(self.total_len - self.shared_len, 0)

    @property
    def resume_cost(self) -> int:
        """Positions a re-admission would actually *recompute*. With a
        tiered KV store, preemption retains every full page (tier-0
        session set, demoted host-ward under pressure), so only the
        positions past ``max(shared_len, persistable_len)`` re-prefill —
        without tiers this degrades to ``exclusive_len`` exactly."""
        keep = max(self.shared_len, self.persistable_len)
        return max(self.total_len - keep, 0)

    # -- lifecycle ----------------------------------------------------------

    @property
    def finished(self) -> bool:
        return self.phase is Phase.FINISHED

    def prefill_tokens(self) -> np.ndarray:
        """Tokens to (re-)prefill on admission: the prompt, plus — after a
        preemption — everything generated so far, so the rebuilt KV equals
        what an uninterrupted run would hold."""
        if not self.tokens:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.tokens, np.int32)])

    def next_key(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def finish(self, reason: FinishReason) -> None:
        self.phase = Phase.FINISHED
        self.finish_reason = reason
        self.slot = None


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed generation event.

    ``token is None`` only for a terminal marker with no token attached
    (e.g. an abort before/without a final sample). ``finished`` is True on
    the request's last event, with ``finish_reason`` set.
    """

    rid: int
    token: Optional[int]
    index: int                       # position in the generated stream
    finished: bool = False
    finish_reason: Optional[FinishReason] = None
