"""Block-paged KV cache management (host side): a refcounted page pool
with copy-on-write prefix sharing.

The paper's §3/Fig. 2 critique of static dataflow applies to memory as
much as compute: a dense ``(num_slots, max_seq)`` cache provisions every
slot for the worst-case sequence, so short requests strand capacity and
admission is bounded by slots, not by actual KV bytes. This module
replaces that with a **block pool**: KV storage is a flat array of
fixed-size pages shared by all sequences, each sequence owns an ordered
list of page ids (its *block table*), and pages cycle through an explicit
LIFO free-list.

Pages are **refcounted**, which buys two things on top of plain paging:

  * **Prefix sharing.** N requests with the same system prompt /
    few-shot header map their page-aligned common prefix onto *one*
    physical copy: admission consults a
    :class:`~repro.serving.prefix.PrefixIndex` (hash chain of page-sized
    token chunks -> live page), bumps the refcount of every matched page
    (:meth:`BlockPool.share`), and prefills only the unshared suffix.
    ``free`` decrements; a page returns to the free list — and leaves the
    index — only when its last owner lets go, so a victim's release never
    tears pages out from under the sequences still reading them.

  * **Copy-on-write.** Shared pages are immutable: the first write into a
    page with refcount > 1 forks it — the manager allocates a fresh page,
    patches the writer's block table, and drops one ref
    (:meth:`PagedSlotManager.fork_for_write`); the engine copies the
    ``(layers, page_size, kv_heads, head_dim)`` slab on device. Everything
    downstream (decode, preemption, release) then treats the fork like any
    privately owned page.

Device layout (see :func:`repro.models.transformer.init_cache` with a
:class:`~repro.models.kvlayout.PagedLayout`):

    k/v pool: (num_layers, num_pages, page_size, kv_heads, head_dim)

Logical position ``p`` of the sequence in slot ``s`` lives at physical
``(block_tables[s, p // page_size], p % page_size)``. Block tables are a
dense ``(num_slots, max_pages_per_seq)`` int32 array handed to the jitted
decode/prefill-chunk steps each tick — **cached device-side** by the
manager and rebuilt only when some table actually changed (alloc, lazy
growth, release, COW fork), so steady-state decode ticks reuse the
device-resident operand. Unassigned entries hold the out-of-bounds
sentinel ``num_pages`` — KV scatters through them are dropped
(``mode="drop"``), and reads clamp to a real page whose contents the
attention length-mask discards. Correctness of empty slots in a partially
occupied batch depends on that sentinel: a 0 entry would alias a real
page another sequence may own.

Two classes:

  * :class:`BlockPool` — the refcounting free-list allocator (no device
    state). Invariant: every page is either on the free list with
    refcount 0, or allocated with refcount >= 1; the sum of refcounts
    equals the ownership multiset across slot block tables plus the
    manager's session-cache refs
    (:meth:`PagedSlotManager.check` enforces the cross-structure half).
  * :class:`PagedSlotManager` — drop-in replacement for
    :class:`repro.serving.kvcache.SlotManager` that additionally owns the
    per-slot block tables and (optionally) the prefix index. Allocation
    is **lazy**: admission reserves pages for the tokens that will
    actually be prefilled (shared prefix excluded) plus one decode growth
    page of headroom, and each decode tick grows a sequence's table
    page-by-page through :meth:`ensure` — so a pool can be overcommitted
    below worst-case footprint and the engine's scheduler preempts a
    victim (refs dropped, request re-queued) when :meth:`ensure` reports
    the pool dry. The block tables make preemption relocation-free: a
    re-admitted sequence just gets fresh pages — or re-maps its shared
    prefix if the pages survived through another owner.

**The memory hierarchy (tier 0 of three).** With a
:class:`~repro.serving.tiers.TieredPool` attached, this pool becomes
tier 0 of an HBM → host → disk page hierarchy and the manager stops
discarding KV it might want back:

  * **Session cache (tier-0 retention).** :meth:`retain_session` — the
    retire/preempt hook — registers a departing sequence's full pages in
    the prefix index and transfers the slot's ref on each to a
    manager-held LRU *session set* instead of freeing them. A returning
    conversation (same prompt + generated history) then re-maps its
    whole prefix by refcount bump, zero copies.
  * **Demotion under pressure.** When allocation runs dry,
    :meth:`reclaim_session` drops session refs LRU-first; pages whose
    last ref that was get their slabs bulk-copied device→host (the
    engine's gather) and land in the tiered store, with the index entry
    rebound from page id to store handle — matchable, just not
    addressable. Only falling off the hierarchy's bottom truly evicts.
  * **Promotion at admission.** :meth:`_make_slot` spans tiers: a match
    whose demoted span reaches the plan's ``swap_threshold`` (the
    ``dispatch.find_swap_threshold`` roofline: link copy cost vs
    chunked-prefill recompute) allocates fresh tier-0 pages for those
    chunks and hands the engine ``pending_promotions`` — one bulk
    host→device upload — instead of re-prefilling them.

The demoted bytes are the bytes the original run wrote, so a resumed or
returning sequence decodes bit-identically to one that never left.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np

from repro.models.kvlayout import pages_for, pow2_bucket  # noqa: F401
# (pages_for re-export: the one page ceil-div definition, shared with
# layouts/engine/benchmarks)
from repro.serving.kvcache import Slot, SlotManager
from repro.serving.prefix import PrefixIndex, shared_prefix_groups
from repro.serving.tiers import TieredPool


class BlockPool:
    """Refcounted fixed-size page allocator over ``num_pages`` pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO: a just-freed (hot) page is reused first
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._ref: dict[int, int] = {}     # page -> refcount (>= 1)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._ref)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts = what a share-less pool would have used."""
        return sum(self._ref.values())

    def allocated_pages(self) -> set:
        """Snapshot of page ids with refcount >= 1."""
        return set(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_for(self, positions: int) -> int:
        """Pages needed to store ``positions`` KV entries."""
        return pages_for(positions, self.page_size)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` pages off the free list (refcount 1 each); None if
        not enough remain."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def share(self, pages: list[int]) -> None:
        """Add one owner to each (already allocated) page."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"cannot share unallocated page {p}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: list[int]) -> list[int]:
        """Drop one ref per page; pages reaching refcount 0 return to the
        free list. Returns the pages that actually **died** — the caller
        (slot manager) purges those from the prefix index so a stale key
        can never resolve to a recycled page."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"double free / foreign page {p}")
        dead = []
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                dead.append(p)
        return dead

    def check(self) -> None:
        """Invariant check (used by the property tests): every page is on
        exactly one side of the free/allocated split, and every allocated
        page has a positive refcount."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & set(self._ref)), "page both free and allocated"
        assert free | set(self._ref) == set(range(self.num_pages)), \
            "page leaked out of the pool"
        assert all(r >= 1 for r in self._ref.values()), \
            "allocated page with refcount < 1"


@dataclasses.dataclass
class GroupPlan:
    """One decode tick's shared-prefix grouping, host side.

    Built by :meth:`PagedSlotManager.group_plan` from the refcount-derived
    groups of :func:`~repro.serving.prefix.shared_prefix_groups`, with
    every dimension pow2-bucketed (groups NG, prefix pages LP, members M)
    so steady workloads hit a handful of jit shapes. Padding groups carry
    zero counts; padding table entries hold the pool's out-of-bounds
    sentinel; solo rows have ``gid == NG`` and ``prefix_len == 0``.

    ``operands()`` lazily uploads the arrays as the
    :class:`~repro.kernels.group_attention.DecodeGroups` pytree the model
    steps take — cached, so the steady-state tick reuses the same device
    buffers (the manager only rebuilds the plan when a block table
    changed).
    """

    gid: np.ndarray            # (B,)  group id per slot row; NG = solo
    member: np.ndarray         # (B,)  rank within the group; 0 for solo
    prefix_len: np.ndarray     # (B,)  shared positions; 0 = solo
    tables: np.ndarray         # (NG, LP) physical pages of each prefix
    n_pages: np.ndarray        # (NG,) live prefix pages per group
    g_prefix_len: np.ndarray   # (NG,) shared positions per group
    num_members: np.ndarray    # (NG,) live members per group
    member_rows: np.ndarray    # (NG, M) slot row of each member; B = pad
    n_grouped: int             # total rows covered by some group
    pages_deduped: int         # sum over groups of (members - 1) * pages
    _operands: object = dataclasses.field(default=None, repr=False)

    def operands(self):
        if self._operands is None:
            import jax.numpy as jnp

            from repro.kernels.group_attention import DecodeGroups
            self._operands = DecodeGroups(
                tables=jnp.asarray(self.tables),
                n_pages=jnp.asarray(self.n_pages),
                g_prefix_len=jnp.asarray(self.g_prefix_len),
                num_members=jnp.asarray(self.num_members),
                member_rows=jnp.asarray(self.member_rows),
                gid=jnp.asarray(self.gid),
                member=jnp.asarray(self.member),
                prefix_len=jnp.asarray(self.prefix_len),
            )
        return self._operands


@dataclasses.dataclass
class PagedSlot(Slot):
    pages: list = dataclasses.field(default_factory=list)
    # prefix-sharing admission metadata (all zero when sharing is off)
    shared_len: int = 0          # prefix positions mapped onto shared pages
    prefill_start: int = 0       # first position the engine must prefill
    prefill_level: int = 0       # same-wave ordering: prefill after every
    #                              slot whose pending pages this one mapped
    pending_fork: Optional[tuple] = None   # (src, dst): slab copy the
    #                              engine owes before this slot's prefill
    # tiered-promotion admission metadata (empty without a TieredPool)
    pending_promotions: list = dataclasses.field(default_factory=list)
    #                              [(slab, dst_page)]: host→device uploads
    #                              the engine owes before this slot's
    #                              prefill (promoted prefix content)
    session_mapped: int = 0      # matched pages served out of the tier-0
    #                              session cache (refcount bump, no copy)


class PagedSlotManager(SlotManager):
    """Slot occupancy + block tables over a shared :class:`BlockPool`.

    Inherits the ``SlotManager`` tick-loop interface (``lengths`` /
    ``tick`` and the admission scan) so the engine can switch cache kinds
    without touching its loop. Admission requires pages for the tokens
    about to be prefilled plus one growth page — minus whatever prefix the
    :class:`~repro.serving.prefix.PrefixIndex` maps onto existing pages
    (``prefix_index=None`` disables sharing); decode-time growth goes
    through :meth:`ensure` (lazy allocation), writes into shared pages
    fork through :meth:`fork_for_write`, and release drops one ref per
    page — the free list only sees pages whose last owner let go.
    """

    def __init__(self, num_slots: int, max_seq: int, pool: BlockPool,
                 prefix_index: Optional[PrefixIndex] = None,
                 tiers: Optional[TieredPool] = None):
        self.pool = pool
        self.prefix = prefix_index
        if prefix_index is not None and \
                prefix_index.page_size != pool.page_size:
            raise ValueError("prefix index / pool page_size mismatch")
        if tiers is not None and prefix_index is None:
            raise ValueError(
                "a TieredPool needs a prefix index — the index is the "
                "cross-tier map that makes demoted pages matchable")
        self.tiers = tiers
        # tier-0 session cache: pages of finished/preempted sequences the
        # manager holds one ref on, LRU order (page -> None); drained by
        # reclaim_session under pool pressure
        self._session: "OrderedDict[int, None]" = OrderedDict()
        # min demoted-span (pages) worth promoting instead of
        # re-prefilling; the engine sets it from plan.paged.swap_threshold
        self.swap_threshold = 1
        # engine hook: reclaim_cb(pages_needed) -> bool, demotes session
        # pages (device→host gather included) and returns whether enough
        # pool capacity was freed
        self.reclaim_cb: Optional[Callable[[int], bool]] = None
        self.max_pages_per_seq = pool.pages_for(max_seq)
        # dense (num_slots, max_pages_per_seq) block-table operand, cached
        # device-side; rebuilt only when a table changed (alloc / ensure /
        # release / COW fork) so steady-state decode ticks reuse it
        self._bt_cache = None
        self._bt_dirty = True
        # the shared-prefix group plan is a pure function of the block
        # tables + refcounts, so it shares the block-table dirty
        # discipline: every event that invalidates _bt_cache (admission,
        # growth, COW fork, release) invalidates the plan too
        self._gp_cache = None
        self._gp_dirty = True
        super().__init__(num_slots, max_seq)

    def _empty_slot(self) -> PagedSlot:
        return PagedSlot()

    def try_assign(self, request_id: int, prompt_len: int, max_new: int,
                   tokens=None) -> Optional[int]:
        idx = super().try_assign(request_id, prompt_len, max_new,
                                 tokens=tokens)
        if idx is not None:
            self._bt_dirty = True
            self._gp_dirty = True
            if self.prefix is not None and tokens is not None:
                # promise this slot's full prompt pages to later arrivals
                # (entries pending at this slot's wave level until its
                # prefill commits them)
                self.prefix.register(
                    tokens, self.slots[idx].pages,
                    level=self.slots[idx].prefill_level)
        return idx

    def _make_slot(self, request_id: int, prompt_len: int, max_new: int,
                   tokens=None) -> Optional[PagedSlot]:
        worst = self.pool.pages_for(prompt_len + max_new)
        if worst > self.pool.num_pages:
            # can never be satisfied, not even by an empty pool — raise like
            # the max_seq check (returning None would livelock admission,
            # and lazily admitting would guarantee an unservable mid-decode
            # growth failure with no preemptable victim once it runs alone)
            raise ValueError(
                f"request {request_id} needs {worst} pages > pool size "
                f"{self.pool.num_pages} (page_size {self.pool.page_size})")

        ps = self.pool.page_size
        # per covered chunk: ("share", page) -> refcount bump, or
        # ("promote", hid) -> fresh page + host→device upload
        kept: list[tuple] = []
        level = 0
        fork_src: Optional[int] = None
        session_mapped = 0
        if self.prefix is not None and tokens is not None and prompt_len:
            m = self.prefix.match(tokens)
            n_demoted = sum(1 for t in m.tiers if t > 0)
            # swap-vs-re-prefill: promoting is a per-admission decision —
            # either the whole demoted span is worth the link copies
            # (plan-tuned swap_threshold pages) or the match truncates at
            # the first demoted entry and those chunks re-prefill
            promote = (self.tiers is not None
                       and n_demoted >= self.swap_threshold)
            for pg, tier, hid in zip(m.pages, m.tiers, m.hids):
                if tier == 0:
                    kept.append(("share", pg))
                elif promote:
                    kept.append(("promote", hid))
                else:
                    break
            if kept and len(kept) * ps == prompt_len:
                # prompt fully covered: the tail page still must yield the
                # last-token logits, so the engine re-runs the final chunk.
                # A committed shared tail is forked (COW — the rewrite
                # lands in a private copy); a pending tail has no content
                # to copy yet, so just prefill that page ourselves; a
                # *promoted* tail needs neither — its fresh tier-0 page is
                # private already, so the re-run writes it in place.
                kind, val = kept[-1]
                if kind == "share":
                    if m.pending[len(kept) - 1] is not None:
                        kept.pop()
                    else:
                        fork_src = val
                        kept.pop()
            if m.pending_level >= 0:
                level = m.pending_level + 1
        n_shared = sum(1 for kind, _ in kept if kind == "share")
        n_promote = len(kept) - n_shared
        shared_len = (len(kept) + (1 if fork_src is not None else 0)) * ps

        # lazy: reserve what prefill will actually write (shared prefix
        # excluded; COW-fork destinations and promoted pages count as
        # writes) plus ONE decode growth page (capped at the request's
        # true total footprint) — without the headroom a request admitted
        # into a dry pool would pay the whole chunked prefill and be
        # preempted on its very first decode write, thrashing one token
        # per re-prefill. Further growth goes through ensure(),
        # preempting on exhaustion.
        need = min(self.pool.pages_for(prompt_len) + 1,
                   self.pool.pages_for(prompt_len + max_new)) - n_shared

        # Pin before any reclaim can run: share() the matched tier-0
        # pages (a session page's lone ref might otherwise be the one
        # reclaim demotes) and pop promoted slabs out of the tiered store
        # (reclaim demotes *into* the store and could otherwise LRU-evict
        # the very slabs this admission is about to upload).
        share_pages = [v for kind, v in kept if kind == "share"]
        self.pool.share(share_pages)
        promos = [(i, hid, self.tiers.pop(hid))
                  for i, (kind, hid) in enumerate(kept) if kind == "promote"]
        fresh = self._alloc_reclaiming(need)
        if fresh is None:
            # roll back: net refcounts restored; slabs re-demoted (their
            # entries rebound to the new handles, purged only if the
            # store is truly full)
            for page in self.pool.free(share_pages):
                self.prefix.drop_page(page)
            for _i, hid, slab in promos:
                new_hid = self.tiers.demote(slab)
                if new_hid is None:
                    self.prefix.purge_hid(hid)
                else:
                    self.prefix.rebind_hid(hid, new_hid)
                    self.prefix.set_tier(new_hid,
                                         self.tiers.tier_of(new_hid))
            if share_pages or promos:
                self._bt_dirty = True
                self._gp_dirty = True
            return None
        pages: list[int] = []
        fi = 0
        pending_promotions: list[tuple] = []
        slab_by_chunk = {i: slab for i, _hid, slab in promos}
        for i, (kind, val) in enumerate(kept):
            if kind == "share":
                pages.append(val)
                if val in self._session:
                    self._session.move_to_end(val)   # LRU recency
                    session_mapped += 1
            else:
                dst = fresh[fi]
                fi += 1
                pending_promotions.append((slab_by_chunk[i], dst))
                self.prefix.promote_hid(val, dst)
                pages.append(dst)
        pages += fresh[fi:]
        slot = PagedSlot(request_id, prompt_len, 0, max_new,
                         pages=pages,
                         shared_len=shared_len, prefill_level=level,
                         pending_promotions=pending_promotions,
                         session_mapped=session_mapped + n_promote)
        if fork_src is not None:
            # block table already points at the fork destination
            # (pages[len(kept)] = fresh[fi]); the engine copies the slab
            # before prefill, then re-runs the final chunk into it
            slot.pending_fork = (fork_src, fresh[fi])
        slot.prefill_start = min(shared_len, prompt_len)
        return slot

    def _alloc_reclaiming(self, n: int) -> Optional[list]:
        """``pool.alloc`` that spends the session cache before failing:
        on a dry pool, ask the engine to demote LRU session pages
        (``reclaim_cb``) and retry — finished-session KV is a cache, and
        a cache must never win a page fight against live admission or
        growth."""
        got = self.pool.alloc(n)
        if got is not None or self.reclaim_cb is None:
            return got
        if self.reclaim_cb(n - self.pool.free_pages):
            return self.pool.alloc(n)
        return None

    def ensure(self, idx: int, positions: int) -> bool:
        """Grow slot ``idx``'s block table to cover ``positions`` KV
        entries. False = the pool is dry (caller preempts and retries);
        the slot's existing pages are untouched either way."""
        s = self.slots[idx]
        need = self.pool.pages_for(positions) - len(s.pages)
        if need <= 0:
            return True
        got = self._alloc_reclaiming(need)
        if got is None:
            return False
        s.pages.extend(got)
        self._bt_dirty = True
        self._gp_dirty = True
        return True

    def fork_for_write(self, idx: int, start: int, end: int):
        """Copy-on-write hook: before slot ``idx`` writes KV positions
        ``[start, end)``, fork every covered page whose refcount > 1 —
        allocate a private destination, patch the block table, drop one
        ref on the source. Returns the ``(src, dst)`` pairs whose
        device slabs the engine must copy, or ``None`` when the pool is
        dry — side-effect free, so the caller preempts and retries
        against unchanged state and can never skip a pending slab copy.

        Every destination is reserved **up front** (one
        ``_alloc_reclaiming`` call): the session-cache reclaim a dry
        alloc may trigger demotes pages and mutates refcounts, so it
        must run before this fork takes any ref — never between a
        source's ref-drop and the engine's slab copy."""
        s = self.slots[idx]
        ps = self.pool.page_size
        to_fork: list[int] = []
        for pi in range(start // ps, (max(end, start + 1) - 1) // ps + 1):
            if pi >= len(s.pages):
                break                    # growth is ensure()'s job
            if self.pool.refcount(s.pages[pi]) > 1:
                to_fork.append(pi)
        if not to_fork:
            return []
        dsts = self._alloc_reclaiming(len(to_fork))
        if dsts is None:
            return None
        out: list[tuple[int, int]] = []
        for pi, dst in zip(to_fork, dsts):
            src = s.pages[pi]
            # a reclaim during the reservation may have dropped a session
            # ref and left src private after all — the fork is then
            # redundant but harmless, except its source can now die
            for page in self.pool.free([src]):
                if self.prefix is not None:
                    self.prefix.drop_page(page)
            s.pages[pi] = dst
            out.append((src, dst))
        self._bt_dirty = True
        self._gp_dirty = True
        return out

    def commit_prefix(self, idx: int, tokens) -> None:
        """Prefill for slot ``idx`` completed: the full prompt pages now
        hold real KV, so pending index entries become matchable-safe and
        this slot's own fresh full pages stay registered for the next
        arrival."""
        if self.prefix is not None:
            self.prefix.commit(tokens)

    def release(self, idx: int) -> None:
        s = self.slots[idx]
        if s.pages:
            for page in self.pool.free(s.pages):
                if self.prefix is not None:
                    self.prefix.drop_page(page)
            self._bt_dirty = True
            self._gp_dirty = True
        super().release(idx)

    # -- session cache (tier-0 retention + demotion under pressure) ----------

    def retain_session(self, idx: int, tokens) -> int:
        """Retire/preempt a slot *without discarding its KV*: register
        every full page of ``tokens`` (the slot's KV-valid token prefix)
        in the prefix index and transfer this slot's ref on each
        registered page to the manager's LRU session set — the tier-0
        session cache. A returning conversation re-maps those pages by
        refcount bump; pool pressure demotes them host-ward through
        :meth:`reclaim_session` instead. Pages the index does not hold
        (partial tail, superseded duplicates) are freed as usual.
        Returns how many pages the session set newly retained."""
        assert self.prefix is not None, "session cache needs a prefix index"
        s = self.slots[idx]
        self.prefix.register(tokens, s.pages)
        self.prefix.commit(tokens)
        indexed = self.prefix.shared_page_ids()
        retained = 0
        to_free: list[int] = []
        for p in s.pages:
            if p in indexed and p not in self._session:
                self._session[p] = None       # ref transfers to the cache
                retained += 1
            else:
                to_free.append(p)
        for page in self.pool.free(to_free):
            self.prefix.drop_page(page)
        s.pages = []
        self._bt_dirty = True
        self._gp_dirty = True
        self.release(idx)
        return retained

    def reclaim_session(self, need: int, gather) -> int:
        """Drop session-cache refs LRU-first until ``need`` pages return
        to the free list (or the cache is empty). A page whose *last* ref
        was the session's dies — its slab is bulk-copied device→host
        first (``gather(pages) -> {page: slab}``, one copy for the whole
        batch) and demoted into the tiered store, the index entry rebound
        to the store handle. A page some live slot still shares survives
        with its entry untouched; dropping the session ref just stops
        pinning it. Returns how many pages were actually freed."""
        if not self._session:
            return 0
        drop: list[int] = []
        expect = 0
        for p in self._session:               # LRU -> MRU order
            drop.append(p)
            if self.pool.refcount(p) == 1:
                expect += 1
            if expect >= need:
                break
        dying = [p for p in drop if self.pool.refcount(p) == 1]
        slabs = gather(dying) if dying and self.tiers is not None else {}
        freed = 0
        for p in drop:
            del self._session[p]
            if not self.pool.free([p]):
                continue                      # survives through a slot
            freed += 1
            hid = self.tiers.demote(slabs[p]) \
                if self.tiers is not None and p in slabs else None
            if hid is None or not self.prefix.demote_page(
                    p, hid, tier=self.tiers.tier_of(hid)):
                if hid is not None:
                    self.tiers.drop(hid)      # page wasn't indexed
                self.prefix.drop_page(p)
        self._bt_dirty = True
        self._gp_dirty = True
        return freed

    def session_pages(self) -> int:
        return len(self._session)

    def block_tables(self):
        """Dense (num_slots, max_pages_per_seq) int32 block-table operand
        for the jitted steps — a **cached device array**, rebuilt only
        when some slot's table changed since the last call, so
        steady-state decode ticks hand the model the same device-resident
        buffer instead of re-uploading an unchanged table every tick.

        Unassigned entries hold the out-of-bounds sentinel ``num_pages``:
        KV scatters through them are dropped (so an empty slot in the
        batch can never corrupt a page another sequence owns) and reads
        clamp to a real page whose contents the attention length-mask
        discards.
        """
        if self._bt_dirty or self._bt_cache is None:
            import jax.numpy as jnp
            bt = np.full((len(self.slots), self.max_pages_per_seq),
                         self.pool.num_pages, np.int32)
            for i, s in enumerate(self.slots):
                if s.pages:
                    bt[i, :len(s.pages)] = s.pages
            self._bt_cache = jnp.asarray(bt)
            self._bt_dirty = False
        return self._bt_cache

    def group_plan(self, threshold: int = 2) -> Optional[GroupPlan]:
        """Shared-prefix grouping for this tick's decode batch, or
        ``None`` when no group is worth dispatching — cached under the
        same dirty discipline as :meth:`block_tables` (rebuilt only when
        some table or refcount changed), so steady-state grouped decode
        reuses one host plan and its device operands tick after tick.

        A group survives only if it has >= 2 members **and** its
        deduplicated work ``members * prefix_pages >= threshold`` — below
        that the extra kernel stage costs more than the KV reads it
        saves (the plan's ``group_threshold`` knob, calibrated by
        ``dispatch.find_group_threshold``). Members must already cover
        their shared prefix (``length >= prefix_len``); a mid-prefill
        resident is left solo rather than read past its valid KV.
        """
        if not self._gp_dirty and self._gp_cache is not None \
                and self._gp_cache[0] == threshold:
            return self._gp_cache[1]
        plan = self._build_group_plan(threshold)
        self._gp_cache = (threshold, plan)
        self._gp_dirty = False
        return plan

    def _build_group_plan(self, threshold: int) -> Optional[GroupPlan]:
        ps = self.pool.page_size
        kept = []
        for key, members in shared_prefix_groups(self.slots,
                                                 self.pool.refcount):
            plen = len(key) * ps
            live = [i for i in members if self.slots[i].length >= plen]
            if len(live) >= 2 and len(live) * len(key) >= threshold:
                kept.append((key, live))
        if not kept:
            return None
        b = len(self.slots)
        ng = pow2_bucket(len(kept))
        lp = pow2_bucket(max(len(k) for k, _ in kept),
                         hi=self.max_pages_per_seq)
        m = pow2_bucket(max(len(ms) for _, ms in kept), hi=b)
        sentinel = self.pool.num_pages
        tables = np.full((ng, lp), sentinel, np.int32)
        n_pages = np.zeros(ng, np.int32)
        g_prefix_len = np.zeros(ng, np.int32)
        num_members = np.zeros(ng, np.int32)
        member_rows = np.full((ng, m), b, np.int32)
        gid = np.full(b, ng, np.int32)          # NG = solo sentinel
        member = np.zeros(b, np.int32)
        prefix_len = np.zeros(b, np.int32)
        n_grouped = 0
        pages_deduped = 0
        for g, (key, live) in enumerate(kept):
            tables[g, :len(key)] = key
            n_pages[g] = len(key)
            g_prefix_len[g] = len(key) * ps
            num_members[g] = len(live)
            member_rows[g, :len(live)] = live
            for r, i in enumerate(live):
                gid[i] = g
                member[i] = r
                prefix_len[i] = len(key) * ps
            n_grouped += len(live)
            pages_deduped += (len(live) - 1) * len(key)
        return GroupPlan(gid=gid, member=member, prefix_len=prefix_len,
                         tables=tables, n_pages=n_pages,
                         g_prefix_len=g_prefix_len,
                         num_members=num_members, member_rows=member_rows,
                         n_grouped=n_grouped, pages_deduped=pages_deduped)

    def check(self) -> None:
        """Cross-structure invariants for the property tests: free/ref
        conservation in the pool, and — the refcount invariant — the
        ownership multiset across slot block tables *plus the session
        cache's one-ref-per-page holdings* equals the pool's refcounts
        exactly. With a tiered store attached, every demoted index entry
        must resolve to a live slab."""
        self.pool.check()
        owned: dict[int, int] = {}
        for s in self.slots:
            if s.free:
                assert not s.pages, "free slot still holds pages"
            for p in s.pages:
                owned[p] = owned.get(p, 0) + 1
        for s in self.slots:
            assert len(set(s.pages)) == len(s.pages), \
                "one slot maps the same page twice (fork aliased)"
        for p in self._session:
            owned[p] = owned.get(p, 0) + 1
            assert self.prefix is not None \
                and p in self.prefix.shared_page_ids(), \
                f"session cache holds unindexed page {p}"
        assert {p: self.pool.refcount(p) for p in owned} == owned, \
            "refcounts out of sync with slot+session ownership multiset"
        assert set(owned) == self.pool.allocated_pages(), \
            "pool used-set out of sync with slot block tables"
        if self.tiers is not None:
            self.tiers.check()
        if self.prefix is not None:
            self.prefix.check(
                self.pool.allocated_pages(),
                self.tiers.ids() if self.tiers is not None else frozenset())
