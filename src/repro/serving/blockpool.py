"""Block-paged KV cache management (host side): a refcounted page pool
with copy-on-write prefix sharing.

The paper's §3/Fig. 2 critique of static dataflow applies to memory as
much as compute: a dense ``(num_slots, max_seq)`` cache provisions every
slot for the worst-case sequence, so short requests strand capacity and
admission is bounded by slots, not by actual KV bytes. This module
replaces that with a **block pool**: KV storage is a flat array of
fixed-size pages shared by all sequences, each sequence owns an ordered
list of page ids (its *block table*), and pages cycle through an explicit
LIFO free-list.

Pages are **refcounted**, which buys two things on top of plain paging:

  * **Prefix sharing.** N requests with the same system prompt /
    few-shot header map their page-aligned common prefix onto *one*
    physical copy: admission consults a
    :class:`~repro.serving.prefix.PrefixIndex` (hash chain of page-sized
    token chunks -> live page), bumps the refcount of every matched page
    (:meth:`BlockPool.share`), and prefills only the unshared suffix.
    ``free`` decrements; a page returns to the free list — and leaves the
    index — only when its last owner lets go, so a victim's release never
    tears pages out from under the sequences still reading them.

  * **Copy-on-write.** Shared pages are immutable: the first write into a
    page with refcount > 1 forks it — the manager allocates a fresh page,
    patches the writer's block table, and drops one ref
    (:meth:`PagedSlotManager.fork_for_write`); the engine copies the
    ``(layers, page_size, kv_heads, head_dim)`` slab on device. Everything
    downstream (decode, preemption, release) then treats the fork like any
    privately owned page.

Device layout (see :func:`repro.models.transformer.init_cache` with a
:class:`~repro.models.kvlayout.PagedLayout`):

    k/v pool: (num_layers, num_pages, page_size, kv_heads, head_dim)

Logical position ``p`` of the sequence in slot ``s`` lives at physical
``(block_tables[s, p // page_size], p % page_size)``. Block tables are a
dense ``(num_slots, max_pages_per_seq)`` int32 array handed to the jitted
decode/prefill-chunk steps each tick — **cached device-side** by the
manager and rebuilt only when some table actually changed (alloc, lazy
growth, release, COW fork), so steady-state decode ticks reuse the
device-resident operand. Unassigned entries hold the out-of-bounds
sentinel ``num_pages`` — KV scatters through them are dropped
(``mode="drop"``), and reads clamp to a real page whose contents the
attention length-mask discards. Correctness of empty slots in a partially
occupied batch depends on that sentinel: a 0 entry would alias a real
page another sequence may own.

Two classes:

  * :class:`BlockPool` — the refcounting free-list allocator (no device
    state). Invariant: every page is either on the free list with
    refcount 0, or allocated with refcount >= 1; the sum of refcounts
    equals the ownership multiset across slot block tables
    (:meth:`PagedSlotManager.check` enforces the cross-structure half).
  * :class:`PagedSlotManager` — drop-in replacement for
    :class:`repro.serving.kvcache.SlotManager` that additionally owns the
    per-slot block tables and (optionally) the prefix index. Allocation
    is **lazy**: admission reserves pages for the tokens that will
    actually be prefilled (shared prefix excluded) plus one decode growth
    page of headroom, and each decode tick grows a sequence's table
    page-by-page through :meth:`ensure` — so a pool can be overcommitted
    below worst-case footprint and the engine's scheduler preempts a
    victim (refs dropped, request re-queued) when :meth:`ensure` reports
    the pool dry. The block tables make preemption relocation-free: a
    re-admitted sequence just gets fresh pages — or re-maps its shared
    prefix if the pages survived through another owner.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models.kvlayout import pages_for, pow2_bucket  # noqa: F401
# (pages_for re-export: the one page ceil-div definition, shared with
# layouts/engine/benchmarks)
from repro.serving.kvcache import Slot, SlotManager
from repro.serving.prefix import PrefixIndex, shared_prefix_groups


class BlockPool:
    """Refcounted fixed-size page allocator over ``num_pages`` pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO: a just-freed (hot) page is reused first
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._ref: dict[int, int] = {}     # page -> refcount (>= 1)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._ref)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts = what a share-less pool would have used."""
        return sum(self._ref.values())

    def allocated_pages(self) -> set:
        """Snapshot of page ids with refcount >= 1."""
        return set(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def pages_for(self, positions: int) -> int:
        """Pages needed to store ``positions`` KV entries."""
        return pages_for(positions, self.page_size)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` pages off the free list (refcount 1 each); None if
        not enough remain."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def share(self, pages: list[int]) -> None:
        """Add one owner to each (already allocated) page."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"cannot share unallocated page {p}")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: list[int]) -> list[int]:
        """Drop one ref per page; pages reaching refcount 0 return to the
        free list. Returns the pages that actually **died** — the caller
        (slot manager) purges those from the prefix index so a stale key
        can never resolve to a recycled page."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"double free / foreign page {p}")
        dead = []
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                self._free.append(p)
                dead.append(p)
        return dead

    def check(self) -> None:
        """Invariant check (used by the property tests): every page is on
        exactly one side of the free/allocated split, and every allocated
        page has a positive refcount."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & set(self._ref)), "page both free and allocated"
        assert free | set(self._ref) == set(range(self.num_pages)), \
            "page leaked out of the pool"
        assert all(r >= 1 for r in self._ref.values()), \
            "allocated page with refcount < 1"


@dataclasses.dataclass
class GroupPlan:
    """One decode tick's shared-prefix grouping, host side.

    Built by :meth:`PagedSlotManager.group_plan` from the refcount-derived
    groups of :func:`~repro.serving.prefix.shared_prefix_groups`, with
    every dimension pow2-bucketed (groups NG, prefix pages LP, members M)
    so steady workloads hit a handful of jit shapes. Padding groups carry
    zero counts; padding table entries hold the pool's out-of-bounds
    sentinel; solo rows have ``gid == NG`` and ``prefix_len == 0``.

    ``operands()`` lazily uploads the arrays as the
    :class:`~repro.kernels.group_attention.DecodeGroups` pytree the model
    steps take — cached, so the steady-state tick reuses the same device
    buffers (the manager only rebuilds the plan when a block table
    changed).
    """

    gid: np.ndarray            # (B,)  group id per slot row; NG = solo
    member: np.ndarray         # (B,)  rank within the group; 0 for solo
    prefix_len: np.ndarray     # (B,)  shared positions; 0 = solo
    tables: np.ndarray         # (NG, LP) physical pages of each prefix
    n_pages: np.ndarray        # (NG,) live prefix pages per group
    g_prefix_len: np.ndarray   # (NG,) shared positions per group
    num_members: np.ndarray    # (NG,) live members per group
    member_rows: np.ndarray    # (NG, M) slot row of each member; B = pad
    n_grouped: int             # total rows covered by some group
    pages_deduped: int         # sum over groups of (members - 1) * pages
    _operands: object = dataclasses.field(default=None, repr=False)

    def operands(self):
        if self._operands is None:
            import jax.numpy as jnp

            from repro.kernels.group_attention import DecodeGroups
            self._operands = DecodeGroups(
                tables=jnp.asarray(self.tables),
                n_pages=jnp.asarray(self.n_pages),
                g_prefix_len=jnp.asarray(self.g_prefix_len),
                num_members=jnp.asarray(self.num_members),
                member_rows=jnp.asarray(self.member_rows),
                gid=jnp.asarray(self.gid),
                member=jnp.asarray(self.member),
                prefix_len=jnp.asarray(self.prefix_len),
            )
        return self._operands


@dataclasses.dataclass
class PagedSlot(Slot):
    pages: list = dataclasses.field(default_factory=list)
    # prefix-sharing admission metadata (all zero when sharing is off)
    shared_len: int = 0          # prefix positions mapped onto shared pages
    prefill_start: int = 0       # first position the engine must prefill
    prefill_level: int = 0       # same-wave ordering: prefill after every
    #                              slot whose pending pages this one mapped
    pending_fork: Optional[tuple] = None   # (src, dst): slab copy the
    #                              engine owes before this slot's prefill


class PagedSlotManager(SlotManager):
    """Slot occupancy + block tables over a shared :class:`BlockPool`.

    Inherits the ``SlotManager`` tick-loop interface (``lengths`` /
    ``tick`` and the admission scan) so the engine can switch cache kinds
    without touching its loop. Admission requires pages for the tokens
    about to be prefilled plus one growth page — minus whatever prefix the
    :class:`~repro.serving.prefix.PrefixIndex` maps onto existing pages
    (``prefix_index=None`` disables sharing); decode-time growth goes
    through :meth:`ensure` (lazy allocation), writes into shared pages
    fork through :meth:`fork_for_write`, and release drops one ref per
    page — the free list only sees pages whose last owner let go.
    """

    def __init__(self, num_slots: int, max_seq: int, pool: BlockPool,
                 prefix_index: Optional[PrefixIndex] = None):
        self.pool = pool
        self.prefix = prefix_index
        if prefix_index is not None and \
                prefix_index.page_size != pool.page_size:
            raise ValueError("prefix index / pool page_size mismatch")
        self.max_pages_per_seq = pool.pages_for(max_seq)
        # dense (num_slots, max_pages_per_seq) block-table operand, cached
        # device-side; rebuilt only when a table changed (alloc / ensure /
        # release / COW fork) so steady-state decode ticks reuse it
        self._bt_cache = None
        self._bt_dirty = True
        # the shared-prefix group plan is a pure function of the block
        # tables + refcounts, so it shares the block-table dirty
        # discipline: every event that invalidates _bt_cache (admission,
        # growth, COW fork, release) invalidates the plan too
        self._gp_cache = None
        self._gp_dirty = True
        super().__init__(num_slots, max_seq)

    def _empty_slot(self) -> PagedSlot:
        return PagedSlot()

    def try_assign(self, request_id: int, prompt_len: int, max_new: int,
                   tokens=None) -> Optional[int]:
        idx = super().try_assign(request_id, prompt_len, max_new,
                                 tokens=tokens)
        if idx is not None:
            self._bt_dirty = True
            self._gp_dirty = True
            if self.prefix is not None and tokens is not None:
                # promise this slot's full prompt pages to later arrivals
                # (entries pending at this slot's wave level until its
                # prefill commits them)
                self.prefix.register(
                    tokens, self.slots[idx].pages,
                    level=self.slots[idx].prefill_level)
        return idx

    def _make_slot(self, request_id: int, prompt_len: int, max_new: int,
                   tokens=None) -> Optional[PagedSlot]:
        worst = self.pool.pages_for(prompt_len + max_new)
        if worst > self.pool.num_pages:
            # can never be satisfied, not even by an empty pool — raise like
            # the max_seq check (returning None would livelock admission,
            # and lazily admitting would guarantee an unservable mid-decode
            # growth failure with no preemptable victim once it runs alone)
            raise ValueError(
                f"request {request_id} needs {worst} pages > pool size "
                f"{self.pool.num_pages} (page_size {self.pool.page_size})")

        ps = self.pool.page_size
        shared: list[int] = []
        level = 0
        fork_src: Optional[int] = None
        if self.prefix is not None and tokens is not None and prompt_len:
            m = self.prefix.match(tokens)
            shared = list(m.pages)
            if shared and len(shared) * ps == prompt_len:
                # prompt fully covered: the tail page still must yield the
                # last-token logits, so the engine re-runs the final chunk.
                # A committed tail is forked (COW — the rewrite lands in a
                # private copy); a pending tail has no content to copy yet,
                # so just prefill that page ourselves.
                if m.tail_pending:
                    shared.pop()
                else:
                    fork_src = shared.pop()
            if m.pending_level >= 0:
                level = m.pending_level + 1
        n_shared = len(shared)
        shared_len = (n_shared + (1 if fork_src is not None else 0)) * ps

        # lazy: reserve what prefill will actually write (shared prefix
        # excluded; the COW fork's destination counts as a write) plus ONE
        # decode growth page (capped at the request's true total
        # footprint) — without the headroom a request admitted into a dry
        # pool would pay the whole chunked prefill and be preempted on its
        # very first decode write, thrashing one token per re-prefill.
        # Further growth goes through ensure(), preempting on exhaustion.
        need = min(self.pool.pages_for(prompt_len) + 1,
                   self.pool.pages_for(prompt_len + max_new)) - n_shared
        fresh = self.pool.alloc(need)
        if fresh is None:
            return None                  # no refs taken — side-effect free
        self.pool.share(shared)
        slot = PagedSlot(request_id, prompt_len, 0, max_new,
                         pages=shared + fresh,
                         shared_len=shared_len, prefill_level=level)
        if fork_src is not None:
            # block table already points at the fork destination
            # (pages[n_shared] = fresh[0]); the engine copies the slab
            # before prefill, then re-runs the final chunk into it
            slot.pending_fork = (fork_src, fresh[0])
        slot.prefill_start = min(shared_len, prompt_len)
        return slot

    def ensure(self, idx: int, positions: int) -> bool:
        """Grow slot ``idx``'s block table to cover ``positions`` KV
        entries. False = the pool is dry (caller preempts and retries);
        the slot's existing pages are untouched either way."""
        s = self.slots[idx]
        need = self.pool.pages_for(positions) - len(s.pages)
        if need <= 0:
            return True
        got = self.pool.alloc(need)
        if got is None:
            return False
        s.pages.extend(got)
        self._bt_dirty = True
        self._gp_dirty = True
        return True

    def fork_for_write(self, idx: int, start: int, end: int):
        """Copy-on-write hook: before slot ``idx`` writes KV positions
        ``[start, end)``, fork every covered page whose refcount > 1 —
        allocate a private destination, patch the block table, drop one
        ref on the source. Returns the ``(src, dst)`` pairs whose
        device slabs the engine must copy, or ``None`` when the pool is
        dry — in which case every fork this call already made is rolled
        back (table restored, ref re-taken, destination freed), so the
        caller preempts and retries against unchanged state and can
        never skip a pending slab copy."""
        s = self.slots[idx]
        ps = self.pool.page_size
        forked: list[tuple[int, int, int]] = []     # (page idx, src, dst)
        for pi in range(start // ps, (max(end, start + 1) - 1) // ps + 1):
            if pi >= len(s.pages):
                break                    # growth is ensure()'s job
            src = s.pages[pi]
            if self.pool.refcount(src) <= 1:
                continue                 # private already — write in place
            got = self.pool.alloc(1)
            if got is None:
                for pj, prev, dst in forked:
                    s.pages[pj] = prev
                    self.pool.share([prev])
                    self.pool.free([dst])
                self._bt_dirty = True
                self._gp_dirty = True
                return None
            dst = got[0]
            self.pool.free([src])        # drop our ref; survivors keep it
            s.pages[pi] = dst
            self._bt_dirty = True
            self._gp_dirty = True
            forked.append((pi, src, dst))
        return [(src, dst) for _pi, src, dst in forked]

    def commit_prefix(self, idx: int, tokens) -> None:
        """Prefill for slot ``idx`` completed: the full prompt pages now
        hold real KV, so pending index entries become matchable-safe and
        this slot's own fresh full pages stay registered for the next
        arrival."""
        if self.prefix is not None:
            self.prefix.commit(tokens)

    def release(self, idx: int) -> None:
        s = self.slots[idx]
        if s.pages:
            for page in self.pool.free(s.pages):
                if self.prefix is not None:
                    self.prefix.drop_page(page)
            self._bt_dirty = True
            self._gp_dirty = True
        super().release(idx)

    def block_tables(self):
        """Dense (num_slots, max_pages_per_seq) int32 block-table operand
        for the jitted steps — a **cached device array**, rebuilt only
        when some slot's table changed since the last call, so
        steady-state decode ticks hand the model the same device-resident
        buffer instead of re-uploading an unchanged table every tick.

        Unassigned entries hold the out-of-bounds sentinel ``num_pages``:
        KV scatters through them are dropped (so an empty slot in the
        batch can never corrupt a page another sequence owns) and reads
        clamp to a real page whose contents the attention length-mask
        discards.
        """
        if self._bt_dirty or self._bt_cache is None:
            import jax.numpy as jnp
            bt = np.full((len(self.slots), self.max_pages_per_seq),
                         self.pool.num_pages, np.int32)
            for i, s in enumerate(self.slots):
                if s.pages:
                    bt[i, :len(s.pages)] = s.pages
            self._bt_cache = jnp.asarray(bt)
            self._bt_dirty = False
        return self._bt_cache

    def group_plan(self, threshold: int = 2) -> Optional[GroupPlan]:
        """Shared-prefix grouping for this tick's decode batch, or
        ``None`` when no group is worth dispatching — cached under the
        same dirty discipline as :meth:`block_tables` (rebuilt only when
        some table or refcount changed), so steady-state grouped decode
        reuses one host plan and its device operands tick after tick.

        A group survives only if it has >= 2 members **and** its
        deduplicated work ``members * prefix_pages >= threshold`` — below
        that the extra kernel stage costs more than the KV reads it
        saves (the plan's ``group_threshold`` knob, calibrated by
        ``dispatch.find_group_threshold``). Members must already cover
        their shared prefix (``length >= prefix_len``); a mid-prefill
        resident is left solo rather than read past its valid KV.
        """
        if not self._gp_dirty and self._gp_cache is not None \
                and self._gp_cache[0] == threshold:
            return self._gp_cache[1]
        plan = self._build_group_plan(threshold)
        self._gp_cache = (threshold, plan)
        self._gp_dirty = False
        return plan

    def _build_group_plan(self, threshold: int) -> Optional[GroupPlan]:
        ps = self.pool.page_size
        kept = []
        for key, members in shared_prefix_groups(self.slots,
                                                 self.pool.refcount):
            plen = len(key) * ps
            live = [i for i in members if self.slots[i].length >= plen]
            if len(live) >= 2 and len(live) * len(key) >= threshold:
                kept.append((key, live))
        if not kept:
            return None
        b = len(self.slots)
        ng = pow2_bucket(len(kept))
        lp = pow2_bucket(max(len(k) for k, _ in kept),
                         hi=self.max_pages_per_seq)
        m = pow2_bucket(max(len(ms) for _, ms in kept), hi=b)
        sentinel = self.pool.num_pages
        tables = np.full((ng, lp), sentinel, np.int32)
        n_pages = np.zeros(ng, np.int32)
        g_prefix_len = np.zeros(ng, np.int32)
        num_members = np.zeros(ng, np.int32)
        member_rows = np.full((ng, m), b, np.int32)
        gid = np.full(b, ng, np.int32)          # NG = solo sentinel
        member = np.zeros(b, np.int32)
        prefix_len = np.zeros(b, np.int32)
        n_grouped = 0
        pages_deduped = 0
        for g, (key, live) in enumerate(kept):
            tables[g, :len(key)] = key
            n_pages[g] = len(key)
            g_prefix_len[g] = len(key) * ps
            num_members[g] = len(live)
            member_rows[g, :len(live)] = live
            for r, i in enumerate(live):
                gid[i] = g
                member[i] = r
                prefix_len[i] = len(key) * ps
            n_grouped += len(live)
            pages_deduped += (len(live) - 1) * len(key)
        return GroupPlan(gid=gid, member=member, prefix_len=prefix_len,
                         tables=tables, n_pages=n_pages,
                         g_prefix_len=g_prefix_len,
                         num_members=num_members, member_rows=member_rows,
                         n_grouped=n_grouped, pages_deduped=pages_deduped)

    def check(self) -> None:
        """Cross-structure invariants for the property tests: free/ref
        conservation in the pool, and — the refcount invariant — the
        ownership multiset across slot block tables equals the pool's
        refcounts exactly."""
        self.pool.check()
        owned: dict[int, int] = {}
        for s in self.slots:
            if s.free:
                assert not s.pages, "free slot still holds pages"
            for p in s.pages:
                owned[p] = owned.get(p, 0) + 1
        for s in self.slots:
            assert len(set(s.pages)) == len(s.pages), \
                "one slot maps the same page twice (fork aliased)"
        assert {p: self.pool.refcount(p) for p in owned} == owned, \
            "refcounts out of sync with slot ownership multiset"
        assert set(owned) == self.pool.allocated_pages(), \
            "pool used-set out of sync with slot block tables"
        if self.prefix is not None:
            self.prefix.check(self.pool.allocated_pages())
