"""Block-paged KV cache management (host side).

The paper's §3/Fig. 2 critique of static dataflow applies to memory as much
as compute: a dense ``(num_slots, max_seq)`` cache provisions every slot for
the worst-case sequence, so short requests strand capacity and admission is
bounded by slots, not by actual KV bytes. This module replaces that with a
**block pool**: KV storage is a flat array of fixed-size pages shared by all
sequences, each sequence owns an ordered list of page ids (its *block
table*), and pages cycle through an explicit LIFO free-list on release.

Device layout (see :func:`repro.models.transformer.init_cache` with a
:class:`~repro.models.kvlayout.PagedLayout`):

    k/v pool: (num_layers, num_pages, page_size, kv_heads, head_dim)

Logical position ``p`` of the sequence in slot ``s`` lives at physical
``(block_tables[s, p // page_size], p % page_size)``. Block tables are a
dense ``(num_slots, max_pages_per_seq)`` int32 array handed to the jitted
decode/prefill-chunk steps each tick; unassigned entries hold the
out-of-bounds sentinel ``num_pages`` — KV scatters through them are
dropped (``mode="drop"``), and reads clamp to a real page whose contents
the attention length-mask discards. Correctness of empty slots in a
partially occupied batch depends on that sentinel: a 0 entry would alias a
real page another sequence may own.

Two classes:

  * :class:`BlockPool` — the free-list allocator (no device state).
  * :class:`PagedSlotManager` — drop-in replacement for
    :class:`repro.serving.kvcache.SlotManager` that additionally owns the
    per-slot block tables. Allocation is **lazy**: admission reserves
    pages for the tokens that will be prefilled (plus one decode growth
    page of headroom), and each decode tick grows a sequence's table
    page-by-page through :meth:`ensure` — so a
    pool can be overcommitted below worst-case footprint and the engine's
    scheduler preempts a victim (pages freed, request re-queued) when
    :meth:`ensure` reports the pool dry. The block tables make preemption
    relocation-free: a re-admitted sequence just gets fresh pages.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models.kvlayout import pages_for  # noqa: F401  (re-export: the
# one page ceil-div definition, shared with layouts/engine/benchmarks)
from repro.serving.kvcache import Slot, SlotManager


class BlockPool:
    """Fixed-size page allocator over ``num_pages`` physical pages."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO: a just-freed (hot) page is reused first
        self._free: list[int] = list(range(num_pages - 1, -1, -1))
        self._used: set[int] = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._used)

    def pages_for(self, positions: int) -> int:
        """Pages needed to store ``positions`` KV entries."""
        return pages_for(positions, self.page_size)

    def alloc(self, n: int) -> Optional[list[int]]:
        """Pop ``n`` pages off the free list; None if not enough remain."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(f"double free / foreign page {p}")
            self._used.remove(p)
            self._free.append(p)

    def check(self) -> None:
        """Invariant check (used by the property tests): every page is on
        exactly one side of the free/used split."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & self._used), "page both free and allocated"
        assert free | self._used == set(range(self.num_pages)), \
            "page leaked out of the pool"


@dataclasses.dataclass
class PagedSlot(Slot):
    pages: list = dataclasses.field(default_factory=list)


class PagedSlotManager(SlotManager):
    """Slot occupancy + block tables over a shared :class:`BlockPool`.

    Inherits the ``SlotManager`` tick-loop interface (``lengths`` /
    ``tick`` and the admission scan) so the engine can switch cache kinds
    without touching its loop. Admission requires pages for the tokens
    about to be prefilled plus one growth page; decode-time growth goes
    through :meth:`ensure` (lazy allocation), and release returns every
    page to the free list.
    """

    def __init__(self, num_slots: int, max_seq: int, pool: BlockPool):
        self.pool = pool
        self.max_pages_per_seq = pool.pages_for(max_seq)
        super().__init__(num_slots, max_seq)

    def _empty_slot(self) -> PagedSlot:
        return PagedSlot()

    def _make_slot(self, request_id: int, prompt_len: int,
                   max_new: int) -> Optional[PagedSlot]:
        worst = self.pool.pages_for(prompt_len + max_new)
        if worst > self.pool.num_pages:
            # can never be satisfied, not even by an empty pool — raise like
            # the max_seq check (returning None would livelock admission,
            # and lazily admitting would guarantee an unservable mid-decode
            # growth failure with no preemptable victim once it runs alone)
            raise ValueError(
                f"request {request_id} needs {worst} pages > pool size "
                f"{self.pool.num_pages} (page_size {self.pool.page_size})")
        # lazy: reserve what prefill will write plus ONE decode growth page
        # (capped at the request's true total footprint) — without the
        # headroom a request admitted into a dry pool would pay the whole
        # chunked prefill and be preempted on its very first decode write,
        # thrashing one token per re-prefill. Further growth goes through
        # ensure(), preempting on pool exhaustion.
        need = min(self.pool.pages_for(prompt_len) + 1,
                   self.pool.pages_for(prompt_len + max_new))
        pages = self.pool.alloc(need)
        if pages is None:
            return None
        return PagedSlot(request_id, prompt_len, 0, max_new, pages=pages)

    def ensure(self, idx: int, positions: int) -> bool:
        """Grow slot ``idx``'s block table to cover ``positions`` KV
        entries. False = the pool is dry (caller preempts and retries);
        the slot's existing pages are untouched either way."""
        s = self.slots[idx]
        need = self.pool.pages_for(positions) - len(s.pages)
        if need <= 0:
            return True
        got = self.pool.alloc(need)
        if got is None:
            return False
        s.pages.extend(got)
        return True

    def release(self, idx: int) -> None:
        s = self.slots[idx]
        if s.pages:
            self.pool.free(s.pages)
        super().release(idx)

    def block_tables(self) -> np.ndarray:
        """Dense (num_slots, max_pages_per_seq) int32 block-table array.

        Unassigned entries hold the out-of-bounds sentinel ``num_pages``:
        KV scatters through them are dropped (so an empty slot in the batch
        can never corrupt a page another sequence owns) and reads clamp to
        a real page whose contents the attention length-mask discards.
        """
        bt = np.full((len(self.slots), self.max_pages_per_seq),
                     self.pool.num_pages, np.int32)
        for i, s in enumerate(self.slots):
            if s.pages:
                bt[i, :len(s.pages)] = s.pages
        return bt

    def check(self) -> None:
        """Cross-structure invariants for the property tests."""
        self.pool.check()
        owned: list[int] = []
        for s in self.slots:
            if s.free:
                assert not s.pages, "free slot still holds pages"
            owned.extend(s.pages)
        assert len(owned) == len(set(owned)), \
            "page owned by two sequences (double allocation)"
        assert set(owned) == self.pool._used, \
            "pool used-set out of sync with slot block tables"
