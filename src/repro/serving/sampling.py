"""Token sampling: greedy / temperature / top-k (pure JAX, vocab-padded
logits are masked by the caller or here via ``vocab_size``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,              # (B, V_padded) f32/bf16
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    vocab_size: int = 0,
) -> jax.Array:
    """Returns (B,) int32 next tokens."""
    logits = logits.astype(jnp.float32)
    if vocab_size and vocab_size < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad, -jnp.inf, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
