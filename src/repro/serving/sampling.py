"""Token sampling: greedy / temperature / top-k / top-p (pure JAX;
vocab-padded logits are masked by the caller or here via ``vocab_size``).

The engine drives this with a *per-request* PRNG key
(:class:`repro.serving.request.SamplingParams` carries an optional seed),
so one request's sampling order can never perturb another's — a
precondition for preemption being output-invariant under sampling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _top_p_mask(logits: jax.Array, top_p: float) -> jax.Array:
    """Nucleus filter: keep the smallest prefix of tokens (by descending
    probability) whose cumulative probability reaches ``top_p``. The
    highest-probability token always survives (the exclusive cumsum of the
    top token is 0 < top_p)."""
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs      # exclusive cumsum
    keep = cum_before < top_p                            # (B, V) sorted order
    # logit threshold = smallest kept logit; everything below is cut
    kth = jnp.min(jnp.where(keep, sorted_logits, jnp.inf),
                  axis=-1, keepdims=True)
    return jnp.where(logits < kth, -jnp.inf, logits)


def sample(
    logits: jax.Array,              # (B, V_padded) f32/bf16
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    vocab_size: int = 0,
) -> jax.Array:
    """Returns (B,) int32 next tokens."""
    logits = logits.astype(jnp.float32)
    if vocab_size and vocab_size < logits.shape[-1]:
        pad = jnp.arange(logits.shape[-1]) >= vocab_size
        logits = jnp.where(pad, -jnp.inf, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        logits = _top_p_mask(logits, top_p)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
