"""Pluggable scheduling policies: admission order and preemption victims.

The engine owns the *mechanism* (slots, pages, prefill/decode ticks,
preemption plumbing); a :class:`Scheduler` owns the *policy* — in what
order waiting requests are offered admission, whether a blocked head of
queue may be skipped, and which resident sequence is evicted when the page
pool runs dry mid-decode. Policies see only
:class:`~repro.serving.request.RequestState` cost signals (arrival order,
remaining token budget, KV footprint), never device state, so new policies
are a dozen lines.

Built-ins:

  * :class:`FCFS` — strict arrival order, head-of-line blocking (a request
    that cannot be admitted *stops* admission, so later arrivals can never
    overtake it: the no-starvation policy). Victim: newest arrival.

  * :class:`ShortestJobFirst` — order by remaining ``max_new_tokens``
    budget (the paper-adjacent cost-aware policy: short decodes drain
    slots fastest, keeping decode batches full). Skips blocked requests.
    Victim: the longest remaining job.

  * :class:`PageBudgetFair` — order by current KV footprint ascending
    (cheapest-to-host first — maximizes resident request count for a fixed
    page budget). Victim: the largest *exclusive* footprint — prefix
    sharing means evicting a sequence only reclaims pages nobody else
    refcounts, and its shared prefix re-maps (rather than re-prefills) on
    re-admission, so exclusive bytes are both the reclaim value and the
    eviction cost.

Preemption contract: ``pick_victim`` gets *every* resident sequence —
including the one that needs pages this tick, so e.g. FCFS really evicts
the newest arrival even when the newest is the one growing (it then
self-preempts and re-queues). Returning a candidate frees its pages and
re-queues it (state machine: RUNNING -> PREEMPTED -> re-admitted and
re-prefilled later). It must return a candidate when any exist; the
engine guards the lone-resident case itself.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.serving.request import RequestState


class Scheduler:
    """Base policy; subclasses override the two order functions."""

    name = "base"
    #: may admission skip a blocked request and try later arrivals?
    allow_skip = True

    def admission_order(
            self, waiting: Sequence[RequestState]) -> list[RequestState]:
        raise NotImplementedError

    def pick_victim(
            self, candidates: Sequence[RequestState]
    ) -> Optional[RequestState]:
        """Choose the resident sequence to evict; None iff no candidates."""
        raise NotImplementedError


class FCFS(Scheduler):
    name = "fcfs"
    allow_skip = False

    def admission_order(self, waiting):
        return sorted(waiting, key=lambda s: (s.arrival, s.rid))

    def pick_victim(self, candidates):
        # newest arrival loses: the oldest requests keep making progress,
        # so every admitted request eventually finishes (no livelock)
        return max(candidates, key=lambda s: (s.arrival, s.rid),
                   default=None)


class ShortestJobFirst(Scheduler):
    name = "sjf"
    allow_skip = True

    def admission_order(self, waiting):
        return sorted(
            waiting, key=lambda s: (s.remaining_new, s.arrival, s.rid))

    def pick_victim(self, candidates):
        return max(candidates,
                   key=lambda s: (s.remaining_new, s.arrival, s.rid),
                   default=None)


class PageBudgetFair(Scheduler):
    name = "pagefair"
    allow_skip = True

    def admission_order(self, waiting):
        return sorted(
            waiting, key=lambda s: (s.total_len, s.arrival, s.rid))

    def pick_victim(self, candidates):
        # cost signal knows about prefix sharing AND the tiered store:
        # evicting a request only reclaims its *exclusively* owned pages
        # (shared-prefix pages survive through the other owners, and
        # re-admission re-maps them instead of re-prefilling) — so rank
        # victims by exclusive footprint: most pages freed per eviction.
        # Among equals, prefer the victim whose re-admission recomputes
        # the least (``resume_cost``): with a TieredPool, a preemption
        # retains full pages in the session cache, so a sequence whose KV
        # can be demoted-and-promoted is cheaper to evict than one that
        # must re-prefill the same span. Without tiers resume_cost ==
        # exclusive_len and the ranking is unchanged.
        return max(candidates,
                   key=lambda s: (s.exclusive_len, -s.resume_cost, s.rid),
                   default=None)


SCHEDULERS = {
    cls.name: cls for cls in (FCFS, ShortestJobFirst, PageBudgetFair)
}


def get_scheduler(policy) -> Scheduler:
    """Resolve a policy name (or pass through an instance)."""
    if isinstance(policy, Scheduler):
        return policy
    try:
        return SCHEDULERS[policy]()
    except KeyError:
        raise ValueError(
            f"unknown scheduler {policy!r}; have {sorted(SCHEDULERS)}"
        ) from None
