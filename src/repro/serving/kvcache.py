"""Slot-based (dense) KV cache manager for continuous batching.

The device cache is the model family's own pytree (dense KV / ring KV +
SSM state / recurrent state — ``api.init_cache``), always allocated for
``num_slots`` sequences at ``max_seq``. This manager tracks slot
occupancy host-side and produces the per-tick (lengths, active mask)
arrays; eviction is immediate on completion so a waiting request can
claim the slot on the next tick (continuous batching).

This is the *dense* storage discipline: every slot reserves ``max_seq``
positions up front, so capacity = slots x worst case. The block-paged
alternative (:mod:`repro.serving.blockpool`) shares a page pool across
sequences and reserves only each request's actual footprint; the engine
selects between them with ``cache_kind="dense" | "paged"``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Slot:
    request_id: Optional[int] = None
    length: int = 0                  # valid positions in the cache
    generated: int = 0
    max_new: int = 0

    @property
    def free(self) -> bool:
        return self.request_id is None


class SlotManager:
    def __init__(self, num_slots: int, max_seq: int):
        self.max_seq = max_seq
        self.slots = [self._empty_slot() for _ in range(num_slots)]
        # device-side cache of the per-tick lengths operand (same
        # invalidation discipline as the paged manager's block-table
        # cache): rebuilt only when some slot's length actually changed
        # (assign / release / tick), so spectator-heavy phases — chunked
        # prefill steps where only the wave rows move, idle ticks — reuse
        # the device-resident buffer instead of re-uploading it
        self._len_dev = None
        self._len_dirty = True
        # the rope-position operand gets its own buffer under the same
        # discipline: today positions == lengths for every family, but
        # the decode step takes it as an explicit operand (the fused
        # ingest kernel consumes it directly), so it is cached separately
        self._pos_dev = None
        self._pos_dirty = True

    # hooks overridden by the paged manager (blockpool.PagedSlotManager)
    def _empty_slot(self) -> Slot:
        return Slot()

    def _make_slot(self, request_id: int, prompt_len: int,
                   max_new: int, tokens=None) -> Optional[Slot]:
        """Build the slot record for an admitted request; None = the
        backing storage (e.g. a page pool) cannot host it right now.
        ``tokens`` is the exact prefill token sequence — dense slots
        ignore it; the paged manager matches its page-aligned prefix
        against the prefix index (copy-on-write sharing)."""
        return Slot(request_id, prompt_len, 0, max_new)

    def try_assign(self, request_id: int, prompt_len: int,
                   max_new: int, tokens=None) -> Optional[int]:
        if prompt_len + max_new > self.max_seq:
            raise ValueError(
                f"request {request_id} needs {prompt_len + max_new} > "
                f"max_seq {self.max_seq}")
        for i, s in enumerate(self.slots):
            if s.free:
                new = self._make_slot(request_id, prompt_len, max_new,
                                      tokens=tokens)
                if new is None:
                    return None
                self.slots[i] = new
                self._len_dirty = True
                self._pos_dirty = True
                return i
        return None

    def release(self, idx: int) -> None:
        self.slots[idx] = self._empty_slot()
        self._len_dirty = True
        self._pos_dirty = True

    def ensure(self, idx: int, positions: int) -> bool:
        """Grow backing storage for slot ``idx`` to ``positions`` KV
        entries. Dense slots pre-reserve ``max_seq`` — always True; the
        paged manager overrides this with lazy page allocation."""
        return positions <= self.max_seq

    def fork_for_write(self, idx: int, start: int, end: int):
        """Copy-on-write hook before writing KV positions [start, end):
        dense slots are never shared — nothing to fork. The paged manager
        forks pages with refcount > 1 and returns the (src, dst) slab
        copies the engine owes the device cache."""
        return []

    def commit_prefix(self, idx: int, tokens) -> None:
        """Prefill-completion hook (prefix-index bookkeeping); no-op for
        dense slots."""

    def block_tables(self):
        """The layout's optional addressing operand for the jitted steps:
        None for dense slot storage; the paged manager returns the
        (num_slots, max_pages_per_seq) int32 logical→physical map."""
        return None

    def lengths(self) -> np.ndarray:
        return np.array([s.length for s in self.slots], np.int32)

    def lengths_device(self):
        """The (num_slots,) int32 lengths operand as a **cached device
        array** — the jitted decode step's per-tick companion to
        :meth:`block_tables`. Rebuilt (one host→device upload) only when
        a slot's length changed since the last call; unchanged ticks and
        repeat reads hand back the same device-resident buffer."""
        if self._len_dirty or self._len_dev is None:
            import jax.numpy as jnp
            self._len_dev = jnp.asarray(self.lengths())
            self._len_dirty = False
        return self._len_dev

    def positions_device(self):
        """The (num_slots,) int32 rope-position operand as a cached
        device array, same invalidation discipline as
        :meth:`lengths_device`. The next decode token lands at position
        ``length`` for every family, so the values equal the lengths —
        but the decode step takes positions as an explicit operand (the
        fused ingest stage consumes it directly), so the buffer is
        cached and uploaded independently."""
        if self._pos_dirty or self._pos_dev is None:
            import jax.numpy as jnp
            self._pos_dev = jnp.asarray(self.positions())
            self._pos_dirty = False
        return self._pos_dev

    def positions(self) -> np.ndarray:
        """Host-side rope positions for the next decode token (== the
        slot lengths; free slots report 0)."""
        return np.array([s.length for s in self.slots], np.int32)

    def active(self) -> np.ndarray:
        return np.array([not s.free for s in self.slots], np.bool_)

    def tick(self, idx: int, *, wrote_kv: bool = True) -> None:
        """Account one emitted token. ``wrote_kv=False`` for the token that
        comes out of prefill itself (its KV lands in the cache only on the
        next decode tick, which scatters at the current length)."""
        s = self.slots[idx]
        if wrote_kv:
            s.length += 1
            self._len_dirty = True
            self._pos_dirty = True
        s.generated += 1
