"""Deterministic sharded synthetic-token pipeline with background prefetch.

Determinism contract (the fault-tolerance linchpin): batch contents are a
pure function of ``(seed, step)`` — restarting from a checkpoint at step k
replays exactly the stream a never-interrupted run would have seen, on any
host count (each host materializes only its shard of the global batch, so
elastic restarts re-slice the same global stream).

Tokens follow a Zipf-ish distribution over the vocab with a deterministic
per-step permutation — cheap to generate, non-degenerate for throughput
work, and the label stream is the standard next-token shift.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig


class SyntheticTokens:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        *,
        seed: int = 0,
        host_index: int = 0,
        host_count: int = 1,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count
        assert shape.global_batch % host_count == 0
        self.local_batch = shape.global_batch // host_count

    def batch_at(self, step: int) -> dict:
        """Materialize this host's shard of the global batch for ``step``."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        b, s = self.local_batch, self.shape.seq_len
        v = self.cfg.vocab_size
        # Zipf-ish: rank ~ floor(exp(u * ln(v))) gives a heavy head
        u = rng.random((b, s + 1))
        toks = np.minimum(
            (np.exp(u * np.log(v)) - 1.0).astype(np.int64), v - 1
        ).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "vision":
            from repro.models.api import n_image_tokens
            npfx = n_image_tokens(s)
            batch["tokens"] = batch["tokens"][:, : s - npfx]
            batch["labels"] = batch["labels"][:, : s - npfx]
            batch["prefix_embeds"] = (
                rng.standard_normal((b, npfx, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
        if self.cfg.family == "encdec":
            batch["frames"] = (
                rng.standard_normal((b, s, self.cfg.d_model)) * 0.02
            ).astype(np.float32)
        return batch


class Prefetcher:
    """Double-buffered background producer over a SyntheticTokens stream.

    One producer thread keeps ``depth`` batches ready so a slow host's
    input generation never stalls the (synchronous) collective step — the
    straggler posture called out in DESIGN.md §5.
    """

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 60.0):
        return self.q.get(timeout=timeout)

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)


def device_batch(batch: dict, mesh=None, specs=None) -> dict:
    """Host numpy batch -> device arrays (sharded when a mesh is given)."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding
    out = {}
    for k, v in batch.items():
        spec = specs[k] if specs else None
        if spec is None:
            out[k] = jnp.asarray(v)
        else:
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out
