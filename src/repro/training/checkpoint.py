"""Async sharded checkpoints with manifest + elastic reshard on restore.

Layout (one directory per step):

    <dir>/step_000400/
        manifest.json       {step, leaf paths, shapes, dtypes, spec strings}
        shard_h000.npz      this host's leaf arrays (flattened names)
        COMMIT              written last — a checkpoint without it is torn
                            and ignored by `latest_step` (crash-safe).

Saves run on a background thread (the train loop keeps stepping while the
previous checkpoint drains to disk — async checkpointing). Restore is
*elastic*: arrays are loaded as host numpy and re-placed under whatever
mesh/sharding the restarted job uses (different device count included);
`load_state` takes the target sharding tree and `device_put`s each leaf.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V":  # ml_dtypes (bf16/f8): store as uint bits
            arr = arr.view({2: np.uint16, 1: np.uint8}[arr.dtype.itemsize])
        flat[key] = arr
    return flat


def tree_paths(tree: Any) -> list[str]:
    return sorted(_flatten_structure(tree))


def _flatten_structure(tree: Any) -> list[str]:
    out = []
    for path, _ in jax.tree_util.tree_leaves_with_path(tree):
        out.append(_SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        ))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.host_index = host_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk on a worker thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(np.asarray, state)  # device -> host copy

        def write():
            try:
                self._write(step, host_tree)
            except BaseException as e:  # noqa: BLE001 — surfaced via wait()
                self._error = e

        if blocking:
            write()
            self.wait()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host_tree: Any) -> None:
        path = self._step_dir(step)
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_tree)
        np.savez(os.path.join(tmp, f"shard_h{self.host_index:03d}.npz"),
                 **flat)
        manifest = {
            "step": step,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            },
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "COMMIT"))):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def load_state(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (elastic re-placement).

        ``shardings``: optional pytree of NamedSharding — each loaded leaf
        is ``device_put`` under it, so a restart may use a different mesh
        or device count than the run that saved the checkpoint.
        """
        path = self._step_dir(step)
        with np.load(os.path.join(path, f"shard_h{self.host_index:03d}.npz"),
                     allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
        keys = _flatten_structure(like)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0]
            if shardings is not None else [None] * len(leaves_like)
        )
        out = []
        for key, leaf, sh in zip(keys, leaves_like, shard_leaves):
            arr = flat[key]
            want = np.dtype(leaf.dtype)
            if arr.dtype != want:
                if (arr.dtype.kind in "uiV"
                        and arr.dtype.itemsize == want.itemsize
                        and want.kind == "V"):
                    arr = arr.view(want)   # uint bits -> ml_dtypes (bf16)
                else:
                    arr = arr.astype(want)
            out.append(
                jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)
            )
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- internals -------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:06d}")

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
