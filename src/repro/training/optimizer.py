"""AdamW + global-norm clip + warmup-cosine schedule (pure JAX).

Moments are f32 regardless of param dtype; the update is computed in f32
and cast back (bf16 params with f32 optimizer state — the standard mixed
setup). Because params are FSDP-sharded by the rules in
``distributed/sharding.py``, the moments inherit that sharding and the
optimizer runs fully sharded with zero extra collectives (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    if cfg.warmup_steps <= 0:
        warm = 1.0
    else:
        warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * decay


def adamw_init(params: Any) -> tuple[Any, Any]:
    """(m, v) f32 moment trees shaped like params."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    m: Any,
    v: Any,
    step: jax.Array,
):
    """One AdamW step. Returns (params, m, v, metrics)."""
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t

    def upd(p, g, m_, v_):
        gf = g.astype(jnp.float32)
        m_new = cfg.beta1 * m_ + (1 - cfg.beta1) * gf
        v_new = cfg.beta2 * v_ + (1 - cfg.beta2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, m, v)
    new_params = jax.tree.map(
        lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
    new_m = jax.tree.map(
        lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
    new_v = jax.tree.map(
        lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
    return new_params, new_m, new_v, {"grad_norm": gnorm, "lr": lr}
