"""Fault-tolerant training loop.

Operational posture (DESIGN.md §5, 1000+-node):

  * **checkpoint/restart** — async sharded checkpoints every
    ``checkpoint_every`` steps; on start the loop resumes from the latest
    committed checkpoint (a torn write is invisible: COMMIT is last).
    Data is a pure function of (seed, step), so a restart replays the
    identical stream — bitwise-deterministic recovery.
  * **preemption** — SIGTERM/SIGINT flips a flag; the loop finishes the
    in-flight step, writes a blocking checkpoint, and exits 0 (the
    scheduler restarts the job elsewhere).
  * **straggler mitigation** — input is produced by a prefetch thread
    (never on the step's critical path); a step-time watchdog flags
    slow steps (p50 x `watchdog_factor`) so an orchestrator can
    replace the slow host. SPMD collectives are synchronous: detection +
    replacement is the mitigation, matching TPU-pod practice.
  * **elastic scaling** — checkpoints restore under a *different* mesh
    (load_state re-places every leaf under the new sharding).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.config import ModelConfig, RunConfig, ShapeConfig
from repro.training.checkpoint import CheckpointManager
from repro.training.data import Prefetcher, SyntheticTokens, device_batch


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: list
    step_times: list
    preempted: bool = False
    restored_from: Optional[int] = None
    slow_steps: int = 0


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful save-and-exit flag."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


def train_loop(
    *,
    model_cfg: ModelConfig,
    shape: ShapeConfig,
    run: RunConfig,
    train_step: Callable,
    init_state: Callable[[], Any],
    mesh=None,
    state_shardings: Any = None,
    batch_specs: Any = None,
    max_steps: Optional[int] = None,
    log_every: int = 10,
    watchdog_factor: float = 3.0,
    install_signals: bool = True,
    preempt_after: Optional[int] = None,   # test hook: simulate preemption
) -> LoopResult:
    """Run (or resume) training until ``max_steps`` or preemption."""
    total = max_steps if max_steps is not None else run.total_steps
    ckpt = CheckpointManager(run.checkpoint_dir, keep=run.keep_checkpoints)
    guard = PreemptionGuard(install=install_signals)

    # ---- restore or init ----
    restored_from = None
    state = init_state()
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.load_state(latest, state, state_shardings)
        restored_from = latest
    start_step = int(np.asarray(state.step))

    source = SyntheticTokens(model_cfg, shape, seed=run.seed)
    prefetch = Prefetcher(source, start_step=start_step)

    losses, times = [], []
    slow = 0
    step = start_step
    try:
        while step < total and not guard.requested:
            step_idx, host_batch = prefetch.next()
            assert step_idx == step, (step_idx, step)
            batch = device_batch(host_batch, mesh, batch_specs)
            t0 = time.perf_counter()
            state, metrics = train_step(state, batch)
            loss = float(np.asarray(metrics["loss"]))
            dt = time.perf_counter() - t0
            losses.append(loss)
            times.append(dt)
            # watchdog: flag stragglers once there's a baseline
            if len(times) >= 8:
                p50 = float(np.median(times[-64:]))
                if dt > watchdog_factor * p50:
                    slow += 1
            step += 1
            if step % run.checkpoint_every == 0:
                ckpt.save(step, state)          # async
            if log_every and step % log_every == 0:
                print(f"step {step:>6}  loss {loss:.4f}  {dt*1e3:.1f} ms")
            if preempt_after is not None and step - start_step >= preempt_after:
                guard.requested = True
        preempted = guard.requested and step < total
        if preempted or step % run.checkpoint_every != 0:
            ckpt.save(step, state, blocking=True)   # final/preemption save
        ckpt.wait()
    finally:
        prefetch.close()
        if install_signals:
            guard.restore()

    return LoopResult(
        final_step=step, losses=losses, step_times=times,
        preempted=preempted, restored_from=restored_from, slow_steps=slow,
    )
