"""Training substrate: optimizer, state, data pipeline, checkpointing, loop.

Everything is pure JAX over explicit pytrees; sharding comes from
:mod:`repro.distributed.sharding` (params FSDP over ``pod``+``data``, TP
over ``model`` — optimizer moments inherit the param sharding, which *is*
the ZeRO posture).
"""
from repro.training.optimizer import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_update,
    lr_schedule,
)
from repro.training.train_state import TrainState  # noqa: F401
