"""TrainState pytree + the jit-able train step factory.

``make_train_step`` builds the function the dry-run lowers and the trainer
executes: forward loss (family-dispatched), backprop, optional microbatch
gradient accumulation, optional int8-EF cross-pod gradient compression,
AdamW update. Pure function of (state, batch) -> (state, metrics).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RunConfig
from repro.models.api import ModelApi
from repro.models.layers import LayerCtx
from repro.training import optimizer as opt


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jax.Array            # () int32
    params: Any
    m: Any
    v: Any
    ef_err: Optional[Any] = None   # int8-EF residuals (grad compression)

    @staticmethod
    def create(params: Any, *, npods: int = 0,
               compression: str = "none") -> "TrainState":
        m, v = opt.adamw_init(params)
        ef = None
        if compression == "int8_ef" and npods > 1:
            from repro.distributed import collectives as C
            ef = C.zeros_error_state(params, npods)
        return TrainState(
            step=jnp.zeros((), jnp.int32), params=params, m=m, v=v, ef_err=ef
        )


def adamw_config(run: RunConfig) -> opt.AdamWConfig:
    return opt.AdamWConfig(
        learning_rate=run.learning_rate,
        weight_decay=run.weight_decay,
        warmup_steps=run.warmup_steps,
        total_steps=run.total_steps,
    )


def make_train_step(
    api: ModelApi,
    ctx: LayerCtx,
    run: RunConfig,
    *,
    unroll: bool = False,
    mesh=None,
) -> Callable:
    """Build train_step(state, batch) -> (state, metrics)."""
    acfg = adamw_config(run)
    remat = run.remat != "none"

    def loss_fn(params, batch):
        return api.train_loss(ctx, params, batch, unroll=unroll, remat=remat)

    def compute_grads(params, batch):
        if run.microbatch and run.microbatch > 1:
            # gradient accumulation: split the batch on axis 0 into
            # `microbatch` slices and scan, accumulating f32 grads.
            nmb = run.microbatch

            def split(x):
                b = x.shape[0]
                assert b % nmb == 0, (b, nmb)
                return x.reshape(nmb, b // nmb, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                tot_g = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), tot_g, g)
                return (tot_l + l, tot_g), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), gz), mbs)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            return loss / nmb, grads
        return jax.value_and_grad(loss_fn)(params, batch)

    compressed = (
        run.grad_compression == "int8_ef"
        and mesh is not None
        and "pod" in getattr(mesh, "axis_names", ())
    )

    if not compressed:
        def train_step(state: TrainState, batch):
            loss, grads = compute_grads(state.params, batch)
            params, m, v, metrics = opt.adamw_update(
                acfg, state.params, grads, state.m, state.v, state.step)
            new_state = TrainState(
                step=state.step + 1, params=params, m=m, v=v,
                ef_err=state.ef_err)
            metrics = dict(metrics, loss=loss)
            return new_state, metrics

        return train_step

    # ---- int8-EF compressed cross-pod gradients --------------------------
    # Gradients must be *pod-local* for the compressed hop to be real, so
    # the grad computation runs inside a shard_map manual over `pod` only
    # (data/model stay under GSPMD). Params are pod-replicated in this mode
    # (rules use fsdp over `data` only); the batch's pod slice is consumed
    # manually; per-pod EF residuals ride a leading pod axis.
    from jax.sharding import PartitionSpec as P
    from repro.distributed import collectives as C

    def train_step(state: TrainState, batch):
        def pod_body(batch_l, params, ef_l):
            ef = jax.tree.map(lambda e: e[0], ef_l)
            loss, grads = compute_grads(params, batch_l)
            grads, ef_new = C.crosspod_psum_int8(grads, ef, axis="pod")
            losses = jax.lax.all_gather(loss, "pod")
            return (
                jnp.mean(losses)[None],
                jax.tree.map(lambda g: g[None], grads),
                jax.tree.map(lambda e: e[None], ef_new),
            )

        batch_spec = jax.tree.map(lambda _: P("pod"), batch)
        g_spec = jax.tree.map(lambda _: P("pod"), state.params)
        e_spec = jax.tree.map(lambda _: P("pod"), state.ef_err)
        fn = jax.shard_map(
            pod_body,
            mesh=mesh,
            in_specs=(batch_spec, jax.tree.map(lambda _: P(), state.params),
                      e_spec),
            out_specs=(P("pod"), g_spec, e_spec),
            axis_names={"pod"},
        )
        loss_boxed, grads_boxed, ef = fn(batch, state.params, state.ef_err)
        loss = loss_boxed[0]
        grads = jax.tree.map(lambda g: g[0], grads_boxed)
        params, m, v, metrics = opt.adamw_update(
            acfg, state.params, grads, state.m, state.v, state.step)
        new_state = TrainState(
            step=state.step + 1, params=params, m=m, v=v, ef_err=ef)
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step
