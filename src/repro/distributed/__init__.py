"""Distribution layer: named-axis sharding rules, compressed collectives,
and the optional pipeline-parallel schedule.

The mesh contract (DESIGN.md §5):
  * single pod:  (data=16, model=16)
  * multi-pod:   (pod=2, data=16, model=16)

``pod`` + ``data`` together form the batch/FSDP axes; ``model`` is the
tensor-parallel axis (and the sequence-split axis for T1 decode attention).
"""
from repro.distributed.sharding import (  # noqa: F401
    Rules,
    make_rules,
    make_shard_fn,
)
