"""Compressed cross-pod gradient reduction (int8 + error feedback).

At (pod=2, data=16, model=16) the slowest collective in the training step is
the cross-pod gradient all-reduce: it crosses the inter-pod links (DCI),
which are far scarcer than intra-pod ICI. We compress that hop 2x (bf16 ->
int8) with per-leaf scale factors and an **error-feedback** accumulator
(Seide et al. / 1-bit-SGD lineage): the quantization residual is added back
into the next step's gradient, so the *time-averaged* gradient is unbiased
and SGD-style convergence is preserved.

Integration: gradients are computed pod-locally (batch sharded over
``pod``+``data``; params replicated over ``pod``), then
:func:`crosspod_allreduce_int8` reconciles pods inside a ``shard_map`` that
is *manual over the pod axis only* (``axis_names`` leaves data/model to
GSPMD). The intra-pod reduce-scatter stays uncompressed bf16/f32 — ICI has
16x the bandwidth, and compressing it would put the quantizer inside the
FSDP reduce-scatter path for no roofline win.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# int8 quantizer with per-leaf scale
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (any float) -> (int8 codes, f32 scale). scale = amax/127."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_quantize_leaf(
    g: jax.Array, err: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback quantize one leaf.

    Returns (codes, scale, new_err) with new_err = (g + err) - deq(codes).
    """
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def _pack_i8(q: jax.Array) -> tuple[jax.Array, int]:
    """int8 array -> (int32 words, pad count). Byte-identical payload."""
    flat = q.reshape(-1)
    pad = (-flat.size) % 4
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return jax.lax.bitcast_convert_type(
        flat.reshape(-1, 4), jnp.int32), pad


def _unpack_i8(words: jax.Array, shape: tuple[int, ...],
               pad: int) -> jax.Array:
    flat = jax.lax.bitcast_convert_type(words, jnp.int8).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


# ---------------------------------------------------------------------------
# Cross-pod all-reduce of a gradient pytree
# ---------------------------------------------------------------------------


def zeros_error_state(grads: Any, npods: int) -> Any:
    """Per-pod f32 error-feedback accumulators (part of TrainState).

    Pod-local state is materialized as a leading pod axis of size
    ``npods`` sharded over ``pod`` — the SPMD-native encoding of
    "one private accumulator per pod".
    """
    return jax.tree.map(
        lambda g: jnp.zeros((npods, *g.shape), jnp.float32), grads
    )


def crosspod_psum_int8(grads: Any, err: Any, axis: str = "pod"):
    """Compressed mean over ``axis`` — call *inside* a shard_map that is
    manual over ``axis`` (the trainer's grad step; see training/loop.py).

    Each pod quantizes its local gradient (with error feedback); the int8
    codes travel the cross-pod links via all-gather (1 B/elem vs 2 B for a
    bf16 all-reduce), and the receive side reconstructs the exact weighted
    sum Σ_p scale_p · q_p. Returns (mean gradient tree [pod-invariant],
    new error tree [pod-varying]). Leaves are plain arrays.
    """
    npods = jax.lax.psum(1, axis)

    def leaf(g, e):
        q, scale, new_e = ef_quantize_leaf(g, e)
        # int8 codes packed 4-per-int32 word for the wire (identical byte
        # count; sidesteps XLA backends that cannot collective s8 directly)
        packed, pad = _pack_i8(q)
        ps = jax.lax.all_gather(packed, axis)               # (P, n/4) i32
        ss = jax.lax.all_gather(scale, axis)                # (P,) f32
        qs = jax.vmap(lambda p: _unpack_i8(p, q.shape, pad))(ps)
        total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
        return (total / npods).astype(g.dtype), new_e

    pairs = jax.tree.map(leaf, grads, err)
    new_grads = jax.tree.map(
        lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple)
    )
    new_err = jax.tree.map(
        lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple)
    )
    return new_grads, new_err


def crosspod_allreduce_int8(
    mesh: Mesh,
    grads: Any,
    err: Any,
    *,
    axis: str = "pod",
):
    """Standalone jit-composable wrapper around :func:`crosspod_psum_int8`.

    Pod-local values are encoded with a leading ``(npods, ...)`` axis
    sharded over ``axis`` (the SPMD representation of per-pod state —
    :func:`zeros_error_state` builds ``err`` this way). Returns
    (mean grads broadcast back to the pod axis, new error state).
    Manual collectives run over ``axis`` only; data/model placements ride
    along under GSPMD (``shard_map(..., axis_names={axis})``).
    """
    if axis not in mesh.axis_names:
        return grads, err

    def body(g_boxed, e_boxed):
        g = jax.tree.map(lambda a: a[0], g_boxed)
        e = jax.tree.map(lambda a: a[0], e_boxed)
        mean_g, new_e = crosspod_psum_int8(g, e, axis=axis)
        g_out = jax.tree.map(lambda a: a[None], mean_g)
        e_out = jax.tree.map(lambda a: a[None], new_e)
        return g_out, e_out

    spec_g = jax.tree.map(lambda _: P(axis), grads)
    spec_e = jax.tree.map(lambda _: P(axis), err)
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_g, spec_e),
        out_specs=(spec_g, spec_e),
        axis_names={axis},
    )
    return fn(grads, err)


# ---------------------------------------------------------------------------
# Softmax partial combines over a named axis (T1 at pod scale) — re-exported
# here so the distributed story lives in one package.
# ---------------------------------------------------------------------------

from repro.core.softmax import (  # noqa: E402,F401
    combine_async_collective,
    combine_sync_collective,
)
