"""Named-axis sharding rules (DP/FSDP over ``pod``+``data``, TP over
``model``, sequence-split KV over ``model`` for decode).

Three rule families:

  * **Activations** — the model zoo calls ``ctx.shard(x, role)`` with a
    logical role string; :meth:`Rules.act_spec` maps it to a
    :class:`~jax.sharding.PartitionSpec` adapted to the array's rank
    (batch on axis 0, TP features on the last axis). Non-divisible dims
    degrade to replicated *at trace time* (``long_500k`` has batch=1).

  * **Params** — :meth:`Rules.param_spec` walks a param pytree and assigns
    Megatron-style TP (column/row rules by leaf name + parent context)
    plus FSDP over the combined ``pod``+``data`` axes on the other matrix
    dim. This is what makes grok-1-314b *fit*: 628 GB of bf16 params is
    2.5 GB/chip at (2,16,16) but 39 GB/chip with TP-only sharding.

  * **Inputs / caches** — token batches shard over batch axes; KV caches
    shard batch over ``data`` and **sequence over ``model``** — the layout
    under which T1's additive (num, den) combine turns cross-chip decode
    attention into one psum pair (see DESIGN.md §2-T1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig

# ---------------------------------------------------------------------------
# Activation roles: role -> (shard batch on axis 0, shard model on last axis)
# ---------------------------------------------------------------------------

ACT_ROLES: dict[str, tuple[bool, bool]] = {
    "act_resid": (True, False),
    "act_qkv": (True, True),
    "act_kv": (True, True),
    "act_attn_out": (True, True),
    "act_ffn": (True, True),
    "act_logits": (True, True),
    "act_moe_grouped": (True, False),
    "act_moe_slots": (True, False),
    "act_moe_hidden": (True, True),
}

# TP orientation by (parent, leaf-name). COL = output dim over model,
# ROW = input dim over model. Anything absent is replicated (plus FSDP).
_COL = {
    "wq", "wk", "wv",            # attention projections (D, out)
    "w_gate", "w_up",            # mlp up projections (D, F)
    "w_in",                      # hybrid ssm in-proj (D, inner)
    "w_r", "w_g",                # rwkv/hybrid square gates (D, D)
    "w_dt", "w_bc",              # hybrid ssm dt/B/C projections (D, small)
    "lm_head",
}
_ROW = {
    "wo",                        # attention out (q_dim, D)
    "w_down",                    # mlp down (F, D)
    "w_out",                     # ssm out (inner, D)
}
# context-sensitive leaves: (parent, name) -> "col" | "row" | "rep"
_CTX = {
    ("tm", "w_k"): "col", ("tm", "w_v"): "col", ("tm", "w_o"): "row",
    ("cm", "w_k"): "col", ("cm", "w_v"): "row", ("cm", "w_r"): "col",
    ("ssm", "w_gate"): "col",
    ("moe", "w_gate"): "moe_up", ("moe", "w_up"): "moe_up",
    ("moe", "w_down"): "moe_down",
    ("moe", "router"): "rep",
    ("tm", "decay_A"): "rep", ("tm", "decay_B"): "rep",
}
_BIAS_COL = {"bq", "bk", "bv"}   # 1-D, sized like a COL output dim


def _divides(dim: int, axes: tuple[str, ...], sizes: dict[str, int]) -> bool:
    n = 1
    for a in axes:
        n *= sizes[a]
    return dim % n == 0 and dim >= n


@dataclasses.dataclass(frozen=True)
class Rules:
    """Sharding rule set bound to one mesh configuration."""

    mesh_cfg: MeshConfig
    seq_shard_kv: bool = True     # KV-cache sequence over `model` (T1 layout)
    # False -> params FSDP over `data` only (pod-replicated) — required by
    # the int8-EF compressed-gradient mode, whose pod hop is manual.
    fsdp_over_pod: bool = True
    # False -> activation constraints never mention `pod` (they execute
    # inside the pod-manual shard_map in compressed-gradient mode, where a
    # constraint naming a manual axis is illegal). Inputs/caches, which
    # live outside, keep the full batch axes.
    act_over_pod: bool = True
    # False -> params are TP-sharded only (replicated over data) — the
    # serving layout for models whose TP shard fits HBM: FSDP would
    # all-gather the full parameter set once per decoded token.
    fsdp_params: bool = True

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.mesh_cfg.data_axes          # ("pod","data") or ("data",)

    @property
    def act_batch_axes(self) -> tuple[str, ...]:
        if self.act_over_pod:
            return self.batch_axes
        return tuple(a for a in self.batch_axes if a != "pod")

    @property
    def fsdp_axes(self) -> tuple[str, ...]:
        if self.fsdp_over_pod:
            return self.batch_axes
        return tuple(a for a in self.batch_axes if a != "pod")

    @property
    def model_axis(self) -> str:
        return self.mesh_cfg.model_axis

    @property
    def axis_sizes(self) -> dict[str, int]:
        return dict(zip(self.mesh_cfg.axis_names, self.mesh_cfg.shape))

    # -- activations --------------------------------------------------------

    def act_spec(self, role: str, shape: tuple[int, ...]) -> P:
        sizes = self.axis_sizes
        batch = self.act_batch_axes
        entries: list[Any] = [None] * len(shape)

        def try_set(i: int, axes) -> None:
            ax = axes if isinstance(axes, tuple) else (axes,)
            if len(shape) > i and _divides(shape[i], ax, sizes):
                entries[i] = axes

        # decode-path roles (T1 split-KV layout): scores/exp partials are
        # sequence-sharded over `model`; q/k/v of the single new token are
        # model-replicated; a per-layer cache slice (B, S, H, Dh) keeps the
        # stored sequence over `model`.
        if role == "act_scores_decode":          # (B, H, S)
            try_set(0, batch)
            if self.seq_shard_kv:
                try_set(len(shape) - 1, self.model_axis)
            return P(*entries)
        if role == "act_decode_rep":             # (B, ...) replicated rest
            try_set(0, batch)
            return P(*entries)
        if role == "act_cache_slice":            # (B, S, H, Dh)
            try_set(0, batch)
            if self.seq_shard_kv:
                try_set(1, self.model_axis)
            return P(*entries)

        batch0, model_last = ACT_ROLES.get(role, (True, False))
        if batch0:
            try_set(0, batch)
        if model_last and len(shape) >= 2:
            try_set(len(shape) - 1, self.model_axis)
        return P(*entries)

    # -- params --------------------------------------------------------------

    def param_spec(self, path: tuple[str, ...], shape: tuple[int, ...]) -> P:
        """TP + FSDP spec for one param leaf.

        ``path`` is the tuple of dict keys from the root; leaves under a
        ``*layers`` key carry a leading stacked-L axis (never sharded).
        """
        sizes = self.axis_sizes
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""
        stacked = any("layers" in p for p in path[:-1])
        lead = 1 if stacked else 0
        body = shape[lead:]

        kind = _CTX.get((parent, name))
        if kind is None:
            if name in _COL:
                kind = "col"
            elif name in _ROW:
                kind = "row"
            elif name == "embedding":
                kind = "embed"
            elif name in _BIAS_COL:
                kind = "bias_col"
            else:
                kind = "rep"

        entries: list[Any] = [None] * len(body)
        model = self.model_axis
        batch = self.fsdp_axes if self.fsdp_params else ()

        def set_axis(i: int, axes) -> None:
            ax = axes if isinstance(axes, tuple) else (axes,)
            if ax and _divides(body[i], ax, sizes):
                entries[i] = axes

        if kind == "col" and len(body) == 2:
            set_axis(1, model)            # output over TP
            set_axis(0, batch)            # input over FSDP
        elif kind == "row" and len(body) == 2:
            set_axis(0, model)
            set_axis(1, batch)
        elif kind == "embed" and len(body) == 2:
            set_axis(0, model)            # vocab over TP
            set_axis(1, batch)            # d_model over FSDP
        elif kind == "moe_up" and len(body) == 3:   # (E, D, F)
            set_axis(2, model)
            set_axis(1, batch)
        elif kind == "moe_down" and len(body) == 3:  # (E, F, D)
            set_axis(1, model)
            set_axis(2, batch)
        elif kind == "bias_col" and len(body) == 1:
            set_axis(0, model)
        # "rep": all None (norm scales, mus, router, decay loras, …)

        return P(*([None] * lead), *entries)

    def param_spec_tree(self, params: Any) -> Any:
        """Pytree of PartitionSpec matching ``params`` (arrays or SDS)."""
        def walk(path, leaf):
            keys = tuple(
                k.key if hasattr(k, "key") else str(k) for k in path
            )
            return self.param_spec(keys, leaf.shape)

        return jax.tree_util.tree_map_with_path(walk, params)

    # -- inputs / caches ----------------------------------------------------

    def batch_spec(self, shape: tuple[int, ...]) -> P:
        # compressed-grad mode (act_over_pod=False): inputs stay data-sharded
        # and the pod split happens manually in the grad shard_map — XLA's
        # gather partitioner crashes when both claim the pod axis.
        axes = self.act_batch_axes
        entries: list[Any] = [None] * len(shape)
        if len(shape) >= 1 and _divides(shape[0], axes, self.axis_sizes):
            entries[0] = axes
        return P(*entries)

    def cache_spec(self, shape: tuple[int, ...]) -> P:
        """KV cache (L, B, S, H, Dh) / SSM state (L, B, H, N, N) / shift
        state (L, B, D): batch over ``data`` axes; for the 5-D KV cache the
        *sequence* axis shards over ``model`` (T1's split-KV layout).
        """
        sizes = self.axis_sizes
        entries: list[Any] = [None] * len(shape)
        if len(shape) >= 2 and _divides(shape[1], self.batch_axes, sizes):
            entries[1] = self.batch_axes
        if len(shape) == 5 and self.seq_shard_kv and _divides(
                shape[2], (self.model_axis,), sizes):
            entries[2] = self.model_axis
        return P(*entries)

    def input_specs_tree(self, specs: Any) -> Any:
        """Shardings for a dry-run input pytree (tokens/labels/cache/...)."""
        def pick(path, leaf):
            keys = [k.key if hasattr(k, "key") else str(k) for k in path]
            if "cache" in keys:
                return self.cache_spec(leaf.shape)
            return self.batch_spec(leaf.shape)

        return jax.tree_util.tree_map_with_path(pick, specs)


def make_rules(mesh_cfg: MeshConfig, *, seq_shard_kv: bool = True,
               fsdp_over_pod: bool = True,
               act_over_pod: bool = True,
               fsdp_params: bool = True) -> Rules:
    return Rules(mesh_cfg=mesh_cfg, seq_shard_kv=seq_shard_kv,
                 fsdp_over_pod=fsdp_over_pod, act_over_pod=act_over_pod,
                 fsdp_params=fsdp_params)


def make_shard_fn(mesh: Optional[Mesh], rules: Optional[Rules]):
    """Build the ``LayerCtx.shard`` callable: role-based
    ``with_sharding_constraint`` (identity when mesh is None — single-host
    smoke paths).

    Values that are *varying over a manual axis* (inside the pod-manual
    shard_map of the compressed-gradient mode) need the constraint mesh to
    type those axes Manual — detected per value from ``jax.typeof(x).vma``.
    """
    if mesh is None or rules is None:
        return lambda x, role: x

    def shard(x: jax.Array, role: str) -> jax.Array:
        # jax.typeof is post-0.4.x; older jax has no vma typing at all
        # (partial-manual values simply lack the attribute -> empty set)
        typeof = getattr(jax, "typeof", None) or jax.core.get_aval
        vma = frozenset(getattr(typeof(x), "vma", frozenset()))
        if vma:
            # Inside a partial-manual shard_map (compressed-grad mode):
            # explicit constraints on manual-varying values trip an XLA
            # SPMD-partitioner CHECK (spmd_partitioner_util.cc) as of
            # XLA/jax 0.8 — let GSPMD propagate from the in_shardings
            # instead (recorded in DESIGN.md §8).
            return x
        spec = rules.act_spec(role, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


def named(mesh: Mesh, tree_of_specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda s: isinstance(s, P),
    )
