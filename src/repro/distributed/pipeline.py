"""Optional GPipe-style pipeline parallelism over the ``pod`` axis.

At 1000+-node scale the cross-pod links are the scarcest resource; instead
of replicating the model across pods (hierarchical DP, the default), the
``pod`` axis can carry *pipeline stages*: each pod holds a contiguous slice
of layers, and activations flow pod-to-pod with ``lax.ppermute`` while
microbatches fill the pipeline (GPipe schedule: all-forward then
all-backward, bubble fraction (P-1)/(M+P-1)).

Implementation: a ``shard_map`` manual over ``pod``; stage params live only
on their stage (leading stage axis sharded over ``pod``); the steady-state
loop runs P + M - 1 ticks, each tick = one stage compute + one boundary
ppermute. Inside the stage body GSPMD still auto-shards data/model exactly
as in the non-pipelined path.

This module is deliberately self-contained and schedule-focused: it
pipelines any ``stage_fn(stage_params, x) -> x``. The trainer uses it when
``RunConfig.pipeline=True`` (off by default — hierarchical DP + int8-EF
cross-pod gradients is the better roofline trade at pod=2; the crossover
analysis is in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def split_stages(layers_params: Any, num_stages: int) -> Any:
    """Reshape stacked-L layer params (L, ...) -> (P, L/P, ...)."""
    def leaf(a):
        l = a.shape[0]
        assert l % num_stages == 0, (l, num_stages)
        return a.reshape(num_stages, l // num_stages, *a.shape[1:])

    return jax.tree.map(leaf, layers_params)


def merge_stages(staged: Any) -> Any:
    return jax.tree.map(
        lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), staged
    )


def pipeline_forward(
    mesh: Mesh,
    staged_params: Any,
    x_microbatches: jax.Array,       # (M, mb, ...) microbatched activations
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    *,
    axis: str = "pod",
):
    """GPipe forward over ``axis``. Returns (M, mb, ...) outputs.

    Differentiable: backward replays the schedule in reverse through the
    ppermute transpose rules, giving the standard all-back schedule.
    """
    num_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def body(params_stage, xs):
        # params_stage: this pod's slice, leading stage axis of size 1
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        m = xs.shape[0]
        stage_idx = jax.lax.axis_index(axis)
        ticks = m + num_stages - 1
        fwd = functools.partial(_perm_next, axis=axis, n=num_stages)

        buf = jax.lax.pvary(jnp.zeros_like(xs[0]), axis)
        outs = jax.lax.pvary(jnp.zeros_like(xs), axis)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            mb_idx = jnp.clip(t, 0, m - 1)
            buf = jnp.where(
                (stage_idx == 0) & (t < m),
                jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False),
                buf,
            )
            # every stage with a live microbatch computes
            live = (t >= stage_idx) & (t < m + stage_idx)
            y = stage_fn(params_stage, buf)
            buf_out = jnp.where(live, y, buf)
            # last stage banks its finished microbatch
            done_idx = jnp.clip(t - (num_stages - 1), 0, m - 1)
            outs = jnp.where(
                (stage_idx == num_stages - 1) & live,
                jax.lax.dynamic_update_index_in_dim(
                    outs, buf_out[None], done_idx, 0
                ),
                outs,
            )
            # rotate boundary activations to the next stage
            buf_next = fwd(buf_out)
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only stage P-1 banked real outputs; return per-stage boxed values
        # and let the caller read the last stage's copy.
        return outs[None]

    stage_spec = jax.tree.map(lambda _: P(axis), staged_params)
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(stage_spec, P()),
        out_specs=P(axis),
        axis_names={axis},
    )
    boxed = fn(staged_params, x_microbatches)   # (num_stages, M, mb, ...)
    return boxed[-1]


def _perm_next(x: jax.Array, *, axis: str, n: int) -> jax.Array:
    return jax.lax.ppermute(x, axis, [(i, (i + 1) % n) for i in range(n)])
