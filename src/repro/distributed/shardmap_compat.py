"""Version compatibility for ``jax.shard_map``.

The stable ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
axis_names=...)`` alias appeared after the 0.4.x series; on older jax the
function lives at ``jax.experimental.shard_map.shard_map`` with an ``auto``
parameter (the complement of ``axis_names``: mesh axes left under GSPMD).
Importing this module (for the side effect, like
:mod:`repro.kernels.pltpu_compat`) installs an adapter so every call site
keeps the single stable-API idiom.

Imported by :mod:`repro` itself so any entry point gets the alias.
"""
from __future__ import annotations

import jax

if not hasattr(jax, "shard_map"):  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                   check_rep: bool = True):
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            # partial-manual mode: old shard_map cannot replication-check
            check_rep = False
        mapped = _exp_shard_map(f, mesh, in_specs, out_specs,
                                check_rep=check_rep, auto=auto)
        # old shard_map has no eager path (NotImplementedError when called
        # outside a jit); the stable API executes eagerly, so close the gap
        return jax.jit(mapped)

    jax.shard_map = _shard_map
