"""Version compatibility for ``jax.shard_map``.

The stable ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
axis_names=...)`` alias appeared after the 0.4.x series; on older jax the
function lives at ``jax.experimental.shard_map.shard_map`` with an ``auto``
parameter (the complement of ``axis_names``: mesh axes left under GSPMD).
Importing this module (for the side effect, like
:mod:`repro.kernels.pltpu_compat`) installs an adapter so every call site
keeps the single stable-API idiom.

Imported by :mod:`repro` itself so any entry point gets the alias.

**Partial-manual support gate.** On the 0.4.x series the adapter makes
partial-manual ``shard_map`` (``axis_names`` a strict subset of the mesh,
``auto`` non-empty) *trace*, but the era's XLA SPMD partitioner dies in a
``CHECK`` inside ``IsManualSubgroup`` when it meets the resulting
partial-manual subgroups — a process **abort**, not a Python exception,
so a single affected test kills the whole pytest run. ``PARTIAL_MANUAL_OK``
records (before the shim installs, while the distinction is still
observable) whether the running jax has the native stable API — the same
releases whose partitioner handles partial-manual subgroups. Test modules
gate the four multi-device paths that need partial-manual collectives
(crosspod int8 allreduce, pipeline grad, split-KV collective claim,
manual MoE dispatch) on this flag so the slow lane *completes* on old
jax instead of being killed mid-run.
"""
from __future__ import annotations

import jax

#: True when jax ships the stable ``jax.shard_map`` natively — the proxy
#: for "the XLA partitioner survives partial-manual subgroups". Recorded
#: before the adapter below installs the attribute, which would otherwise
#: erase the signal.
PARTIAL_MANUAL_OK: bool = hasattr(jax, "shard_map")

#: skip/xfail message shared by the gated test modules
PARTIAL_MANUAL_REASON = (
    "old-jax XLA SPMD partitioner aborts (IsManualSubgroup CHECK) on "
    "partial-manual shard_map; needs native jax.shard_map")

if not PARTIAL_MANUAL_OK:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                   check_rep: bool = True):
        auto = frozenset()
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            # partial-manual mode: old shard_map cannot replication-check
            check_rep = False
        mapped = _exp_shard_map(f, mesh, in_specs, out_specs,
                                check_rep=check_rep, auto=auto)
        # old shard_map has no eager path (NotImplementedError when called
        # outside a jit); the stable API executes eagerly, so close the gap
        return jax.jit(mapped)

    jax.shard_map = _shard_map
