"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the process entrypoint (``python -m repro.launch.dryrun``): the
first two lines below claim 512 placeholder CPU devices before any jax
import so ``jax.make_mesh`` can build the production meshes. Nothing else
in the repo sets this flag — smoke tests and benches see 1 device.

Per cell this produces:
  * the full-depth compile (scan-over-layers) — the *fit + shard proof*:
    ``compiled.memory_analysis()`` (bytes/device) and the collective
    schedule from the post-SPMD HLO;
  * two unrolled probes (L=1, L=3) — ``cost_analysis()`` FLOPs/bytes and
    per-collective bytes decompose linearly in L (layers are identical),
    giving exact full-depth roofline terms (see analysis/roofline.py).

Artifacts are JSON files under ``--out`` consumed by
benchmarks/roofline_report.py and EXPERIMENTS.md.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    # XLA:CPU-only pass that widens small-dtype all-reduces; it (a)
    # CHECK-crashes on the compressed-gradient program and (b) would
    # distort the counted collective byte widths. TPU is unaffected.
    " --xla_disable_hlo_passes=all-reduce-promotion"
)

# ruff: noqa: E402  (the two lines above must precede all other imports)
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.analysis import hlo as hlo_analysis
from repro.analysis import roofline
from repro.config import (
    MULTI_POD, SINGLE_POD, MeshConfig, ModelConfig, RunConfig, ShapeConfig,
    SHAPES, applicable_shapes,
)
from repro.core import plan as plan_mod
from repro.distributed.sharding import Rules, make_rules, make_shard_fn, named
from repro.launch.mesh import make_mesh_from_config
from repro.models import api as model_api
from repro.models.layers import LayerCtx
from repro.training.train_state import TrainState, make_train_step


# ---------------------------------------------------------------------------
# Step builders — one per shape kind
# ---------------------------------------------------------------------------


def _ctx(cfg: ModelConfig, mesh, rules, run: RunConfig) -> LayerCtx:
    groups = 1
    if rules is not None:
        sizes = rules.axis_sizes
        for a in rules.batch_axes:
            groups *= sizes[a]
    base = run.plan if run.plan is not None else plan_mod.make_plan()
    ep = base.with_overrides(
        backend="xla",     # Mosaic doesn't lower on CPU
        fallback=False,    # no cond double-count in cost analysis
        # pre-T1 baseline (Fig. 4(b)): synchronized softmax everywhere
        scheme="sync" if run.sync_softmax else None,
    )
    return LayerCtx(
        cfg=cfg,
        shard=make_shard_fn(mesh, rules),
        plan=ep,
        moe_groups=groups,
        mesh=mesh if run.grad_compression == "none" else None,
        rules=rules,
    )


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules,
                run: RunConfig, *, unroll: bool):
    api = model_api.get_model(cfg)
    ctx = _ctx(cfg, mesh, rules, run)
    step = make_train_step(api, ctx, run, unroll=unroll, mesh=mesh)

    state_struct = jax.eval_shape(
        lambda: TrainState.create(
            api.init_params(jax.random.PRNGKey(0)),
            npods=rules.axis_sizes.get("pod", 0) if rules else 0,
            compression=run.grad_compression,
        )
    )
    batch_struct = model_api.train_input_specs(cfg, shape)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0,)), (state_struct,
                                                    batch_struct)
    pspec = rules.param_spec_tree(state_struct.params)
    ef_spec = None
    if state_struct.ef_err is not None:
        ef_spec = jax.tree.map(lambda _: P("pod"), state_struct.ef_err)
    state_spec = TrainState(
        step=P(), params=pspec, m=pspec, v=pspec, ef_err=ef_spec)
    batch_spec = rules.input_specs_tree(batch_struct)

    in_shardings = (named(mesh, state_spec), named(mesh, batch_spec))
    out_shardings = (named(mesh, state_spec), None)
    fn = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings,
                 donate_argnums=(0,))   # state updated in place
    return fn, (state_struct, batch_struct)


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules,
                 run: RunConfig, *, unroll: bool):
    api = model_api.get_model(cfg)
    ctx = _ctx(cfg, mesh, rules, run)

    def serve_step(params, tokens, cache, lengths):
        logits, new_cache = api.decode_step(
            ctx, params, tokens, cache, lengths, unroll=unroll)
        return logits, new_cache

    params_struct = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0)))
    pspec = rules.param_spec_tree(params_struct) if rules else None
    specs = model_api.serve_decode_input_specs(cfg, shape)
    cache_struct = specs["cache"]
    if mesh is None:
        fn = jax.jit(serve_step, donate_argnums=(2,))
        return fn, (params_struct, specs["tokens"], cache_struct,
                    specs["lengths"])
    cache_spec = jax.tree.map(
        lambda l: rules.cache_spec(l.shape), cache_struct)
    tok_spec = rules.batch_spec(specs["tokens"].shape)
    len_spec = rules.batch_spec(specs["lengths"].shape)

    in_shardings = (
        named(mesh, pspec), NamedSharding(mesh, tok_spec),
        named(mesh, cache_spec), NamedSharding(mesh, len_spec),
    )
    # pin the cache output to its input layout: no per-token resharding;
    # donate it: the KV append must be in place (32k cache per token!)
    out_shardings = (None, named(mesh, cache_spec))
    fn = jax.jit(serve_step, in_shardings=in_shardings,
                 out_shardings=out_shardings, donate_argnums=(2,))
    args = (params_struct, specs["tokens"], cache_struct, specs["lengths"])
    return fn, args


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules,
                  run: RunConfig, *, unroll: bool):
    api = model_api.get_model(cfg)
    ctx = _ctx(cfg, mesh, rules, run)
    specs = model_api.serve_prefill_input_specs(cfg, shape)
    cache_struct = api.cache_spec(
        model_api.DenseLayout(shape.global_batch, shape.seq_len))

    def prefill_step(params, tokens, lengths, extra):
        logits, cache = api.prefill(
            ctx, params, tokens, lengths, cache_struct,
            unroll=unroll, **extra)
        return logits, cache

    params_struct = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0)))
    extra = {k: v for k, v in specs.items()
             if k not in ("tokens", "lengths")}
    if mesh is None:
        return jax.jit(prefill_step), (params_struct, specs["tokens"],
                                       specs["lengths"], extra)
    pspec = rules.param_spec_tree(params_struct)
    extra_spec = {k: rules.batch_spec(v.shape) for k, v in extra.items()}
    in_shardings = (
        named(mesh, pspec),
        NamedSharding(mesh, rules.batch_spec(specs["tokens"].shape)),
        NamedSharding(mesh, rules.batch_spec(specs["lengths"].shape)),
        named(mesh, extra_spec),
    )
    cache_spec = jax.tree.map(
        lambda l: rules.cache_spec(l.shape), cache_struct)
    out_shardings = (None, named(mesh, cache_spec))
    fn = jax.jit(prefill_step, in_shardings=in_shardings,
                 out_shardings=out_shardings)
    args = (params_struct, specs["tokens"], specs["lengths"], extra)
    return fn, args


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}

# serving keeps params data-replicated below this per-chip TP-shard size
# (v5e: 16 GB HBM - KV cache - activations headroom)
SERVE_REPLICATE_BUDGET_BYTES = 10e9


# ---------------------------------------------------------------------------
# Lower + compile + analyse one cell
# ---------------------------------------------------------------------------


def _probe_cfg(cfg: ModelConfig, num_layers: int) -> ModelConfig:
    updates: dict[str, Any] = {"num_layers": num_layers}
    if cfg.encoder_layers:
        updates["encoder_layers"] = num_layers
    return dataclasses.replace(cfg, **updates)


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh_cfg: MeshConfig,
    mesh,
    run: RunConfig,
    *,
    unroll: bool = False,
    compile_: bool = True,
):
    """Returns ((lowered, compiled|None), seconds_lower, seconds_compile).

    ``mesh=None`` lowers the unsharded program (used to count exact global
    FLOPs/bytes: inside-shard_map ops are otherwise reported per shard).
    """
    # Serving layout: params replicate over `data` when the TP shard fits
    # the HBM budget — FSDP would all-gather the whole parameter set every
    # decoded token (EXPERIMENTS.md §Perf, deepseek decode iteration 2).
    # Training always uses FSDP (optimizer state triples the footprint).
    fsdp_params = True
    if shape.kind in ("decode", "prefill"):
        model_shards = dict(zip(mesh_cfg.axis_names,
                                mesh_cfg.shape)).get("model", 1)
        tp_bytes = cfg.param_count() * 2 / model_shards
        fsdp_params = tp_bytes > SERVE_REPLICATE_BUDGET_BYTES
    rules = None if mesh is None else make_rules(
        mesh_cfg,
        seq_shard_kv=run.seq_shard_attention,
        fsdp_over_pod=run.grad_compression == "none",
        act_over_pod=run.grad_compression == "none",
        fsdp_params=fsdp_params,
    )
    fn, args = BUILDERS[shape.kind](cfg, shape, mesh, rules, run,
                                    unroll=unroll)
    t0 = time.time()
    lowered = fn.lower(*args)
    t1 = time.time()
    if not compile_:
        return (lowered, None), t1 - t0, 0.0
    compiled = lowered.compile()
    t2 = time.time()
    return (lowered, compiled), t1 - t0, t2 - t1


def analyse(lowered, compiled) -> dict:
    """FLOPs/bytes from the *pre-SPMD* module (global, exact, independent
    of per-L partitioning strategy — compiled per-device cost_analysis on
    XLA:CPU also misses dots inside wrapped fusions); collective schedule
    and memory fit from the *post-SPMD* compiled module."""
    out: dict[str, Any] = {}
    try:
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out["flops_global"] = float(cost.get("flops", 0.0))
        out["bytes_global"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # noqa: BLE001
        out["lowered_cost_error"] = repr(e)
    if compiled is None:
        return out
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out["flops_per_device"] = float(cost.get("flops", 0.0))
        out["bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # noqa: BLE001
        out["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                out[k] = int(v)
        if "argument_size_in_bytes" in out:
            out["per_device_bytes"] = (
                out["argument_size_in_bytes"]
                + out.get("temp_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0)
            )
    except Exception as e:  # noqa: BLE001
        out["memory_error"] = repr(e)
    try:
        text = compiled.as_text()
        stats = hlo_analysis.parse_collectives(text)
        out["collective_bytes"] = stats.total_bytes()
        out["collective_counts"] = stats.counts
        out["collective_by_kind"] = stats.by_kind()
    except Exception as e:  # noqa: BLE001
        out["hlo_error"] = repr(e)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    mesh_cfg: MeshConfig,
    mesh,
    run: RunConfig,
    *,
    probes: tuple[int, ...] = (1, 3),
    full: bool = True,
    sync_softmax: bool = False,
    plan: Optional[plan_mod.ExecutionPlan] = None,
) -> dict:
    cfg = configs.get(arch)
    if plan is not None:
        run = dataclasses.replace(run, plan=plan)
    if sync_softmax:   # paper-faithful pre-T1 baseline (Fig. 4(b))
        run = dataclasses.replace(run, sync_softmax=True)
    shape = SHAPES[shape_name]
    mesh_name = "x".join(str(s) for s in mesh_cfg.shape)
    if sync_softmax:
        mesh_name += "-sync"
    record: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": mesh_cfg.num_devices, "ok": False,
    }
    try:
        if full:
            (lowered, compiled), tl, tc = lower_cell(
                cfg, shape, mesh_cfg, mesh, run, unroll=False)
            record["full"] = analyse(lowered, compiled)
            record["full"]["lower_s"] = round(tl, 2)
            record["full"]["compile_s"] = round(tc, 2)
            del lowered, compiled
        probe_rows = []
        for nl in probes:
            pcfg = _probe_cfg(cfg, nl)
            (lowered, compiled), tl, tc = lower_cell(
                pcfg, shape, mesh_cfg, mesh, run, unroll=True)
            row = analyse(lowered, compiled)
            # exact global FLOPs/bytes: unsharded lowering (shard_map
            # regions in the sharded module are counted per shard)
            (lone, _), _, _ = lower_cell(
                pcfg, shape, mesh_cfg, None, run, unroll=True,
                compile_=False)
            gcost = lone.cost_analysis()
            row["flops_global"] = float(gcost.get("flops", 0.0))
            row["bytes_global"] = float(gcost.get("bytes accessed", 0.0))
            row["num_layers"] = nl
            row["lower_s"] = round(tl, 2)
            row["compile_s"] = round(tc, 2)
            probe_rows.append(row)
            del lowered, compiled, lone
        record["probes"] = probe_rows
        record["ok"] = True
    except Exception:  # noqa: BLE001
        record["error"] = traceback.format_exc(limit=20)
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def iter_cells(arch_filter=None, shape_filter=None):
    for arch in configs.ASSIGNED:
        if arch_filter and arch != arch_filter:
            continue
        cfg = configs.get(arch)
        for shape in applicable_shapes(cfg):
            if shape_filter and shape.name != shape_filter:
                continue
            yield arch, shape.name


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-probes", action="store_true",
                    help="full compile only (multi-pod shard proof)")
    ap.add_argument("--no-full", action="store_true",
                    help="probes only (roofline terms)")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--sync-softmax", action="store_true",
                    help="paper-faithful pre-T1 baseline: dispatch every "
                         "attention op with the synchronized scheme")
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="ExecutionPlan JSON to dispatch by (requires "
                         "--arch: a plan's provenance pins one config)")
    ap.add_argument("--tune", action="store_true",
                    help="tune a fresh plan per arch before lowering "
                         "(analytical backend; backend/fallback are still "
                         "forced to xla/off for cost-analysis hygiene)")
    ap.add_argument("--decode-fusion",
                    choices=["split", "fused", "looped"], default=None,
                    help="override the plan's decode-layer stage "
                         "granularity for the decode cells (the xla "
                         "backend override keeps the granularity; fused "
                         "stages dispatch their jnp oracles)")
    ap.add_argument("--weight-dtype",
                    choices=["bf16", "int8", "fp8"], default=None,
                    help="override the plan's GEMM weight storage dtype "
                         "(matmul.weight_dtype) for cost analysis — the "
                         "lowered cells carry the knob so the roofline "
                         "sees the quantized weight stream")
    args = ap.parse_args()
    if args.plan and not args.arch:
        ap.error("--plan requires --arch (plan provenance pins one config)")

    os.makedirs(args.out, exist_ok=True)
    run = RunConfig(grad_compression=args.grad_compression)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(SINGLE_POD)
    if args.mesh in ("multi", "both"):
        meshes.append(MULTI_POD)

    # resolve plans once per arch (a tune sweep / file parse per cell
    # would be pure waste — cells only vary shape and mesh)
    plans: dict[str, plan_mod.ExecutionPlan] = {}

    def plan_for(arch: str) -> Optional[plan_mod.ExecutionPlan]:
        if not (args.tune or args.plan or args.decode_fusion
                or args.weight_dtype):
            return None
        if arch not in plans:
            cfg = configs.get(arch)
            if args.tune:
                tuned = plan_mod.tune(cfg)
                if args.plan:   # serve.py semantics: tune + save to --plan
                    tuned.save(args.plan)
                base = tuned
            elif args.plan:
                base = plan_mod.ExecutionPlan.load(args.plan, cfg=cfg)
            else:
                base = plan_mod.make_plan()
            if args.decode_fusion is not None:
                base = dataclasses.replace(
                    base, decode_fusion=dataclasses.replace(
                        base.decode_fusion,
                        granularity=args.decode_fusion))
            if args.weight_dtype is not None:
                base = dataclasses.replace(
                    base, matmul=dataclasses.replace(
                        base.matmul, weight_dtype=args.weight_dtype))
            plans[arch] = base
        return plans[arch]

    failures = 0
    for mesh_cfg in meshes:
        mesh = make_mesh_from_config(mesh_cfg)
        mesh_name = "x".join(str(s) for s in mesh_cfg.shape)
        # probes (roofline) are single-pod only per the assignment;
        # multi-pod is the shard proof (full compile).
        probes = () if (args.no_probes or mesh_cfg is MULTI_POD) else (1, 3)
        for arch, shape_name in iter_cells(args.arch, args.shape):
            t0 = time.time()
            rec = run_cell(arch, shape_name, mesh_cfg, mesh, run,
                           probes=probes, full=not args.no_full,
                           sync_softmax=args.sync_softmax,
                           plan=plan_for(arch))
            dt = time.time() - t0
            tag = "OK " if rec["ok"] else "FAIL"
            print(f"[{tag}] {mesh_name:<9} {arch:<16} {shape_name:<12} "
                  f"({dt:.1f}s)", flush=True)
            if not rec["ok"]:
                failures += 1
                print(rec["error"].splitlines()[-1])
            fname = f"{arch}__{shape_name}__{mesh_name}.json"
            with open(os.path.join(args.out, fname), "w") as f:
                json.dump(rec, f, indent=2)
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
