"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 200 --batch 8 --seq 128

``--smoke`` uses the reduced same-family config (CPU-runnable ~100M-and-
below models); without it the full assigned config is built (real-TPU
deployments). ``--devices N`` requests N placeholder devices *before jax
initializes* to exercise the sharded path on CPU.

Fault tolerance is live here: SIGTERM checkpoints and exits 0; rerunning
the same command resumes from the latest committed step.
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N placeholder devices + mesh (data, model)")
    ap.add_argument("--mesh", default="",
                    help="mesh as DATAxMODEL, e.g. 4x2 (with --devices)")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args()


def main() -> int:
    args = _parse()
    if args.devices:
        flags = f"--xla_force_host_platform_device_count={args.devices}"
        if args.grad_compression != "none":
            # XLA:CPU's all-reduce-promotion pass CHECK-crashes on the
            # partitioned collectives of the pod-manual grad step (CPU-only
            # pass; TPU unaffected). Harmless to skip: it only widens
            # small-dtype all-reduces that CPU could not fuse anyway.
            flags += " --xla_disable_hlo_passes=all-reduce-promotion"
        os.environ["XLA_FLAGS"] = flags

    import jax

    from repro import configs
    from repro.config import MeshConfig, RunConfig, ShapeConfig
    from repro.distributed.sharding import make_rules, make_shard_fn, named
    from repro.launch.mesh import make_mesh_from_config
    from repro.models.api import get_model, train_input_specs
    from repro.models.layers import LayerCtx
    from repro.training.loop import train_loop
    from repro.training.train_state import TrainState, make_train_step

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        microbatch=args.microbatch,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        grad_compression=args.grad_compression,
        seed=args.seed,
        warmup_steps=max(args.steps // 20, 1),
    )

    mesh = None
    rules = None
    state_shardings = None
    batch_specs = None
    if args.devices:
        dims = [int(x) for x in (args.mesh or "").split("x") if x] or None
        if dims is None:
            dims = [max(args.devices // 2, 1), min(2, args.devices)]
        names = ("data", "model") if len(dims) == 2 else (
            "pod", "data", "model")
        mesh_cfg = MeshConfig(tuple(dims), names)
        mesh = make_mesh_from_config(mesh_cfg)
        rules = make_rules(
            mesh_cfg, fsdp_over_pod=args.grad_compression == "none",
            act_over_pod=args.grad_compression == "none")

    api = get_model(cfg)
    ctx = LayerCtx(cfg=cfg, shard=make_shard_fn(mesh, rules),
                   moe_groups=1 if mesh is None else
                   max(dict(zip(mesh.axis_names, mesh.devices.shape)
                            ).get("data", 1), 1))
    step = make_train_step(api, ctx, run, mesh=mesh)

    def init_state():
        params = api.init_params(jax.random.PRNGKey(run.seed))
        npods = 0
        if mesh is not None:
            npods = dict(zip(mesh.axis_names, mesh.devices.shape)
                         ).get("pod", 0)
        return TrainState.create(params, npods=npods,
                                 compression=run.grad_compression)

    jit_kwargs = {}
    if mesh is not None:
        state_struct = jax.eval_shape(init_state)
        pspec = rules.param_spec_tree(state_struct.params)
        from jax.sharding import PartitionSpec as P
        ef_spec = (jax.tree.map(lambda _: P("pod"), state_struct.ef_err)
                   if state_struct.ef_err is not None else None)
        state_spec = TrainState(step=P(), params=pspec, m=pspec, v=pspec,
                                ef_err=ef_spec)
        batch_specs = rules.input_specs_tree(train_input_specs(cfg, shape))
        state_shardings = named(mesh, state_spec)
        jit_kwargs = dict(
            in_shardings=(state_shardings, named(mesh, batch_specs)),
            out_shardings=(state_shardings, None),
        )
    train_step = jax.jit(step, donate_argnums=(0,), **jit_kwargs)

    res = train_loop(
        model_cfg=cfg, shape=shape, run=run, train_step=train_step,
        init_state=init_state, mesh=mesh, state_shardings=state_shardings,
        batch_specs=batch_specs, log_every=args.log_every,
    )
    print(
        f"finished at step {res.final_step} "
        f"(restored_from={res.restored_from}, preempted={res.preempted}); "
        f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}; "
        f"median step {1e3 * sorted(res.step_times)[len(res.step_times)//2]:.1f} ms; "
        f"slow_steps={res.slow_steps}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
