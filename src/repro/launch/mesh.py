"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — device count is locked on first
use, and smoke tests must see 1 CPU device while the dry-run sees 512
placeholders.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "the dry-run entrypoint must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_config(cfg: MeshConfig) -> Mesh:
    n = cfg.num_devices
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(cfg.shape, cfg.axis_names, devices=devices)
