"""End-to-end serving driver (continuous batching over synthetic requests).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 16 --slots 4 --max-new 12

Pool pressure and preemption are drivable from the CLI: ``--cache-kind
paged --overcommit 0.5`` provisions half the worst-case page pool (or set
``--num-pages`` exactly), and ``--scheduler`` picks the admission/victim
policy. ``--prefix-sharing`` (with ``--shared-prefix N`` to synthesize a
common system prompt) maps identical page-aligned prompt prefixes onto
refcounted copy-on-write pages. The summary line reports per-phase
throughput plus preemption, page-utilization, and prefix-sharing counters
— the scheduler-policy numbers the paper's heuristic-dataflow argument
cares about.

Kernel dispatch is plan-driven: ``--tune`` runs the offline T3 decision
flow for the arch and saves a provenanced ``plans/<arch>-<hw>.json``;
``--plan PATH`` serves with a previously tuned plan (stale plans — wrong
hardware or config hash — are rejected at load). ``--gather-chunk
dense|fused`` overrides the plan's chunked-prefill page-access mode
(fused = the chunk-attention kernel over the pool / resident-bounded
tables on XLA — see ``repro.kernels.chunk_attention``). ``--decode-group
grouped`` overrides the plan's prefix-shared decode mode: with
``--prefix-sharing`` on a paged cache, requests decoding behind the same
refcounted prefix pages attend to the shared prefix once per group and
unified-max-merge their private tails (see
``repro.kernels.group_attention``); the summary then reports grouped
decode counts and prefix KV bytes the dedup saved.

The tiered KV hierarchy rides on ``--host-pages N`` (host-RAM page store
behind the device pool) and ``--session-cache`` (retain finished
conversations' KV pages — demoted host-ward under pool pressure, promoted
back when the conversation returns): preemption and retirement demote
pages instead of discarding them, and the summary grows
demoted/promoted/session-hit counters. ``--rounds R`` resubmits the same
prompts R times (returning-conversation workload — the second round hits
the session cache instead of re-prefilling).

``--kv-dtype int8|fp8`` stores KV pages quantized (paged cache only):
each page carries per-(page, head) symmetric scales in a parallel f32
pool and the decode/chunk kernels dequantize in-registers, so the
full-precision slab never exists in HBM. int8 halves KV bytes per decode
step and doubles resident-page capacity at greedy-equivalent accuracy;
fp8 (e4m3) matches the footprint with cheaper dequant but coarser
mantissa. The summary reports bytes/page and total decode-read KV bytes
so the savings are directly visible against a ``bf16`` run.

``--weight-dtype int8|fp8`` is the weight-side twin: GEMM weight leaves
are quantized once at load to int8/fp8 codes plus one f32 scale per
output channel, and every GEMM kernel dequantizes on the f32 accumulator
in-register, so the bf16 weight slab never exists in HBM. At decode's
tiny M the weight stream dominates the tick, so int8 halves it (the
summary's ``weights=`` segment reports stored bytes per tick and total
decode-read weight bytes against a ``bf16`` run); accuracy is held to
the same dtype-derived logits guard as ``--kv-dtype``.

``--decode-fusion split|fused|looped`` overrides the plan's decode-layer
stage granularity (``DecodeFusionPlan``): ``fused`` collapses
norm→QKV→rope and o_proj→residual into the fused stage kernels,
``looped`` additionally runs the whole depth under one ``lax.scan``. The
summary line reports the effective granularity as ``fusion=...``.
"""
import argparse
import sys
import time


def _parse():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--cache-kind", choices=["dense", "paged"],
                    default="dense",
                    help="dense slot cache or block-paged pool")
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="exact pool size in pages (default: worst-case "
                         "slots*max_seq footprint scaled by --overcommit)")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="fraction of the worst-case page footprint to "
                         "provision; <1 forces lazy-growth preemption")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "sjf", "pagefair"],
                    help="admission/preemption policy")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="map identical page-aligned prompt prefixes onto "
                         "shared refcounted pages (copy-on-write; paged "
                         "cache only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical tokens to every "
                         "synthetic prompt (system-prompt workload — makes "
                         "--prefix-sharing visible in the summary)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill chunk size (dense-KV families); "
                         "default: the plan's tuned paged.chunk_block")
    ap.add_argument("--gather-chunk", choices=["dense", "fused"],
                    default=None,
                    help="override the plan's chunked-prefill page access "
                         "mode: 'dense' gathers the full (B, NB*PS) KV "
                         "view per chunk step, 'fused' reads pages in "
                         "place (fused kernel on the Pallas backend, "
                         "resident-bounded tables on XLA)")
    ap.add_argument("--decode-group", choices=["off", "grouped"],
                    default=None,
                    help="override the plan's prefix-shared decode mode: "
                         "'grouped' computes shared-prefix attention once "
                         "per group and unified-max-merges per-request "
                         "private tails (paged cache + --prefix-sharing "
                         "only)")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host-RAM tier capacity in KV pages: preemption "
                         "and retirement demote pages here instead of "
                         "discarding them (paged cache + --prefix-sharing "
                         "only); returning prompts promote them back")
    ap.add_argument("--session-cache", action="store_true",
                    help="retain finished conversations' KV pages in the "
                         "tiered session cache (implied by --host-pages; "
                         "alone it enables tier-0 retention only)")
    ap.add_argument("--kv-dtype", choices=["bf16", "int8", "fp8"],
                    default=None,
                    help="KV-cache page storage dtype (paged cache only): "
                         "int8/fp8 pages carry per-(page, head) scales and "
                         "are dequantized inside the attention kernels; "
                         "default: the plan's paged.kv_dtype")
    ap.add_argument("--weight-dtype", choices=["bf16", "int8", "fp8"],
                    default=None,
                    help="GEMM weight storage dtype: int8/fp8 weights are "
                         "quantized at load to codes + per-output-channel "
                         "f32 scales and dequantized on the kernels' f32 "
                         "accumulators; default: the plan's "
                         "matmul.weight_dtype")
    ap.add_argument("--decode-fusion", choices=["split", "fused", "looped"],
                    default=None,
                    help="decode-layer stage granularity: split = the "
                         "per-op chain, fused = fused ingest/epilogue "
                         "stage kernels per layer, looped = fused stages "
                         "under one depth scan; default: the plan's "
                         "decode_fusion.granularity")
    ap.add_argument("--rounds", type=int, default=1,
                    help="resubmit every prompt this many times — round "
                         ">= 2 models returning conversations hitting the "
                         "session cache")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--plan", default=None, metavar="PATH",
                    help="ExecutionPlan JSON to dispatch kernels by; "
                         "rejected if its provenance (hardware/config "
                         "hash) does not match this run")
    ap.add_argument("--tune", action="store_true",
                    help="tune a plan offline for this arch (T3 decision "
                         "flow over every op), save it to --plan (default "
                         "plans/<arch>-<hw>.json), and serve with it")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main() -> int:
    args = _parse()
    import jax
    import numpy as np

    from repro import configs
    from repro.core import plan as plan_mod
    from repro.models.api import get_model
    from repro.models.kvlayout import pages_for
    from repro.serving.engine import Engine
    from repro.serving.request import SamplingParams

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = configs.smoke(cfg)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(args.seed))

    plan = None
    if args.tune:
        plan = plan_mod.tune(cfg, page_size=args.page_size)
        path = args.plan or plan_mod.default_plan_path(cfg)
        plan.save(path)
        print(f"tuned plan -> {path}\n  {plan.describe()}")
    elif args.plan:
        plan = plan_mod.ExecutionPlan.load(args.plan, cfg=cfg)
        print(f"loaded plan {args.plan}\n  {plan.describe()}")

    if args.gather_chunk is not None or args.decode_group is not None:
        import dataclasses
        base = plan if plan is not None else plan_mod.DEFAULT_PLAN
        over = {}
        if args.gather_chunk is not None:
            over["gather_chunk"] = args.gather_chunk
        if args.decode_group is not None:
            over["decode_group"] = args.decode_group
        plan = dataclasses.replace(
            base, paged=dataclasses.replace(base.paged, **over))

    num_pages = args.num_pages
    if num_pages is None and args.cache_kind == "paged":
        worst = args.slots * pages_for(args.max_seq, args.page_size)
        num_pages = max(int(worst * args.overcommit), 1)

    eng = Engine(cfg, params, num_slots=args.slots, max_seq=args.max_seq,
                 cache_kind=args.cache_kind, page_size=args.page_size,
                 num_pages=num_pages, prefill_chunk=args.prefill_chunk,
                 scheduler=args.scheduler, plan=plan,
                 prefix_sharing=args.prefix_sharing,
                 host_pages=args.host_pages,
                 session_cache=args.session_cache or None,
                 kv_dtype=args.kv_dtype,
                 weight_dtype=args.weight_dtype,
                 decode_fusion=args.decode_fusion,
                 seed=args.seed)
    rng = np.random.default_rng(args.seed)
    sp = SamplingParams(max_new_tokens=args.max_new,
                        temperature=args.temperature, top_p=args.top_p)
    header = rng.integers(1, cfg.vocab_size,
                          size=args.shared_prefix).astype(np.int32)
    reqs = [
        (np.concatenate([header, rng.integers(
            1, cfg.vocab_size, size=args.prompt_len).astype(np.int32)]), sp)
        for _ in range(args.requests)
    ]

    t0 = time.perf_counter()
    out = {}
    for rnd in range(max(args.rounds, 1)):
        out = eng.run(reqs)
        if rnd + 1 < args.rounds:
            eng.evict_finished()   # KV stays in the session cache
    dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in out.values())
    line = (f"served {len(out)} requests, {total_tokens} tokens in {dt:.2f}s "
            f"({total_tokens / dt:.1f} tok/s, {eng.ticks} decode ticks, "
            f"{eng.scheduler.name} scheduler, "
            f"fusion={eng.decode_fusion}, "
            f"weights={eng.weight_dtype} "
            f"({eng._weight_bytes_per_tick} B/tick, "
            f"{eng.stats.weight_bytes_decode_read} decode weight bytes "
            f"read), "
            f"{eng.stats.preemptions} preemptions")
    if eng.pool is not None:
        util = eng.stats.peak_pages_used / eng.pool.num_pages
        line += (f", peak pages {eng.stats.peak_pages_used}"
                 f"/{eng.pool.num_pages} = {util:.0%}")
        line += (f", kv={eng.kv_dtype} "
                 f"({eng.stats.kv_page_bytes} B/page, "
                 f"{eng.stats.kv_bytes_decode_read} decode KV bytes read)")
    if args.prefix_sharing:
        line += (f", {eng.stats.shared_prefix_pages} shared pages, "
                 f"{eng.stats.saved_prefill_tokens} prefill tokens saved, "
                 f"{eng.stats.cow_forks} COW forks")
    if eng.stats.grouped_requests:
        line += (f", {eng.stats.grouped_requests} grouped decodes, "
                 f"{eng.stats.prefix_kv_bytes_saved} prefix KV bytes saved")
    if eng.tiers is not None:
        line += (f", {eng.stats.demoted_pages} pages demoted, "
                 f"{eng.stats.promoted_pages} promoted, "
                 f"{eng.stats.session_hits} session hits")
        if eng.stats.host_evicted_pages:
            line += f", {eng.stats.host_evicted_pages} evicted"
    print(line + ")")
    for rid in sorted(out)[:4]:
        print(f"  req {rid}: {out[rid]} "
              f"[{eng.finish_reason(rid)}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
