"""Assigned architectures (public literature) + the paper's own model.

One module per arch; ``REGISTRY`` maps the assignment's ``--arch`` ids
(dashes) to :class:`~repro.config.ModelConfig`. ``smoke(cfg)`` derives the
reduced same-family config used by the per-arch CPU smoke tests (the full
configs are only exercised via the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig, MoEConfig, SSMConfig

from repro.configs.qwen2_0_5b import CONFIG as QWEN2_05B
from repro.configs.minitron_8b import CONFIG as MINITRON_8B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.phi3_mini_3_8b import CONFIG as PHI3_MINI
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.internvl2_76b import CONFIG as INTERNVL2_76B
from repro.configs.grok_1_314b import CONFIG as GROK_1
from repro.configs.dbrx_132b import CONFIG as DBRX
from repro.configs.hymba_1_5b import CONFIG as HYMBA
from repro.configs.rwkv6_1_6b import CONFIG as RWKV6
from repro.configs.llama2_7b import CONFIG as LLAMA2_7B

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        QWEN2_05B, MINITRON_8B, DEEPSEEK_67B, PHI3_MINI, WHISPER_TINY,
        INTERNVL2_76B, GROK_1, DBRX, HYMBA, RWKV6, LLAMA2_7B,
    )
}

ASSIGNED = [
    "qwen2-0.5b", "minitron-8b", "deepseek-67b", "phi3-mini-3.8b",
    "whisper-tiny", "internvl2-76b", "grok-1-314b", "dbrx-132b",
    "hymba-1.5b", "rwkv6-1.6b",
]


def get(name: str) -> ModelConfig:
    return REGISTRY[name]


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kv = min(cfg.num_kv_heads, 2)
    heads = max(kv * 2, 4) if cfg.family != "ssm" else 2
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv if cfg.family != "ssm" else heads,
        head_dim=128 // heads if cfg.family != "ssm" else 0,
        d_ff=256,
        vocab_size=512,
        max_seq_len=4096,
    )
    if cfg.moe is not None:
        updates["moe"] = MoEConfig(
            num_experts=4,
            num_experts_per_tok=min(2, cfg.moe.num_experts_per_tok),
        )
    if cfg.ssm is not None:
        updates["ssm"] = SSMConfig(
            state_size=cfg.ssm.state_size, head_dim=64, expand=2
        )
        updates["d_model"] = 128
        if cfg.family == "ssm":
            updates["num_heads"] = 2
            updates["num_kv_heads"] = 2
            updates["head_dim"] = 0
    if cfg.encoder_layers:
        updates["encoder_layers"] = 2
    if cfg.sliding_window:
        updates["sliding_window"] = 64
    return dataclasses.replace(cfg, **updates)
