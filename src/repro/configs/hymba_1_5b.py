"""Hymba-1.5B — hybrid parallel attention+mamba heads. [arXiv:2411.13676; hf]"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    sliding_window=1024,
    ssm=SSMConfig(state_size=16, head_dim=64, expand=2),
    source="arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base",
)
