"""DeepSeek-67B — llama-architecture dense GQA, 95 layers. [arXiv:2401.02954; hf]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
)
