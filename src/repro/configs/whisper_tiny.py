"""Whisper-tiny — enc-dec audio backbone; conv frontend STUB. [arXiv:2212.04356; unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    norm="layernorm",
    activation="gelu",
    frontend="audio",
    source="arXiv:2212.04356; hf:openai/whisper-tiny",
)
