"""DBRX 132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base; unverified]"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    moe=MoEConfig(num_experts=16, num_experts_per_tok=4),
    source="hf:databricks/dbrx-base",
)
