"""Phi-3-mini 3.8B — RoPE SwiGLU, MHA-equal GQA (kv=32). [arXiv:2404.14219; unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    source="arXiv:2404.14219; hf:microsoft/Phi-3-mini-4k-instruct",
)
