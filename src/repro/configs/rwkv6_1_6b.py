"""RWKV6 (Finch) 1.6B — attention-free, data-dependent decay. [arXiv:2404.05892; unverified]"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,   # d_model / ssm head_dim — API bookkeeping only
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    ssm=SSMConfig(state_size=64, head_dim=64),
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-1b6",
)
