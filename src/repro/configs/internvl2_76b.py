"""InternVL2-76B — InternViT (STUB) + LLaMA3-70B-style LM backbone. [arXiv:2404.16821; unverified]"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=5e5,
    frontend="vision",
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-Llama3-76B",
)
