"""Provenance/schema linter for committed ``plans/*.json`` artifacts.

    PYTHONPATH=src python tools/check_plans.py [paths...]

A tuned plan is a promise: "these knob values were derived from *this*
config on *this* hardware." The serving loader (``ExecutionPlan.load``)
already rejects stale plans at use time, but a drive-by edit to
``HardwareSpec`` or ``ModelConfig`` strands every committed artifact
silently — nothing fails until someone serves with ``--plan``. This
linter runs the same checks ahead of time, over every committed plan:

  * the document parses through ``ExecutionPlan.from_json`` — every knob
    value passes the same validation serving uses (scheme/backend
    whitelists, positive blocks, ``kv_dtype`` in ``KV_DTYPES``, ...);
  * ``version`` matches ``PLAN_VERSION``;
  * provenance exists, its ``config`` hash matches the *current*
    ``configs.get(config_name)`` and its ``hardware`` hash matches the
    named spec in ``repro.hardware`` — i.e. the plan would load
    strictly today;
  * the paged op records an explicit ``kv_dtype`` (pre-quantization
    documents default to bf16 on load, but committed artifacts must say
    what they tuned for);
  * the ``decode_fusion`` op records an explicit ``granularity`` in
    ``FUSION_MODES`` (pre-fusion documents default to split on load —
    same rule: committed artifacts must say what they tuned);
  * the ``matmul`` op records an explicit ``weight_dtype`` in
    ``WEIGHT_DTYPES`` (pre-weight-quant documents default to bf16 on
    load — committed artifacts must say what they tuned);
  * the filename matches ``default_plan_path`` for its provenance.

Exit status 0 = every plan clean, 1 = at least one finding (one line per
finding, ``path: message``). Wired into tier-1 via
``tests/test_check_plans.py`` so the committed plans can never go stale
unnoticed again.
"""
from __future__ import annotations

import glob
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro import configs, hardware  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402
from repro.core.plan import (  # noqa: E402
    FUSION_MODES, KV_DTYPES, PLAN_VERSION, WEIGHT_DTYPES, ExecutionPlan,
    PlanError,
)


def _hardware_registry() -> dict:
    """name -> HardwareSpec for every spec the hardware module defines."""
    return {
        spec.name: spec
        for spec in vars(hardware).values()
        if isinstance(spec, hardware.HardwareSpec)
    }


def check_plan(path: str) -> list:
    """All findings for one plan file ([] = clean)."""
    findings = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"unreadable JSON: {e}"]

    # knob validity: the full document must round-trip the same
    # constructors serving uses
    try:
        plan = ExecutionPlan.from_json(json.dumps(doc))
    except PlanError as e:
        return [f"schema: {e}"]

    if doc.get("version") != PLAN_VERSION:
        findings.append(f"version {doc.get('version')!r} != "
                        f"PLAN_VERSION {PLAN_VERSION}")

    paged_doc = doc.get("ops", {}).get("paged", {})
    if "kv_dtype" not in paged_doc:
        findings.append("paged op missing explicit kv_dtype "
                        "(legacy document — retune)")
    elif paged_doc["kv_dtype"] not in KV_DTYPES:
        findings.append(f"kv_dtype {paged_doc['kv_dtype']!r} "
                        f"not in {KV_DTYPES}")

    matmul_doc = doc.get("ops", {}).get("matmul", {})
    if "weight_dtype" not in matmul_doc:
        findings.append("matmul op missing explicit weight_dtype "
                        "(legacy document — retune)")
    elif matmul_doc["weight_dtype"] not in WEIGHT_DTYPES:
        findings.append(f"weight_dtype {matmul_doc['weight_dtype']!r} "
                        f"not in {WEIGHT_DTYPES}")

    fusion_doc = doc.get("ops", {}).get("decode_fusion", {})
    if "granularity" not in fusion_doc:
        findings.append("decode_fusion op missing explicit granularity "
                        "(legacy document — retune)")
    elif fusion_doc["granularity"] not in FUSION_MODES:
        findings.append(f"decode_fusion granularity "
                        f"{fusion_doc['granularity']!r} "
                        f"not in {FUSION_MODES}")

    prov = plan.provenance
    if prov is None:
        findings.append("no provenance (hand-written plan committed?)")
        return findings

    try:
        cfg = configs.get(prov.config_name)
    except Exception:
        findings.append(f"provenance config {prov.config_name!r} is not "
                        "a known arch")
        cfg = None
    if cfg is not None and plan_mod.config_hash(cfg) != prov.config:
        findings.append(
            f"stale config hash: plan {prov.config} vs current "
            f"{plan_mod.config_hash(cfg)} for {prov.config_name} — retune")

    specs = _hardware_registry()
    spec = specs.get(prov.hardware_name)
    if spec is None:
        findings.append(f"provenance hardware {prov.hardware_name!r} is "
                        "not a known HardwareSpec")
    elif plan_mod.hardware_hash(spec) != prov.hardware:
        findings.append(
            f"stale hardware hash: plan {prov.hardware} vs current "
            f"{plan_mod.hardware_hash(spec)} for {prov.hardware_name} "
            "— retune")

    if cfg is not None and spec is not None:
        want = os.path.basename(plan_mod.default_plan_path(cfg, spec))
        got = os.path.basename(path)
        if got != want:
            findings.append(f"filename {got!r} != canonical {want!r}")

    return findings


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = argv or sorted(glob.glob(os.path.join(_ROOT, "plans", "*.json")))
    if not paths:
        print("check_plans: no plan files found", file=sys.stderr)
        return 1
    bad = 0
    for path in paths:
        findings = check_plan(path)
        rel = os.path.relpath(path, _ROOT)
        if findings:
            bad += 1
            for msg in findings:
                print(f"{rel}: {msg}")
        else:
            print(f"{rel}: ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
