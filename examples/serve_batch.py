"""Serve a small model with batched requests through the continuous-
batching engine — the paper's end-to-end inference scenario.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.core.plan import tune
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams


def main():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    # T3: offline-tuned execution plan wired into every op of the engine
    plan = tune(cfg)
    eng = Engine(cfg, params, num_slots=4, max_seq=512, plan=plan)

    rng = np.random.default_rng(0)
    requests = [
        (rng.integers(1, cfg.vocab_size,
                      size=int(rng.integers(8, 120))).astype(np.int32),
         SamplingParams(max_new_tokens=16,
                        temperature=0.8 if i % 2 else 0.0,
                        top_k=20, top_p=0.95, seed=i))
        for i in range(12)
    ]
    t0 = time.perf_counter()
    out = eng.run(requests)
    dt = time.perf_counter() - t0
    tok = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {eng.ticks} decode ticks, "
          f"{eng.num_slots} slots)")
    for rid in sorted(out)[:5]:
        print(f"  req {rid:>2}: {out[rid]} [{eng.finish_reason(rid)}]")


if __name__ == "__main__":
    main()
