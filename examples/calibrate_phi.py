"""φ calibration workflow (paper Fig. 5): collect attention-logit
statistics over calibration batches, derive the unified max value + safe
band, and show the OPT-style disable path for wide-ranged models.

    PYTHONPATH=src python examples/calibrate_phi.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import phi as phi_mod
from repro.models import layers as L
from repro.models.api import get_model, make_synthetic_batch
from repro.models.layers import LayerCtx
from repro.config import ShapeConfig


def main():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    ctx = LayerCtx(cfg=cfg)
    params = api.init_params(jax.random.PRNGKey(0))

    # run a few calibration batches through layer-0 QK to collect stats
    stats = phi_mod.LogitStats()
    for i in range(4):
        batch = make_synthetic_batch(
            cfg, ShapeConfig("cal", 128, 2, "train"), jax.random.PRNGKey(i))
        x = L.embed(ctx, params, batch["tokens"])
        p0 = jax.tree.map(lambda a: a[0], params["layers"])
        h = L.norm(cfg, p0["attn_norm"], x)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        q, k, _ = L.attention_qkv(ctx, p0["attn"], h, positions)
        stats = phi_mod.collect_attention_logit_stats(q, k, stats=stats)

    print(f"logit stats over {stats.count} samples: "
          f"mean={stats.mean:+.3f} std={stats.std:.3f} "
          f"range=[{stats.minimum:+.2f}, {stats.maximum:+.2f}]")
    cal = phi_mod.calibrate(stats)
    print(f"calibrated: phi={cal.phi:+.3f} band=({cal.band[0]:+.1f}, "
          f"{cal.band[1]:+.1f}) active={cal.active}")

    # wire it into the model config — every attention op now runs async
    cfg_t1 = dataclasses.replace(cfg, softmax_phi=cal)
    print(f"model '{cfg_t1.name}' now runs T1 with phi={cal.phi:+.3f}")

    # the OPT case: a model whose logits are too wide -> T1 disabled
    wide = phi_mod.LogitStats().update(jnp.asarray([-400.0, 0.0, 390.0]))
    opt_cal = phi_mod.calibrate(wide)
    print(f"wide-range model (OPT case): active={opt_cal.active} "
          "-> engine uses the synchronized scheme everywhere")


if __name__ == "__main__":
    main()
