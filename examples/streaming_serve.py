"""Streaming generation + mid-flight abort through the Engine's new
surface: ``generate()`` yields one ``TokenEvent`` per emitted token (the
engine keeps continuous-batching every other resident request between
yields), and ``abort(rid)`` cancels a request in any phase, releasing its
slot and pages immediately.

    PYTHONPATH=src python examples/streaming_serve.py
"""
import jax
import numpy as np

from repro import configs
from repro.models.api import get_model
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams


def main():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, num_slots=2, max_seq=256,
                 cache_kind="paged", page_size=32, scheduler="fcfs")

    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)

    # a background request sharing the batch with the streamed one
    victim = eng.submit(
        rng.integers(1, cfg.vocab_size, size=40).astype(np.int32),
        SamplingParams(max_new_tokens=64))

    print("streaming request (greedy, 12 tokens):")
    for ev in eng.generate(prompt, SamplingParams(max_new_tokens=12)):
        print(f"  token[{ev.index}] = {ev.token}"
              + (f"  <{ev.finish_reason}>" if ev.finished else ""))
        if ev.index == 5:
            # cancel the background request mid-decode: its slot and pages
            # free instantly; the stream below continues unaffected
            assert eng.abort(victim)
            print(f"  (aborted background request {victim}: "
                  f"{eng.finish_reason(victim)})")

    bg = eng.requests[victim]
    print(f"background request generated {bg.generated} tokens before "
          f"abort; stats: {eng.stats}")


if __name__ == "__main__":
    main()
