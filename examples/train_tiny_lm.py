"""End-to-end driver: train a small (~6M-param) qwen2-family model for a few
hundred steps on CPU with the full production substrate — deterministic
data pipeline, AdamW, async checkpoints, preemption-safe loop.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]

Interrupt it (Ctrl-C) and run again: it resumes from the last checkpoint
and the loss curve continues exactly where it left off.
"""
import argparse
import dataclasses
import tempfile

import jax

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.models.api import get_model
from repro.models.layers import LayerCtx
from repro.training.loop import train_loop
from repro.training.train_state import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    # ~25M params: deepen the smoke config a bit for a real-ish curve
    cfg = dataclasses.replace(
        configs.smoke(configs.get("qwen2-0.5b")),
        num_layers=4, d_model=256, d_ff=1024, vocab_size=8192,
        num_heads=8, num_kv_heads=2, head_dim=32,
    )
    print(f"model: {cfg.name}  params ~{cfg.param_count()/1e6:.1f}M")
    shape = ShapeConfig("tiny_train", seq_len=256, global_batch=8,
                        kind="train")
    run = RunConfig(
        learning_rate=3e-3, warmup_steps=20, total_steps=args.steps,
        checkpoint_every=100,
        checkpoint_dir=args.ckpt or tempfile.mkdtemp(prefix="repro_ex_"),
    )
    api = get_model(cfg)
    ctx = LayerCtx(cfg=cfg)
    step = jax.jit(make_train_step(api, ctx, run), donate_argnums=(0,))

    res = train_loop(
        model_cfg=cfg, shape=shape, run=run, train_step=step,
        init_state=lambda: TrainState.create(
            api.init_params(jax.random.PRNGKey(0))),
        log_every=25,
    )
    print(f"\nloss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
          f"{len(res.losses)} steps "
          f"(resumed from {res.restored_from})")
    assert res.losses[-1] < res.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
