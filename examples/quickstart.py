"""Quickstart: the paper's three techniques in ~60 lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import SoftmaxPhiConfig
from repro.core import plan as plan_mod
from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# T1 — asynchronized softmax with a unified max value
# ---------------------------------------------------------------------------
print("== T1: unified-max decode attention ==")
b, hq, hk, d, s = 2, 8, 2, 64, 512
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
k_cache = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
v_cache = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
lengths = jnp.array([300, 512], jnp.int32)

phi_cfg = SoftmaxPhiConfig(phi=0.0, band=(-40.0, 40.0))   # calibrated φ
out = ops.attention_decode(q, k_cache, v_cache, lengths, phi_cfg=phi_cfg)
want = ref.attention_decode_ref(q, k_cache, v_cache, lengths)
np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
print(f"   async == sync result, max |Δ| = "
      f"{float(jnp.max(jnp.abs(out - want))):.2e}")

# ---------------------------------------------------------------------------
# T2 — flat GEMM with minimal M-padding (the Pallas kernel, interpret mode)
# ---------------------------------------------------------------------------
print("== T2: minimal-pad flat GEMM ==")
from repro.kernels.flat_gemm import flat_gemm
x = jax.random.normal(ks[0], (3, 512), jnp.float32)     # M=3 -> padded to 8
w = jax.random.normal(ks[1], (512, 1024), jnp.float32)
y = flat_gemm(x, w, interpret=True)
np.testing.assert_allclose(y, x @ w, rtol=1e-4, atol=1e-4)
print(f"   (3, 512) @ (512, 1024) via M_pad=8 tile: OK, out {y.shape}")

# ---------------------------------------------------------------------------
# T3 — heuristic dataflow: offline table, runtime lookup
# ---------------------------------------------------------------------------
print("== T3: tuned execution plan (llama2-7b) ==")
plan = plan_mod.tune(configs.get("llama2-7b"))
for (kk, nn), e in sorted(plan.matmul.entries.items()):
    print(f"   [K={kk:>6}, N={nn:>6}]  M1={e.m1:<4} M2={e.m2:<4} "
          f"(M<M1: VPU-GEMV, M<M2: flat-GEMM, else XLA dot)")
m = 4
impl = plan.matmul.pick(m, 4096, 12288)
print(f"   decode batch {m} routes QKV-proj to {impl.value}")
print(f"   plan: {plan.describe()}")
print(f"   round-trips: "
      f"{plan_mod.ExecutionPlan.from_json(plan.to_json()) == plan}")
