"""Property harness for the softmax-merge algebra (repro.kernels.merge).

Every attention kernel in the tree — decode, chunked prefill, grouped
prefix-shared decode — splits the KV sequence and combines partials
through the helpers in :mod:`repro.kernels.merge`. These properties pin
the algebra those kernels rely on:

  * **split equivalence** — folding any 2-way split of the KV axis and
    merging equals the unsplit softmax-attention, for both the
    unified-max (φ) scheme and the online-max / LSE scheme;
  * **order invariance** — merging 3+ unified-max partials is
    permutation- and association-insensitive (the paper's §3 claim: with
    a static φ the combine is pure addition);
  * **overflow detection** — whenever ``max(s − φ)`` leaves the φ band,
    the unified-max stat reports it (``msc`` is exact), so the wrapper's
    ``lax.cond`` recompute can never miss an overflow; inside the band
    the unified output itself matches the stable reference.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.config import SoftmaxPhiConfig
from repro.kernels import merge

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

R, D = 4, 8      # rows x value dim — small, the algebra is dim-blind


def _case(seed, kv_len, spread=1.0):
    """Random (centered logits, values, validity) for one property draw."""
    rng = np.random.default_rng(seed)
    s = (rng.standard_normal((R, kv_len)) * spread).astype(np.float32)
    v = rng.standard_normal((kv_len, D)).astype(np.float32)
    # at least one valid position per row keeps the reference well-defined
    valid = rng.random((R, kv_len)) < 0.8
    valid[:, 0] = True
    return s, v, valid


def _softmax_attention(s, v, valid):
    """Unsplit stable reference in float64."""
    s = np.where(valid, s.astype(np.float64), -np.inf)
    m = s.max(axis=1, keepdims=True)
    e = np.exp(s - m)
    return (e @ v.astype(np.float64)) / e.sum(axis=1, keepdims=True)


def _unified_partial(s, v, valid, phi):
    acc = np.zeros((R, D), np.float32)
    den = np.zeros((R, 1), np.float32)
    acc, den, msc = merge.unified_accumulate(
        acc, den, np.float32(-np.inf), s - phi, v, valid)
    return np.asarray(acc), np.asarray(den), np.asarray(msc)


@given(st.integers(0, 10_000), st.integers(1, 31))
def test_unified_split_equivalence(seed, split):
    """Unified-max: fold [0, t) and [t, S) separately, merge, finalize —
    equals the unsplit softmax-attention at any split point t."""
    s, v, valid = _case(seed, kv_len=32)
    phi = 0.0
    p1 = _unified_partial(s[:, :split], v[:split], valid[:, :split], phi)
    p2 = _unified_partial(s[:, split:], v[split:], valid[:, split:], phi)
    num, den, msc = merge.merge_unified(p1, p2)
    out = np.asarray(merge.finalize(num, den))
    np.testing.assert_allclose(
        out, _softmax_attention(s, v, valid), rtol=1e-4, atol=1e-5)
    assert np.asarray(msc) == np.where(valid, s, -np.inf).max() - phi


@given(st.integers(0, 10_000), st.integers(1, 15), st.integers(16, 31))
def test_sync_split_equivalence(seed, t1, t2):
    """Online-max/LSE: two independently max-stabilized partials merged
    via merge_lse equal the unsplit softmax-attention (any 3 segments:
    [0,t1) folded onto [t1,t2), then LSE-merged with [t2,S))."""
    s, v, valid = _case(seed, kv_len=32)
    sm = np.where(valid, s, -np.inf).astype(np.float32)

    def sync_fold(lo, hi):
        acc = np.zeros((R, D), np.float32)
        den = np.zeros((R, 1), np.float32)
        m = np.full((R, 1), -np.inf, np.float32)
        acc, den, m = merge.sync_accumulate(
            acc, den, m, sm[:, lo:hi], v[lo:hi], valid=valid[:, lo:hi])
        return acc, den, m

    # sequential accumulate across the first two segments = one partial
    acc, den, m = sync_fold(0, t1)
    acc, den, m = merge.sync_accumulate(
        acc, den, m, sm[:, t1:t2], v[t1:t2], valid=valid[:, t1:t2])
    a, d, mm = merge.merge_lse((acc, den, m), sync_fold(t2, 32))
    out = np.asarray(merge.finalize(a, d, guard_zero=True))
    np.testing.assert_allclose(
        out, _softmax_attention(s, v, valid), rtol=1e-4, atol=1e-5)


@given(st.integers(0, 10_000),
       st.lists(st.integers(0, 5), min_size=4, max_size=4))
def test_unified_merge_order_invariance(seed, perm_draw):
    """Unified-max partials merge by addition: any permutation and any
    association of 4 segment partials agrees (up to fp addition
    reordering) — and the msc stat is exactly permutation-invariant."""
    s, v, valid = _case(seed, kv_len=32)
    cuts = [0, 8, 16, 24, 32]
    parts = [
        _unified_partial(s[:, a:b], v[a:b], valid[:, a:b], phi=0.0)
        for a, b in zip(cuts, cuts[1:])
    ]
    order = np.argsort(np.asarray(perm_draw), kind="stable")

    def chain(ps):
        acc = ps[0]
        for p in ps[1:]:
            acc = merge.merge_unified(acc, p)
        return acc

    base = chain(parts)
    shuffled = chain([parts[i] for i in order])
    # tree association vs left fold
    tree = merge.merge_unified(
        merge.merge_unified(parts[0], parts[1]),
        merge.merge_unified(parts[2], parts[3]))
    for other in (shuffled, tree):
        np.testing.assert_allclose(
            np.asarray(merge.finalize(base[0], base[1])),
            np.asarray(merge.finalize(other[0], other[1])),
            rtol=1e-5, atol=1e-6)
        assert np.asarray(base[2]) == np.asarray(other[2])  # max: exact


@given(st.integers(0, 10_000), st.floats(2.0, 8.0))
def test_unified_overflow_stat_detects_band_exit(seed, boost):
    """The fallback contract: scale logits until max(s − φ) exceeds the
    calibrated band's upper edge — the merged msc stat must report it
    exactly (it is a running max, not an estimate), because the wrapper's
    recompute cond fires on ``any(stat > band[1])``. Inside the band the
    unified output must already match the stable reference."""
    phi_cfg = SoftmaxPhiConfig()
    hi = phi_cfg.band[1]
    s, v, valid = _case(seed, kv_len=32)
    for scale in (1.0, float(boost) * hi):    # in-band, out-of-band
        sb = (s * scale).astype(np.float32)
        p1 = _unified_partial(sb[:, :16], v[:16], valid[:, :16], phi_cfg.phi)
        p2 = _unified_partial(sb[:, 16:], v[16:], valid[:, 16:], phi_cfg.phi)
        num, den, msc = merge.merge_unified(p1, p2)
        true_max = np.where(valid, sb, -np.inf).max() - phi_cfg.phi
        assert np.asarray(msc) == np.float32(true_max)
        if true_max <= hi:
            out = np.asarray(merge.finalize(num, den))
            np.testing.assert_allclose(
                out, _softmax_attention(sb, v, valid), rtol=2e-4, atol=1e-5)
        else:
            # the stat crossing the band is exactly the recompute trigger
            assert np.asarray(msc) > hi


def test_finalize_guard_zero_only_touches_empty_rows():
    """guard_zero substitutes den=1 for fully-masked rows (callers drop
    them) and must not perturb any live row."""
    acc = np.arange(R * D, dtype=np.float32).reshape(R, D)
    den = np.array([[2.0], [0.0], [1.0], [0.0]], np.float32)
    out = np.asarray(merge.finalize(acc, den, guard_zero=True))
    live = np.asarray(merge.finalize(acc[::2], den[::2]))
    np.testing.assert_array_equal(out[::2], live)
    np.testing.assert_array_equal(out[1::2], acc[1::2])   # den treated as 1


def test_unified_accumulate_matches_sync_in_band():
    """Cross-scheme agreement on benign logits: the unified-max fold and
    the online-max fold of the same piece agree after finalize."""
    s, v, valid = _case(0, kv_len=32)
    num, den, _ = _unified_partial(s, v, valid, phi=0.0)
    uni = np.asarray(merge.finalize(num, den))
    acc = np.zeros((R, D), np.float32)
    d = np.zeros((R, 1), np.float32)
    m = np.full((R, 1), -np.inf, np.float32)
    sm = np.where(valid, s, -np.inf).astype(np.float32)
    acc, d, m = merge.sync_accumulate(acc, d, m, sm, v, valid=valid)
    syn = np.asarray(merge.finalize(acc, d))
    np.testing.assert_allclose(uni, syn, rtol=2e-4, atol=1e-5)
