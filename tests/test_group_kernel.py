"""Prefix-shared grouped decode attention: kernel-vs-oracle sweeps
(interpret mode) over group-of-1 / zero tails / partial prefix pages /
mixed group sizes / GQA regrouping, the reconstructed-gather bitwise
identity the XLA grouped path rests on, the group-plan knobs and cost
model, the slot manager's per-tick group plan (cache discipline, COW
fork eviction), and the engine-level greedy bit-identity guard across
{grouped, ungrouped} x {sharing on/off} including the COW-fork and
preemption paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TOL
from repro import configs
from repro.core import dispatch as dsp
from repro.core.plan import PagedPlan, PlanError, make_plan, tune
from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention_unified_max
from repro.kernels.group_attention import (
    DecodeGroups,
    grouped_paged_decode_attention_unified_max,
)
from repro.models.api import get_model
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.prefix import PrefixIndex, shared_prefix_groups
from repro.serving.request import SamplingParams


def _mk_groups(b, num_pages, specs, num_slots_pad=None):
    """Build a DecodeGroups pytree from ``specs`` =
    [(prefix_pages, prefix_len, member_rows)], padding NG/LP/M to pow2
    exactly like the slot manager does."""
    def pow2(n):
        p = 1
        while p < n:
            p *= 2
        return p

    ng = pow2(len(specs))
    lp = pow2(max(len(pg) for pg, _, _ in specs))
    m = pow2(max(len(ms) for _, _, ms in specs))
    tables = np.full((ng, lp), num_pages, np.int32)
    n_pages = np.zeros(ng, np.int32)
    g_plen = np.zeros(ng, np.int32)
    num_members = np.zeros(ng, np.int32)
    member_rows = np.full((ng, m), b, np.int32)
    gid = np.full(b, ng, np.int32)
    member = np.zeros(b, np.int32)
    prefix_len = np.zeros(b, np.int32)
    for g, (pages, plen, members) in enumerate(specs):
        tables[g, :len(pages)] = pages
        n_pages[g] = len(pages)
        g_plen[g] = plen
        num_members[g] = len(members)
        member_rows[g, :len(members)] = members
        for r, i in enumerate(members):
            gid[i], member[i], prefix_len[i] = g, r, plen
    return DecodeGroups(*(jnp.asarray(a) for a in (
        tables, n_pages, g_plen, num_members, member_rows,
        gid, member, prefix_len)))


def _fixture(dtype, *, b=6, hq=8, hk=2, d=64, ps=16, num_pages=32, nb=6,
             seed=0):
    """Pool + block tables with two shared prefixes: rows {0, 2, 4} share
    pages [3, 4]; rows {1, 5} share page [7]; row 3 is solo. Lengths
    exercise a zero private tail (row 4: length == prefix) and tails that
    end mid-page."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    bt = np.full((b, nb), num_pages, np.int32)
    bt[0, :4] = [3, 4, 10, 11]
    bt[1, :2] = [7, 12]
    bt[2, :3] = [3, 4, 13]
    bt[3, :2] = [15, 16]
    bt[4, :2] = [3, 4]
    bt[5, :3] = [7, 17, 18]
    lengths = np.array(
        [3 * ps + 5, ps + 3, 2 * ps + 7, ps + 9, 2 * ps, 2 * ps + 1],
        np.int32)
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths)


def _default_groups(num_pages, b=6, ps=16):
    return _mk_groups(b, num_pages, [
        ([3, 4], 2 * ps, [0, 2, 4]),
        ([7], ps, [1, 5]),
    ])


# ---------------------------------------------------------------------------
# Oracle: the reconstructed gather is bitwise-neutral
# ---------------------------------------------------------------------------


def test_gather_grouped_kv_is_bitwise_identical():
    """The grouped oracle's KV view — tail gather overwritten with the
    group-table gather over prefix positions — must be elementwise equal
    to the plain per-row gather: the group tables point at the *same
    physical pages* the rows' own tables lead with."""
    _, kp, vp, bt, _ = _fixture("float32")
    groups = _default_groups(kp.shape[0])
    for pool in (kp, vp):
        got = ref.gather_grouped_kv(pool, bt, groups)
        want = ref.gather_paged_kv(pool, bt)
        assert got.shape == want.shape
        assert bool(jnp.all(got == want))


def test_grouped_refs_bitwise_match_ungrouped_refs():
    """Both grouped oracles (sync and unified-max) run the identical
    dense math on the reconstructed view -> bitwise equal to the plain
    paged oracles. This is the XLA-backend grouped path's whole
    correctness argument."""
    q, kp, vp, bt, lengths = _fixture("float32")
    groups = _default_groups(kp.shape[0])
    out_g = ref.attention_decode_grouped_ref(q, kp, vp, bt, lengths, groups)
    out_p = ref.attention_decode_paged_ref(q, kp, vp, bt, lengths)
    assert bool(jnp.all(out_g == out_p))
    ou_g, st_g = ref.attention_decode_grouped_unified_max_ref(
        q, kp, vp, bt, lengths, groups, phi=0.0)
    ou_p, st_p = ref.attention_decode_paged_unified_max_ref(
        q, kp, vp, bt, lengths, phi=0.0)
    assert bool(jnp.all(ou_g == ou_p)) and bool(jnp.all(st_g == st_p))


# ---------------------------------------------------------------------------
# Kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
def test_grouped_kernel_matches_oracle_mixed_groups(dtype):
    """Mixed group sizes in one batch (3-way, 2-way, solo), GQA head
    regrouping (HQ=8 over HK=2), zero-length private tail (row 4)."""
    q, kp, vp, bt, lengths = _fixture(dtype)
    groups = _default_groups(kp.shape[0])
    out, stat = grouped_paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, groups, phi=0.0, interpret=True)
    want, _ = ref.attention_decode_grouped_unified_max_ref(
        q, kp, vp, bt, lengths, groups, phi=0.0)
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])
    assert stat.shape == (q.shape[0], kp.shape[2])


def test_grouped_kernel_page_aligned_is_bitwise_vs_ungrouped():
    """With page-aligned prefixes (the only shape the engine emits: a
    group key is whole shared pages) the two-stage kernel accumulates the
    same pages in the same order as the ungrouped kernel — the unified-max
    carry makes the split literally the same fp op sequence, so outputs
    are bitwise equal, not just close."""
    q, kp, vp, bt, lengths = _fixture("float32")
    groups = _default_groups(kp.shape[0])
    out, stat = grouped_paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, groups, phi=0.0, interpret=True)
    want, wstat = paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    assert bool(jnp.all(out == want))
    # per-row stats are group-broadcast, but the global overflow decision
    # (any(stat > band)) reduces over the same maxima
    assert float(jnp.max(stat)) == float(jnp.max(wstat))


def test_grouped_kernel_partial_last_prefix_page():
    """A prefix ending mid-page: stage 1 masks past the prefix inside the
    boundary page, stage 2 picks up the rest of that page from the row's
    own table."""
    q, kp, vp, bt, lengths = _fixture("float32", seed=2)
    ps = 16
    groups = _mk_groups(6, kp.shape[0], [
        ([3, 4], 2 * ps - 5, [0, 2]),        # boundary page split mid-page
    ])
    out, _ = grouped_paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, groups, phi=0.0, interpret=True)
    want, _ = ref.attention_decode_grouped_unified_max_ref(
        q, kp, vp, bt, lengths, groups, phi=0.0)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), **TOL["float32"])
    # the reconstructed view stays bitwise-neutral even mid-page
    assert bool(jnp.all(
        ref.gather_grouped_kv(kp, bt, groups) == ref.gather_paged_kv(kp, bt)))


def test_grouped_kernel_group_of_one():
    """A degenerate 1-member group (the manager never emits one, the
    kernel must still be exact): prefix computed 'once' for one row."""
    q, kp, vp, bt, lengths = _fixture("float32", seed=4)
    groups = _mk_groups(6, kp.shape[0], [([3, 4], 32, [0])])
    out, _ = grouped_paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, groups, phi=0.0, interpret=True)
    want, _ = paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    assert bool(jnp.all(out == want))


# ---------------------------------------------------------------------------
# Plan knobs + cost model
# ---------------------------------------------------------------------------


def test_paged_plan_group_knobs_validated():
    with pytest.raises(PlanError):
        PagedPlan(decode_group="bogus")
    with pytest.raises(PlanError):
        PagedPlan(group_threshold=0)
    assert PagedPlan().decode_group == "off"


def test_tuned_plan_carries_group_decision_and_roundtrips():
    from repro.core.plan import ExecutionPlan
    cfg = configs.get("qwen2-0.5b")
    p = tune(cfg)
    assert p.paged.decode_group == "grouped"
    assert p.paged.group_threshold >= 1
    assert ExecutionPlan.from_json(p.to_json()) == p
    assert "group>=" in p.describe()


def test_group_cost_model_grouped_wins_with_scale():
    """The decision flow's invariant: once members x prefix pages clears
    the tuned floor, the grouped path's predicted time stays below the
    per-row re-read's, and the gap grows with the dedup factor."""
    kv_dim = 128
    thr = dsp.find_group_threshold(kv_dim)
    assert thr >= 1
    t_off = dsp.predict_group_decode_time("off", 8, 16, 1, kv_dim)
    t_grp = dsp.predict_group_decode_time("grouped", 8, 16, 1, kv_dim)
    assert t_grp < t_off
    # dedup scales with members: doubling members at fixed prefix should
    # roughly double the grouped path's advantage on the prefix bytes
    gain2 = (dsp.predict_group_decode_time("off", 2, 16, 1, kv_dim)
             - dsp.predict_group_decode_time("grouped", 2, 16, 1, kv_dim))
    gain8 = t_off - t_grp
    assert gain8 > 2 * gain2
    with pytest.raises(ValueError):
        dsp.predict_group_decode_time("bogus", 2, 2, 1, kv_dim)


# ---------------------------------------------------------------------------
# Slot-manager group plan
# ---------------------------------------------------------------------------


def _mgr(num_pages=16, page_size=4, num_slots=3, max_seq=32):
    pool = BlockPool(num_pages, page_size)
    return PagedSlotManager(num_slots, max_seq, pool,
                            prefix_index=PrefixIndex(page_size)), pool


def test_shared_prefix_groups_keys_on_leading_refcounted_run():
    mgr, pool = _mgr()
    toks = np.arange(100, 109, dtype=np.int32)          # 2 full pages
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    groups = shared_prefix_groups(mgr.slots, pool.refcount)
    assert len(groups) == 1
    key, members = groups[0]
    assert sorted(members) == sorted([a, b])
    assert list(key) == mgr.slots[a].pages[:2] == mgr.slots[b].pages[:2]
    assert all(pool.refcount(p) == 2 for p in key)


def test_group_plan_builds_and_caches_until_tables_change():
    mgr, pool = _mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    plan = mgr.group_plan(threshold=2)
    assert plan is not None
    assert plan.n_grouped == 2 and plan.pages_deduped == 2
    np.testing.assert_array_equal(
        np.sort(plan.member_rows[0, :2]), np.sort([a, b]))
    assert plan.prefix_len[a] == plan.prefix_len[b] == 8
    # solo slot rows carry the solo sentinel gid == NG
    ng = plan.tables.shape[0]
    free_rows = [i for i in range(len(mgr.slots)) if i not in (a, b)]
    assert all(plan.gid[i] == ng for i in free_rows)
    # steady state: the identical plan object is reused...
    assert mgr.group_plan(threshold=2) is plan
    # ...until some table changes (growth past the admission reservation)
    mgr.ensure(a, 17)
    assert mgr.group_plan(threshold=2) is not plan
    # device operands cache on the plan and mirror the host arrays
    p2 = mgr.group_plan(threshold=2)
    ops = p2.operands()
    assert ops is p2.operands()
    np.testing.assert_array_equal(np.asarray(ops.gid), p2.gid)


def test_group_plan_threshold_and_fork_evict_members():
    mgr, pool = _mgr()
    toks = np.arange(50, 59, dtype=np.int32)
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)
    # 2 members x 2 pages = 4 units of deduped work
    assert mgr.group_plan(threshold=4) is not None
    assert mgr.group_plan(threshold=5) is None
    # a COW fork privatizes b's copy -> run shortens -> group dissolves
    forks = mgr.fork_for_write(b, 0, 9)
    assert forks
    assert mgr.group_plan(threshold=2) is None
    assert shared_prefix_groups(mgr.slots, pool.refcount) == []
    # release of the leader likewise invalidates the (empty) plan cleanly
    mgr.release(a)
    assert mgr.group_plan(threshold=2) is None
    mgr.check()


# ---------------------------------------------------------------------------
# Engine: greedy bit-identity across {grouped, ungrouped} x sharing
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, params


GROUPED = make_plan(decode_group="grouped", group_threshold=1)


def test_engine_identity_grouped_vs_ungrouped_vs_dense(smoke_model):
    """The acceptance bar: greedy tokens identical across the dense slot
    cache, paged without sharing, paged sharing ungrouped, and paged
    sharing with grouped decode — and the grouped run actually groups."""
    cfg, params = smoke_model
    rng = np.random.default_rng(29)
    header = rng.integers(1, cfg.vocab_size, size=48).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 23, 5)] + [
        rng.integers(1, cfg.vocab_size, size=10).astype(np.int32)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=5)) for p in prompts]

    kw = dict(num_slots=4, max_seq=128, prefill_chunk=16)
    grouped = Engine(cfg, params, cache_kind="paged", page_size=16,
                     prefix_sharing=True, plan=GROUPED, **kw)
    outs = {
        "dense": Engine(cfg, params, cache_kind="dense", **kw).run(reqs()),
        "paged": Engine(cfg, params, cache_kind="paged", page_size=16,
                        **kw).run(reqs()),
        "share": Engine(cfg, params, cache_kind="paged", page_size=16,
                        prefix_sharing=True, **kw).run(reqs()),
        "share+grouped": grouped.run(reqs()),
    }
    base = outs.pop("dense")
    for name, got in outs.items():
        assert got == base, f"{name} diverged from dense"
    assert grouped.stats.grouped_requests > 0, "grouped path never ran"
    assert grouped.stats.prefix_kv_bytes_saved > 0
    grouped.slots.check()


def test_engine_grouped_cow_fork_drops_member_and_matches(smoke_model):
    """COW fork of a group member mid-run: the fully-covered second
    request forks its tail page, whose refcount drop re-keys the group
    plan — outputs still bit-match the ungrouped sharing-off run, and the
    grouped stats only count surviving shared pages."""
    cfg, params = smoke_model
    rng = np.random.default_rng(31)
    prompt = rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
    outs = {}
    for name, (sharing, plan) in {
        "off": (False, None),
        "grouped": (True, GROUPED),
    }.items():
        eng = Engine(cfg, params, cache_kind="paged", num_slots=2,
                     max_seq=128, prefill_chunk=16, page_size=16,
                     prefix_sharing=sharing, plan=plan)
        ra = eng.submit(prompt, SamplingParams(max_new_tokens=8))
        eng.step()            # a prefills + commits, stays resident
        rb = eng.submit(prompt, SamplingParams(max_new_tokens=8))
        while not (eng.requests[ra].finished and eng.requests[rb].finished):
            eng.step()
        outs[name] = {r: eng.requests[r].tokens for r in (ra, rb)}
        if sharing:
            assert eng.stats.cow_forks == 1
            assert eng.stats.grouped_requests > 0
            eng.slots.check()
    assert outs["grouped"] == outs["off"]


def test_engine_grouped_survives_preemption(smoke_model):
    """Preemption under an overcommitted pool with grouped decode on:
    the victim's release re-keys the plan, re-admission re-maps and
    re-groups, outputs still bit-match an ungrouped non-sharing run."""
    cfg, params = smoke_model
    rng = np.random.default_rng(37)
    header = rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([
        header, rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)])
        for n in (9, 10)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=26)) for p in prompts]

    kw = dict(num_slots=2, max_seq=80, page_size=16, prefill_chunk=16,
              num_pages=5)
    grouped = Engine(cfg, params, cache_kind="paged", prefix_sharing=True,
                     plan=GROUPED, **kw)
    plain = Engine(cfg, params, cache_kind="paged", prefix_sharing=False,
                   **kw)
    out_g = grouped.run(reqs())
    out_p = plain.run(reqs())
    assert grouped.stats.preemptions > 0, "pool was never under pressure"
    assert out_g == out_p
    grouped.slots.check()
    assert grouped.pool.used_pages == 0


def test_group_bench_smoke(tmp_path, monkeypatch):
    """benchmarks.group_decode --quick asserts grouped/ungrouped identity
    and emits BENCH_group.json with ~Nx prefix-read dedup per N-way cell."""
    from benchmarks import group_decode
    monkeypatch.setattr(group_decode, "OUT_PATH",
                        str(tmp_path / "BENCH_group.json"))
    result = group_decode.run(quick=True)
    assert (tmp_path / "BENCH_group.quick.json").exists()
    assert not (tmp_path / "BENCH_group.json").exists()
    assert result["rows"]
    for row in result["rows"]:
        assert {"group_n", "prefix_len", "decode_tick_s_off",
                "decode_tick_s_on", "prefix_kv_read_off",
                "prefix_kv_read_on", "dedup_x", "bit_identical"} <= set(row)
        assert row["bit_identical"]
        assert row["dedup_x"] == pytest.approx(row["group_n"])
