"""ExecutionPlan tests: serialization identity, provenance staleness,
tuned-decision invariants, and the greedy-identity guard (plans choose
which kernel runs, never the tokens)."""
import dataclasses
import json

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs, hardware
from repro.core import dispatch as dsp
from repro.core import plan as plan_mod
from repro.core.plan import (
    DEFAULT_PLAN, ExecutionPlan, PlanError, StalePlanError, make_plan, tune,
)

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

CFG = configs.get("qwen2-0.5b")
TUNED = tune(CFG)


# ---------------------------------------------------------------------------
# Round-trip + staleness
# ---------------------------------------------------------------------------


def test_json_roundtrip_is_identity(tmp_path):
    assert ExecutionPlan.from_json(TUNED.to_json()) == TUNED
    path = TUNED.save(str(tmp_path / "p.json"))
    assert ExecutionPlan.load(path, cfg=CFG) == TUNED
    # the default artifact location is versioned per (arch, hardware)
    assert plan_mod.default_plan_path(CFG).endswith(
        f"{CFG.name}-{hardware.DEFAULT.name}.json")


def test_load_rejects_wrong_hardware(tmp_path):
    path = TUNED.save(str(tmp_path / "p.json"))
    other = dataclasses.replace(hardware.TPU_V5E, name="tpu-v9",
                                hbm_bw=5e12)
    with pytest.raises(StalePlanError, match="hardware"):
        ExecutionPlan.load(path, cfg=CFG, spec=other)


def test_load_rejects_wrong_config(tmp_path):
    path = TUNED.save(str(tmp_path / "p.json"))
    with pytest.raises(StalePlanError, match="config"):
        ExecutionPlan.load(path, cfg=configs.smoke(CFG))


def test_load_rejects_wrong_version(tmp_path):
    doc = json.loads(TUNED.to_json())
    doc["version"] = plan_mod.PLAN_VERSION + 1
    p = tmp_path / "p.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(StalePlanError, match="version"):
        ExecutionPlan.load(str(p), cfg=CFG)


def test_load_rejects_unprovenanced_unless_lax(tmp_path):
    path = make_plan().save(str(tmp_path / "hand.json"))
    with pytest.raises(StalePlanError, match="provenance"):
        ExecutionPlan.load(path, cfg=CFG)
    assert ExecutionPlan.load(path, strict=False) == make_plan()


def test_bad_knob_values_rejected():
    with pytest.raises(PlanError):
        plan_mod.AttentionDecodePlan(scheme="bogus")
    with pytest.raises(PlanError):
        plan_mod.MatmulPlan(backend="cuda")
    with pytest.raises(PlanError):
        plan_mod.AttentionDecodePlan(block_k=0)
    with pytest.raises(PlanError):
        plan_mod.AttentionPrefillPlan(chunk_threshold=-1)
    with pytest.raises(PlanError):
        ExecutionPlan.from_json("{not json")


def test_malformed_document_stays_inside_plan_error_contract():
    """Every malformed-document path — ops registry, knob values, and
    provenance — must surface as PlanError, never a raw TypeError."""
    doc = json.loads(TUNED.to_json())
    doc["ops"]["attention_decode"]["block_k"] = 0
    with pytest.raises(PlanError):
        ExecutionPlan.from_json(json.dumps(doc))
    doc = json.loads(TUNED.to_json())
    doc["provenance"] = {"backend": "xla", "hw": "typo"}
    with pytest.raises(PlanError, match="provenance"):
        ExecutionPlan.from_json(json.dumps(doc))


def test_with_overrides_maps_shared_knobs():
    p = TUNED.with_overrides(backend="xla", fallback=False, scheme="sync")
    assert p.attention_decode.fallback is False
    assert p.attention_prefill.scheme == "sync"
    assert p.paged.scheme == "sync"
    assert p.fused_ffn.fused is False          # pallas-only fusion dropped
    assert p.matmul.entries == TUNED.matmul.entries   # decisions survive
    # None keeps everything
    assert TUNED.with_overrides() == TUNED


# ---------------------------------------------------------------------------
# Tuned-decision invariants (hypothesis)
# ---------------------------------------------------------------------------

_ORDER = {dsp.Impl.GEMV: 0, dsp.Impl.FLAT_GEMM: 1, dsp.Impl.XLA_DOT: 2}
_KNS = sorted(TUNED.matmul.entries) + [(17, 23)]   # incl. an unseen shape


@given(st.integers(min_value=1, max_value=2047),
       st.integers(min_value=1, max_value=1024),
       st.sampled_from(_KNS))
def test_tuned_pick_piecewise_monotone_in_m(m, dm, kn):
    """Across the widened op space (every tuned [K, N] and the default
    policy) the decision is piecewise-monotone: growing M never routes
    *down* the ImplA -> ImplB -> ImplC ladder."""
    k, n = kn
    a = TUNED.matmul.pick(m, k, n)
    b = TUNED.matmul.pick(m + dm, k, n)
    assert _ORDER[a] <= _ORDER[b]


@given(st.sampled_from([64, 256, 1024, 4096, 32768]),
       st.integers(min_value=1, max_value=8))
def test_tuned_block_k_monotone_in_seq(s, mult):
    """Decode block_k decision is monotone in the representative KV
    length (the beyond-GEMM inflection analogue)."""
    bk1 = dsp.find_block_k(s, CFG.kv_dim)
    bk2 = dsp.find_block_k(s * mult, CFG.kv_dim)
    assert bk1 <= bk2


# ---------------------------------------------------------------------------
# Greedy-identity guard: plans pick kernels, not tokens
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    from repro.models.api import get_model
    cfg = configs.smoke(CFG)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_greedy_identity_across_plans(smoke_model, cache_kind):
    """Token-identical greedy outputs across plans for the same config:
    a plan may change which kernel runs (GEMM routing, block_k, the
    fallback cond, chunk threshold) but never the math. Scheme/backend
    swaps are excluded from the *bitwise* guard — sync vs. unified-max
    is value-close but not bitwise, and near-uniform random-init logits
    amplify fp ties into argmax flips — so scheme variants get their own
    check: ``test_scheme_swap_decode_logits_value_close`` below bounds
    the decode-logit deviation with an atol tied to the activation
    dtype's epsilon (not just "some other test somewhere")."""
    from repro.serving.engine import Engine
    from repro.serving.request import SamplingParams
    cfg, params = smoke_model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (11, 26)]
    sp = SamplingParams(max_new_tokens=5, temperature=0.0)
    plans = [
        None,                                   # untuned default
        tune(cfg),                              # tuned decisions
        make_plan(fallback=False, block_k=128, chunk_threshold=1024),
    ]
    outs = []
    for p in plans:
        eng = Engine(cfg, params, num_slots=2, max_seq=64,
                     cache_kind=cache_kind, page_size=16, plan=p)
        outs.append(eng.run([(pr, sp) for pr in prompts]))
    assert outs[0] == outs[1] == outs[2]


@pytest.mark.parametrize("cache_kind", ["dense", "paged"])
def test_scheme_swap_decode_logits_value_close(smoke_model, cache_kind):
    """The real check behind the identity guard's scheme exclusion:
    swapping the softmax scheme (sync <-> unified-max) may change the
    *rounding* of decode logits, never their value. Bounds the deviation
    on a warmed cache with an atol tied to the activation dtype — the
    unified-max rescale is one extra multiply per element, so the two
    schemes must agree to a small multiple of eps at logit scale."""
    import jax.numpy as jnp

    from repro.models.api import get_model
    from repro.models.kvlayout import DenseLayout, PagedLayout
    from repro.models.layers import LayerCtx
    from repro.serving.blockpool import BlockPool, PagedSlotManager

    cfg, params = smoke_model
    api = get_model(cfg)
    num_slots, max_seq, page_size = 4, 64, 16
    lengths = jnp.array([7, 33, 60, 13], jnp.int32)
    toks = jnp.array([3, 1, 4, 1], jnp.int32)

    if cache_kind == "dense":
        layout, bt = DenseLayout(num_slots, max_seq), None
    else:
        pool = BlockPool(num_slots * 4, page_size)
        mgr = PagedSlotManager(num_slots, max_seq, pool)
        for i, ln in enumerate(np.asarray(lengths)):
            assert mgr.try_assign(i, int(ln), 1) is not None
            assert mgr.ensure(i, int(ln) + 1)
        layout, bt = PagedLayout(pool.num_pages, page_size), \
            mgr.block_tables()
    # warm the cache with noise so attention reduces over real values
    cache = jax.tree.map(
        lambda c: c + 0.05 * jax.random.normal(
            jax.random.PRNGKey(9), c.shape, c.dtype),
        api.init_cache(layout))

    outs = {}
    for scheme in ("sync", "unified_max"):
        ctx = LayerCtx(cfg=cfg, plan=make_plan(scheme=scheme))
        logits, _ = api.decode_step(ctx, params, toks, cache, lengths,
                                    block_tables=bt)
        outs[scheme] = np.asarray(logits, np.float32)

    eps = float(jnp.finfo(jnp.dtype(cfg.activation_dtype)).eps)
    scale = float(np.abs(outs["sync"]).max())
    atol = 32 * eps * max(scale, 1.0)
    np.testing.assert_allclose(outs["unified_max"], outs["sync"],
                               rtol=32 * eps, atol=atol)


# ---------------------------------------------------------------------------
# Bench artifact smoke (fast lane)
# ---------------------------------------------------------------------------


def test_dispatch_bench_smoke(tmp_path, monkeypatch):
    """benchmarks.dispatch_table --quick tunes, round-trips, and emits a
    well-formed BENCH_dispatch.json."""
    from benchmarks import dispatch_table
    monkeypatch.setattr(dispatch_table, "OUT_PATH",
                        str(tmp_path / "BENCH_dispatch.json"))
    result = dispatch_table.run(quick=True)
    # quick mode lands in the .quick.json sidecar and never clobbers the
    # committed full-mode artifact
    assert (tmp_path / "BENCH_dispatch.quick.json").exists()
    assert not (tmp_path / "BENCH_dispatch.json").exists()
    assert result["mode"] == "quick"
    assert result["config"]["measure"] == "analytical"
    assert result["rows"], "inflection rows must be emitted"
    for row in result["rows"]:
        assert {"arch", "name", "k", "n", "m1", "m2"} <= set(row)
        assert row["m1"] <= row["m2"]
    assert "llama2-7b" in result["plans"]
