"""Block-paged KV cache: pool/free-list invariants (property-based via the
hypothesis shim), lazy growth, block-table consistency, and paged-vs-dense
engine equivalence through the unified KVLayout path — greedy outputs must
be token-identical, including runs where overcommit forces preemption and
re-admission recycles pages."""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout, PagedLayout, pages_for
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# KVLayout: the one shape/addressing descriptor both cache kinds share
# ---------------------------------------------------------------------------


def test_kvlayout_shapes_and_operands():
    dense = DenseLayout(num_slots=4, max_seq=256)
    paged = PagedLayout(num_pages=16, page_size=64)
    assert dense.kv_shape(2, 8, 64) == (2, 4, 256, 8, 64)
    assert paged.kv_shape(2, 8, 64) == (2, 16, 64, 8, 64)
    assert not dense.is_paged and paged.is_paged
    assert paged.pages_for(0) == 0
    assert paged.pages_for(64) == 1
    assert paged.pages_for(65) == 2
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    for layout in (dense, PagedLayout(8, 32)):
        spec = api.cache_spec(layout)
        cache = api.init_cache(layout)
        assert jax.tree.map(lambda s: s.shape, spec) == \
            jax.tree.map(lambda a: a.shape, cache)


def test_recurrent_family_rejects_paged_layout():
    cfg = configs.smoke(configs.get("rwkv6-1.6b"))
    api = get_model(cfg)
    assert not api.supports_paged
    with pytest.raises(ValueError):
        api.init_cache(PagedLayout(8, 32))


# ---------------------------------------------------------------------------
# BlockPool unit behavior
# ---------------------------------------------------------------------------


def test_blockpool_alloc_free_conservation():
    pool = BlockPool(num_pages=8, page_size=16)
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert pool.free_pages == 0 and pool.used_pages == 8
    assert set(a) | set(b) == set(range(8)) and not set(a) & set(b)
    assert pool.alloc(1) is None            # exhausted, not an exception
    pool.free(a)
    assert pool.free_pages == 3
    c = pool.alloc(2)
    assert set(c) <= set(a)                 # freed pages are reused
    pool.check()


def test_blockpool_double_free_raises():
    pool = BlockPool(num_pages=4, page_size=8)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free(a)
    with pytest.raises(ValueError):
        pool.free([99])                      # foreign page


def test_blockpool_share_and_refcounted_free():
    """A shared page is freed only when its LAST owner lets go: free()
    decrements, reports exactly the pages that died, and keeps shared
    pages allocated (no page freed while refcount > 0)."""
    pool = BlockPool(num_pages=4, page_size=8)
    a = pool.alloc(2)
    assert [pool.refcount(p) for p in a] == [1, 1]
    pool.share(a)                            # second owner
    assert [pool.refcount(p) for p in a] == [2, 2]
    assert pool.free(a) == []                # nobody died
    assert pool.used_pages == 2 and pool.free_pages == 2
    pool.check()
    dead = pool.free(a)                      # last owner
    assert sorted(dead) == sorted(a)
    assert pool.free_pages == 4
    with pytest.raises(ValueError):
        pool.free(a)                         # refcount 0 = foreign again
    with pytest.raises(ValueError):
        pool.share([a[0]])                   # cannot share a free page
    pool.check()


def test_blockpool_total_refs_counts_sharing():
    pool = BlockPool(num_pages=4, page_size=8)
    a = pool.alloc(3)
    pool.share(a[:2])
    # 3 physical pages stand in for 5 share-less allocations
    assert pool.used_pages == 3 and pool.total_refs == 5
    pool.check()


def test_blockpool_pages_for():
    pool = BlockPool(num_pages=4, page_size=16)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2


# ---------------------------------------------------------------------------
# PagedSlotManager: lazy admission + ensure()-growth; random lifecycles
# keep every cross-structure invariant (no double allocation, free-list
# conservation, block-table <-> pool consistency)
# ---------------------------------------------------------------------------


def test_lazy_admission_reserves_prefill_footprint_plus_headroom():
    pool = BlockPool(num_pages=8, page_size=8)
    mgr = PagedSlotManager(2, max_seq=64, pool=pool)
    idx = mgr.try_assign(0, prompt_len=20, max_new=30)
    assert idx is not None
    # prefill footprint (3 pages) + one decode growth page — NOT the
    # worst-case ceil(50/8) = 7
    assert pool.used_pages == pages_for(20, 8) + 1   # 4
    # growth is page-at-a-time through ensure()
    assert mgr.ensure(idx, 32)                        # inside headroom
    assert pool.used_pages == 4
    assert mgr.ensure(idx, 33)                        # crosses into page 5
    assert pool.used_pages == 5
    mgr.check()


def test_lazy_admission_headroom_capped_at_total_footprint():
    pool = BlockPool(num_pages=8, page_size=8)
    mgr = PagedSlotManager(2, max_seq=64, pool=pool)
    # prompt+max_new fits the prefill pages exactly: no headroom page
    idx = mgr.try_assign(0, prompt_len=14, max_new=2)   # 16 pos = 2 pages
    assert idx is not None
    assert pool.used_pages == 2
    mgr.check()


def test_ensure_reports_dry_pool_without_corrupting_state():
    pool = BlockPool(num_pages=4, page_size=8)
    mgr = PagedSlotManager(2, max_seq=32, pool=pool)
    a = mgr.try_assign(0, prompt_len=16, max_new=8)   # 2 + headroom = 3
    b = mgr.try_assign(1, prompt_len=4, max_new=1)    # 1 page (capped)
    assert a is not None and b is not None
    assert pool.free_pages == 0
    assert not mgr.ensure(a, 25)                      # pool dry
    mgr.check()                                       # nothing leaked
    mgr.release(b)                                    # preemption mechanics
    assert mgr.ensure(a, 25)                          # freed page picked up
    mgr.check()


@given(st.integers(0, 10_000))
def test_paged_manager_random_lifecycle(seed):
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([4, 8, 16]))
    num_pages = int(rng.integers(4, 40))
    num_slots = int(rng.integers(1, 6))
    max_seq = page_size * max(2, num_pages // max(num_slots, 1))
    pool = BlockPool(num_pages, page_size)
    mgr = PagedSlotManager(num_slots, max_seq, pool)
    live: list[int] = []
    rid = 0
    for _ in range(40):
        op = rng.random()
        if op < 0.4:
            prompt = int(rng.integers(1, max(max_seq // 2, 2)))
            max_new = int(rng.integers(1, max_seq - prompt + 1))
            if pages_for(prompt + max_new, page_size) > num_pages:
                continue                      # would raise by contract
            idx = mgr.try_assign(rid, prompt, max_new)
            if idx is not None:
                assert idx not in live, "slot double-assigned"
                live.append(idx)
                rid += 1
        elif op < 0.6 and live:
            idx = live[rng.integers(len(live))]
            # lazy growth to a random target; failure must be side-effect
            # free (the preempt-and-retry contract)
            mgr.ensure(idx, int(rng.integers(1, max_seq + 1)))
        elif op < 0.8 and live:
            idx = live[rng.integers(len(live))]
            mgr.tick(idx, wrote_kv=bool(rng.random() < 0.9))
        elif live:
            idx = live.pop(rng.integers(len(live)))
            mgr.release(idx)
        mgr.check()                          # invariants after every op
    for idx in live:
        mgr.release(idx)
    mgr.check()
    assert pool.free_pages == num_pages      # everything returned


def test_block_tables_sentinel_and_ownership():
    pool = BlockPool(num_pages=16, page_size=8)
    mgr = PagedSlotManager(3, max_seq=64, pool=pool)
    a = mgr.try_assign(0, prompt_len=20, max_new=4)   # 3 pages (capped)
    b = mgr.try_assign(1, prompt_len=5, max_new=3)    # 1 page (capped)
    assert a is not None and b is not None
    bt = np.asarray(mgr.block_tables())                # cached device array
    assert bt.shape == (3, 8)                          # 64 / 8 logical blocks
    pages_a = set(bt[a][bt[a] < pool.num_pages])
    pages_b = set(bt[b][bt[b] < pool.num_pages])
    assert len(pages_a) == 3 and len(pages_b) == 1
    assert not pages_a & pages_b                       # disjoint ownership
    # unassigned entries (and the whole free slot row) hold the sentinel
    free_row = ({0, 1, 2} - {a, b}).pop()
    assert (bt[free_row] == pool.num_pages).all()
    mgr.release(a)
    assert pool.free_pages == 16 - 1


def test_block_tables_cached_until_invalidated():
    """The dense block-table operand is device-cached: unchanged tables
    return the *same* array object tick after tick (so the jitted decode
    step reuses a device-resident operand instead of re-uploading), and
    every mutation path — lazy growth, release, fresh assignment —
    invalidates it."""
    pool = BlockPool(num_pages=16, page_size=8)
    mgr = PagedSlotManager(2, max_seq=64, pool=pool)
    a = mgr.try_assign(0, prompt_len=9, max_new=20)    # 2 pages + headroom
    bt0 = mgr.block_tables()
    assert mgr.block_tables() is bt0                   # steady state: cached
    mgr.tick(a)                                        # bookkeeping only
    assert mgr.block_tables() is bt0
    assert mgr.ensure(a, 24)                           # inside owned pages
    assert mgr.block_tables() is bt0                   # no table change
    assert mgr.ensure(a, 25)                           # grew one page
    bt1 = mgr.block_tables()
    assert bt1 is not bt0
    assert np.asarray(bt1)[a][3] < pool.num_pages      # new page visible
    mgr.release(a)
    assert mgr.block_tables() is not bt1               # release invalidates
    b = mgr.try_assign(1, prompt_len=5, max_new=3)
    assert b is not None
    assert (np.asarray(mgr.block_tables())[b] < pool.num_pages).sum() == 1


def test_paged_manager_rejects_request_larger_than_pool():
    """A request whose worst-case page footprint exceeds the whole
    (overcommitted) pool must raise at admission, not lazily admit — once
    it ran alone there would be no preemptable victim for its guaranteed
    mid-decode growth failure (livelock)."""
    mgr = PagedSlotManager(1, max_seq=512, pool=BlockPool(2, 64))
    with pytest.raises(ValueError):
        mgr.try_assign(0, prompt_len=200, max_new=100)  # needs 5 > 2 pages


def test_paged_manager_admission_blocks_on_pool_not_slots():
    # plenty of slots, tiny pool: admission must wait on pages
    pool = BlockPool(num_pages=2, page_size=8)
    mgr = PagedSlotManager(4, max_seq=16, pool=pool)
    assert mgr.try_assign(0, prompt_len=15, max_new=1) is not None  # 2 pages
    assert mgr.try_assign(1, prompt_len=1, max_new=1) is None       # no pages
    mgr.release(0)
    assert mgr.try_assign(1, prompt_len=1, max_new=1) is not None


# ---------------------------------------------------------------------------
# Engine equivalence through the unified KVLayout path: paged greedy decode
# is token-identical to dense — with page recycling AND forced preemption
# ---------------------------------------------------------------------------


def _engines(arch, *, page_size=32, num_pages=None, scheduler="fcfs", **kw):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    dense = Engine(cfg, params, cache_kind="dense", **kw)
    paged = Engine(cfg, params, cache_kind="paged", page_size=page_size,
                   num_pages=num_pages, scheduler=scheduler, **kw)
    return cfg, dense, paged


@pytest.mark.parametrize(
    "arch", ["qwen2-0.5b",
             pytest.param("dbrx-132b", marks=pytest.mark.slow)])
def test_paged_engine_token_identical_to_dense(arch):
    """Greedy outputs match across cache kinds, through a workload where
    5 requests share 2 slots — finished sequences release their pages and
    re-admitted requests recycle them mid-run."""
    cfg, dense, paged = _engines(arch, num_slots=2, max_seq=256,
                                 prefill_chunk=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 23, 70, 5)]

    def reqs():
        return [(p, SamplingParams(max_new_tokens=4)) for p in prompts]

    out_dense = dense.run(reqs())
    out_paged = paged.run(reqs())
    assert out_dense == out_paged
    # every page returned to the free list once the run drains
    assert paged.pool.used_pages == 0
    assert paged.pool.free_pages == paged.pool.num_pages


@pytest.mark.parametrize("scheduler", ["fcfs", "sjf", "pagefair"])
def test_overcommitted_paged_engine_preempts_and_matches_dense(scheduler):
    """The acceptance bar: an overcommitted pool (too small for the
    resident batch's total footprint) forces mid-decode preemption —
    pages freed, state re-queued, re-prefilled — and the greedy output
    still matches an un-preempted dense run exactly, under every
    scheduler policy."""
    cfg, dense, paged = _engines(
        "qwen2-0.5b", num_slots=2, max_seq=64, prefill_chunk=16,
        page_size=16, num_pages=4, scheduler=scheduler)
    rng = np.random.default_rng(3)
    # admission footprints (prefill + headroom) are 2 pages each = the
    # whole pool under any admission order; both sequences then need a
    # third page past position 32 mid-decode, so every policy must
    # preempt at least once
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 10)]
    reqs = [(p, SamplingParams(max_new_tokens=26)) for p in prompts]
    out_dense = dense.run(list(reqs))
    out_paged = paged.run(list(reqs))
    assert paged.stats.preemptions > 0, "pool was never under pressure"
    assert out_dense == out_paged
    assert any(paged.requests[r].preemptions > 0 for r in out_paged)
    assert paged.pool.used_pages == 0          # lazy growth leaked nothing
    assert paged.stats.peak_pages_used <= paged.pool.num_pages


def test_paged_engine_page_recycling_visible():
    """With a pool sized for ~one request, back-to-back requests must reuse
    the same physical pages (recycle through the free list) and still match
    the dense engine."""
    cfg, dense, paged = _engines(
        "qwen2-0.5b", num_slots=1, max_seq=64, prefill_chunk=16,
        page_size=16)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
               for _ in range(2)]
    pages_used = []
    outs = {}
    for i, p in enumerate(prompts):
        rid = paged.submit(p, SamplingParams(max_new_tokens=3))
        paged.step()                       # admit + prefill + first tick
        pages_used.append(tuple(paged.slots.slots[0].pages))
        while not paged.requests[rid].finished:
            paged.step()
        outs[rid] = paged.requests[rid].tokens
    out_dense = dense.run([(p, SamplingParams(max_new_tokens=3))
                           for p in prompts])
    assert outs == out_dense
    assert set(pages_used[1]) & set(pages_used[0]), \
        "request 1 did not recycle request 0's freed pages"


def test_paged_engine_rejects_recurrent_families():
    cfg = configs.smoke(configs.get("rwkv6-1.6b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        Engine(cfg, params, cache_kind="paged")
