"""Block-paged KV cache: pool/free-list invariants (property-based via the
hypothesis shim), block-table consistency, and paged-vs-dense engine
equivalence — greedy outputs must be token-identical, including runs where
slot release + re-admission recycles pages."""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models.api import get_model
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine, Request

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# BlockPool unit behavior
# ---------------------------------------------------------------------------


def test_blockpool_alloc_free_conservation():
    pool = BlockPool(num_pages=8, page_size=16)
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert pool.free_pages == 0 and pool.used_pages == 8
    assert set(a) | set(b) == set(range(8)) and not set(a) & set(b)
    assert pool.alloc(1) is None            # exhausted, not an exception
    pool.free(a)
    assert pool.free_pages == 3
    c = pool.alloc(2)
    assert set(c) <= set(a)                 # freed pages are reused
    pool.check()


def test_blockpool_double_free_raises():
    pool = BlockPool(num_pages=4, page_size=8)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(ValueError):
        pool.free(a)
    with pytest.raises(ValueError):
        pool.free([99])                      # foreign page


def test_blockpool_pages_for():
    pool = BlockPool(num_pages=4, page_size=16)
    assert pool.pages_for(0) == 0
    assert pool.pages_for(1) == 1
    assert pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2


# ---------------------------------------------------------------------------
# PagedSlotManager: random admit/tick/release lifecycles keep every
# cross-structure invariant (no double allocation, free-list conservation,
# block-table <-> pool consistency)
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
def test_paged_manager_random_lifecycle(seed):
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([4, 8, 16]))
    num_pages = int(rng.integers(4, 40))
    num_slots = int(rng.integers(1, 6))
    max_seq = page_size * max(2, num_pages // max(num_slots, 1))
    pool = BlockPool(num_pages, page_size)
    mgr = PagedSlotManager(num_slots, max_seq, pool)
    live: list[int] = []
    rid = 0
    for _ in range(40):
        op = rng.random()
        if op < 0.5:
            prompt = int(rng.integers(1, max(max_seq // 2, 2)))
            max_new = int(rng.integers(1, max_seq - prompt + 1))
            idx = mgr.try_assign(rid, prompt, max_new)
            if idx is not None:
                assert idx not in live, "slot double-assigned"
                live.append(idx)
                rid += 1
        elif op < 0.8 and live:
            idx = live[rng.integers(len(live))]
            mgr.tick(idx, wrote_kv=bool(rng.random() < 0.9))
        elif live:
            idx = live.pop(rng.integers(len(live)))
            mgr.release(idx)
        mgr.check()                          # invariants after every op
    for idx in live:
        mgr.release(idx)
    mgr.check()
    assert pool.free_pages == num_pages      # everything returned


def test_block_tables_sentinel_and_ownership():
    pool = BlockPool(num_pages=16, page_size=8)
    mgr = PagedSlotManager(3, max_seq=64, pool=pool)
    a = mgr.try_assign(0, prompt_len=20, max_new=4)   # 3 pages
    b = mgr.try_assign(1, prompt_len=5, max_new=3)    # 1 page
    assert a is not None and b is not None
    bt = mgr.block_tables()
    assert bt.shape == (3, 8)                          # 64 / 8 logical blocks
    pages_a = set(bt[a][bt[a] < pool.num_pages])
    pages_b = set(bt[b][bt[b] < pool.num_pages])
    assert len(pages_a) == 3 and len(pages_b) == 1
    assert not pages_a & pages_b                       # disjoint ownership
    # unassigned entries (and the whole free slot row) hold the sentinel
    free_row = ({0, 1, 2} - {a, b}).pop()
    assert (bt[free_row] == pool.num_pages).all()
    mgr.release(a)
    assert pool.free_pages == 16 - 1


def test_paged_manager_rejects_oversized_request():
    mgr = PagedSlotManager(1, max_seq=32, pool=BlockPool(8, 8))
    with pytest.raises(ValueError):
        mgr.try_assign(0, prompt_len=30, max_new=8)


def test_paged_manager_rejects_request_larger_than_pool():
    """A request whose page footprint exceeds the whole (overcommitted)
    pool must raise, not return None — None would make the engine's
    admission loop retry forever (livelock, ticks never advance)."""
    mgr = PagedSlotManager(1, max_seq=512, pool=BlockPool(2, 64))
    with pytest.raises(ValueError):
        mgr.try_assign(0, prompt_len=200, max_new=100)  # needs 5 > 2 pages


def test_paged_manager_admission_blocks_on_pool_not_slots():
    # plenty of slots, tiny pool: admission must wait on pages
    pool = BlockPool(num_pages=2, page_size=8)
    mgr = PagedSlotManager(4, max_seq=32, pool=pool)
    assert mgr.try_assign(0, prompt_len=8, max_new=8) is not None  # 2 pages
    assert mgr.try_assign(1, prompt_len=1, max_new=1) is None      # no pages
    mgr.release(0)
    assert mgr.try_assign(1, prompt_len=1, max_new=1) is not None


# ---------------------------------------------------------------------------
# Engine equivalence: paged greedy decode is token-identical to dense
# ---------------------------------------------------------------------------


def _engines(arch, **kw):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    dense = Engine(cfg, params, cache_kind="dense", **kw)
    paged = Engine(cfg, params, cache_kind="paged", page_size=32, **kw)
    return cfg, dense, paged


@pytest.mark.parametrize(
    "arch", ["qwen2-0.5b",
             pytest.param("dbrx-132b", marks=pytest.mark.slow)])
def test_paged_engine_token_identical_to_dense(arch):
    """Greedy outputs match bitwise across cache kinds, through a workload
    where 5 requests share 2 slots — finished sequences release their pages
    and re-admitted requests recycle them mid-run."""
    cfg, dense, paged = _engines(arch, num_slots=2, max_seq=256,
                                 prefill_chunk=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 23, 70, 5)]

    def reqs():
        return [Request(id=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]

    out_dense = dense.run(reqs())
    out_paged = paged.run(reqs())
    assert out_dense == out_paged
    # every page returned to the free list once the run drains
    assert paged.pool.used_pages == 0
    assert paged.pool.free_pages == paged.pool.num_pages


def test_paged_engine_page_recycling_visible():
    """With a pool sized for ~one request, back-to-back requests must reuse
    the same physical pages (recycle through the free list) and still match
    the dense engine."""
    cfg, dense, paged = _engines(
        "qwen2-0.5b", num_slots=1, max_seq=64, prefill_chunk=16)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
               for _ in range(2)]
    pages_used = []
    outs = {}
    for i, p in enumerate(prompts):
        paged.submit(Request(id=i, prompt=p, max_new_tokens=3))
        paged.step()                       # admit + prefill + first tick
        pages_used.append(tuple(paged.slots.slots[0].pages))
        while paged.queue or paged.by_slot:
            paged.step()
        outs[i] = paged.results[i].tokens
    out_dense = dense.run([Request(id=i, prompt=p, max_new_tokens=3)
                           for i, p in enumerate(prompts)])
    assert outs == out_dense
    assert set(pages_used[1]) & set(pages_used[0]), \
        "request 1 did not recycle request 0's freed pages"


def test_paged_engine_rejects_recurrent_families():
    cfg = configs.smoke(configs.get("rwkv6-1.6b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        Engine(cfg, params, cache_kind="paged")
