"""Per-arch smoke + family-specific correctness.

Each assigned architecture instantiates its REDUCED same-family config and
runs one forward/train step + one decode step on CPU, asserting output
shapes and finiteness (the assignment's smoke contract). Family math gets
deeper checks: RWKV chunked-vs-step equivalence, hybrid SSD chunk-vs-step,
MoE routing invariants, decode-vs-prefill consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ShapeConfig
from repro.models.api import get_model, make_synthetic_batch
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx

TINY = ShapeConfig("tiny", 64, 2, "train")


def _ctx(cfg):
    return LayerCtx(cfg=cfg)


def _zoo(archs, keep):
    """Keep `keep` archs in the default tier-1 lane; the rest of the model
    zoo runs under ``-m slow`` (the default lane must stay under ~2 min)."""
    return [a if a in keep else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


@pytest.mark.parametrize("arch", _zoo(configs.ASSIGNED, ("qwen2-0.5b",)))
def test_arch_smoke_train_step(arch):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    ctx = _ctx(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    batch = make_synthetic_batch(cfg, TINY, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(ctx, p, batch))(params)
    assert loss.shape == () and bool(jnp.isfinite(loss))
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))), path


@pytest.mark.parametrize("arch", _zoo(configs.ASSIGNED, ("qwen2-0.5b",)))
def test_arch_smoke_decode_step(arch):
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    ctx = _ctx(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    cache = api.init_cache(DenseLayout(2, 128))
    logits, new_cache = api.decode_step(
        ctx, params, jnp.array([3, 5], jnp.int32), cache,
        jnp.array([4, 9], jnp.int32))
    assert logits.shape[0] == 2 and logits.shape[1] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize(
    "arch", _zoo(["qwen2-0.5b", "hymba-1.5b", "rwkv6-1.6b",
                  "whisper-tiny", "grok-1-314b"], ()))
def test_decode_matches_prefill(arch):
    """Greedy tokens from incremental decode == teacher-forced prefill.

    Prefill(prompt) then k decode steps must produce the same next-token
    argmax as prefilling (prompt + generated prefix) from scratch — the KV
    cache/recurrent state path is consistent with the parallel path.
    """
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    ctx = _ctx(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
    max_seq = 64

    # incremental path
    cache = api.init_cache(DenseLayout(1, max_seq))
    lengths = jnp.array([len(prompt)], jnp.int32)
    logits, cache = api.prefill(
        ctx, params, jnp.asarray(prompt)[None], lengths, cache)
    toks = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    cur = lengths
    for _ in range(3):
        logits, cache = api.decode_step(
            ctx, params, jnp.array([toks[-1]], jnp.int32), cache, cur)
        toks.append(int(jnp.argmax(logits[0, :cfg.vocab_size])))
        cur = cur + 1

    # teacher-forced path: prefill(prompt + prefix) -> same next token.
    # On untrained random weights the top logits can tie at f32-epsilon
    # level (decode applies `scale` to scores, prefill to q — equal in
    # exact arithmetic); require argmax equality only when decisive.
    for k in range(1, 4):
        seq = np.concatenate([prompt, np.asarray(toks[:k], np.int32)])
        cache2 = api.init_cache(DenseLayout(1, max_seq))
        l2 = jnp.array([len(seq)], jnp.int32)
        logits2, _ = api.prefill(ctx, params, jnp.asarray(seq)[None], l2,
                                 cache2)
        row = np.asarray(logits2[0, :cfg.vocab_size], np.float32)
        want = int(row.argmax())
        top2 = np.partition(row, -2)[-2:]
        gap = float(top2[1] - top2[0])
        if want != toks[k]:
            got_logit = row[toks[k]]
            assert abs(float(row[want] - got_logit)) < max(
                1e-3, 2 * gap + 1e-3), (arch, k, want, toks, gap)


@pytest.mark.parametrize(
    "arch", _zoo(["rwkv6-1.6b", "hymba-1.5b"], ()))
def test_prefill_is_padding_invariant(arch):
    """Ragged prompts: extra padding after `lengths` must not change the
    state/logits (the serving engine pads prompts to buckets)."""
    cfg = configs.smoke(configs.get(arch))
    api = get_model(cfg)
    ctx = _ctx(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    p = 19
    prompt = rng.integers(1, cfg.vocab_size, size=p).astype(np.int32)
    lengths = jnp.array([p], jnp.int32)

    lo, cache_a = api.prefill(
        ctx, params, jnp.asarray(prompt)[None], lengths,
        api.init_cache(DenseLayout(1, 128)))
    padded = np.concatenate([prompt, rng.integers(
        1, cfg.vocab_size, size=45).astype(np.int32)])
    lp, cache_b = api.prefill(
        ctx, params, jnp.asarray(padded)[None], lengths,
        api.init_cache(DenseLayout(1, 128)))
    np.testing.assert_allclose(
        np.asarray(lo, np.float32), np.asarray(lp, np.float32),
        rtol=2e-2, atol=2e-2)
    # recurrent states must agree (KV ring contents too, for hybrid)
    for path, a in jax.tree_util.tree_leaves_with_path(cache_a):
        b = dict(jax.tree_util.tree_leaves_with_path(cache_b))  # noqa: F841
    a_leaves = jax.tree.leaves(cache_a)
    b_leaves = jax.tree.leaves(cache_b)
    for a, b in zip(a_leaves, b_leaves):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=3e-2, atol=3e-2)


@pytest.mark.slow
def test_rwkv_chunked_equals_stepwise():
    """The chunked-parallel scan must equal the O(1) recurrence exactly."""
    from repro.models import ssm
    cfg = configs.smoke(configs.get("rwkv6-1.6b"))
    ctx = _ctx(cfg)
    p = ssm.layer_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model),
                          jnp.float32) * 0.5
    out_chunk, s_end, _ = ssm.time_mix_chunked(
        ctx, p["tm"], x, return_state=True)
    # stepwise
    state = jnp.zeros_like(s_end)
    last = jnp.zeros((2, cfg.d_model), jnp.float32)
    outs = []
    for t in range(48):
        o, state, last = ssm.time_mix_step(ctx, p["tm"], x[:, t], state, last)
        outs.append(o)
    out_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=2e-3, atol=2e-3)
    # terminal states agree
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(state),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_hybrid_ssd_chunked_equals_stepwise():
    from repro.models import hybrid
    cfg = configs.smoke(configs.get("hymba-1.5b"))
    ctx = _ctx(cfg)
    p = hybrid.layer_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32) * 0.5
    out_chunk, s_end = hybrid.ssm_chunked(ctx, p["ssm"], x,
                                          return_state=True)
    inner, hm, n = hybrid._ssm_dims(cfg)
    state = jnp.zeros((2, hm, hybrid.SSM_HEAD, n), jnp.float32)
    outs = []
    for t in range(32):
        o, state = hybrid.ssm_step(ctx, p["ssm"], x[:, t], state)
        outs.append(o)
    out_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunk), np.asarray(out_step),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(state),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_moe_routing_conservation():
    """Zero-drop MoE: every token's top-k weights sum to 1 and the output
    is a convex combination of expert outputs (checked via linearity)."""
    from repro.models import moe
    cfg = configs.smoke(configs.get("grok-1-314b"))
    ctx = _ctx(cfg)
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.1
    out, aux = moe.moe_block(ctx, p, x, zero_drop=True)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.99  # GShard aux >= 1 at uniform-ish routing

    # doubling every expert's down-proj doubles the output (linearity in
    # the combine path => slotting/weights are consistent)
    p2 = dict(p, w_down=p["w_down"] * 2)
    out2, _ = moe.moe_block(ctx, p2, x, zero_drop=True)
    np.testing.assert_allclose(np.asarray(out2), 2 * np.asarray(out),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
def test_moe_capacity_drops_are_bounded():
    from repro.models import moe
    cfg = configs.smoke(configs.get("dbrx-132b"))
    ctx = _ctx(cfg)
    p = moe.moe_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model),
                          jnp.float32) * 0.1
    out_full, _ = moe.moe_block(ctx, p, x, zero_drop=True)
    out_cap, _ = moe.moe_block(ctx, p, x, capacity_factor=1.25)
    # with near-uniform routing at init, few tokens drop; outputs mostly agree
    close = np.isclose(np.asarray(out_cap), np.asarray(out_full),
                       rtol=1e-3, atol=1e-3).mean()
    assert close > 0.5, close


def test_param_counts_match_literature_order():
    """Analytical param counts should land near the models' nameplates."""
    expected = {
        "qwen2-0.5b": 0.5e9, "minitron-8b": 8e9, "deepseek-67b": 67e9,
        "phi3-mini-3.8b": 3.8e9, "internvl2-76b": 70e9,
        "grok-1-314b": 314e9, "dbrx-132b": 132e9, "hymba-1.5b": 1.5e9,
        "rwkv6-1.6b": 1.6e9,
    }
    for arch, want in expected.items():
        got = configs.get(arch).param_count()
        assert 0.5 * want < got < 1.75 * want, (arch, got, want)


def test_moe_active_params_below_total():
    for arch in ("grok-1-314b", "dbrx-132b"):
        cfg = configs.get(arch)
        assert cfg.active_param_count() < 0.55 * cfg.param_count()
