"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see exactly
one CPU device (the 512-device override belongs to launch/dryrun.py only;
multi-device tests spawn subprocesses)."""
import os
import tempfile

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)

# Persistent XLA compilation cache: tier-1 runtime is compile-dominated
# (smoke models are tiny), so repeat runs drop most of their wall time.
_CACHE_DIR = os.environ.get(
    "REPRO_JAX_CACHE", os.path.join(tempfile.gettempdir(), "repro-jax-cache"))
try:
    jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
except Exception:  # pragma: no cover - older jax without the knobs
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_tree_finite(tree):
    import jax.numpy as jnp
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32))), path


TOL = {"float32": dict(rtol=2e-4, atol=2e-4),
       "bfloat16": dict(rtol=3e-2, atol=3e-2)}
