"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see exactly
one CPU device (the 512-device override belongs to launch/dryrun.py only;
multi-device tests spawn subprocesses)."""
import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_tree_finite(tree):
    import jax.numpy as jnp
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32))), path


TOL = {"float32": dict(rtol=2e-4, atol=2e-4),
       "bfloat16": dict(rtol=3e-2, atol=3e-2)}
