"""Scheduler policies: admission ordering, preemption victims, and the
lifecycle properties the redesign promises — no starvation under FCFS,
preempted sequences eventually finish, and lazy allocation never leaks a
page (pool balance invariant). The properties run against a host-side
simulation of the engine's scheduling protocol (admission → lazy growth →
preempt-on-dry-pool → retire), driven by the hypothesis shim; the real
jitted engine is exercised end-to-end in test_blockpool's
overcommit/preemption equivalence tests."""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models.api import get_model
from repro.models.kvlayout import pages_for
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.request import Phase, RequestState, SamplingParams
from repro.serving.scheduler import (FCFS, PageBudgetFair, Scheduler,
                                     ShortestJobFirst, get_scheduler)

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")


def _state(rid, prompt_len, max_new, arrival=None):
    return RequestState(
        rid=rid, prompt=np.zeros((prompt_len,), np.int32),
        params=SamplingParams(max_new_tokens=max_new),
        arrival=arrival if arrival is not None else rid,
        key=jax.random.PRNGKey(0))


def test_get_scheduler_registry():
    assert isinstance(get_scheduler("fcfs"), FCFS)
    assert isinstance(get_scheduler("sjf"), ShortestJobFirst)
    assert isinstance(get_scheduler("pagefair"), PageBudgetFair)
    inst = PageBudgetFair()
    assert get_scheduler(inst) is inst
    with pytest.raises(ValueError):
        get_scheduler("priority-lottery")


def test_policy_orderings():
    a = _state(0, prompt_len=10, max_new=20)   # oldest, mid job, small KV
    b = _state(1, prompt_len=40, max_new=2)    # shortest job, largest KV
    c = _state(2, prompt_len=5, max_new=30)    # newest, longest job
    fcfs, sjf, fair = FCFS(), ShortestJobFirst(), PageBudgetFair()
    assert [s.rid for s in fcfs.admission_order([c, a, b])] == [0, 1, 2]
    assert [s.rid for s in sjf.admission_order([c, a, b])] == [1, 0, 2]
    assert [s.rid for s in fair.admission_order([c, a, b])] == [2, 0, 1]
    # victims mirror each policy's cost signal
    assert fcfs.pick_victim([a, b, c]).rid == 2        # newest
    assert sjf.pick_victim([a, b, c]).rid == 2         # most work left
    assert fair.pick_victim([a, b, c]).rid == 1        # largest footprint
    assert fcfs.pick_victim([]) is None


# ---------------------------------------------------------------------------
# Protocol simulation: the engine's admission/growth/preempt/retire loop
# over real pool + slot-manager state, with a stub token stream — fast
# enough to property-test every policy on random workloads.
# ---------------------------------------------------------------------------


def _simulate(scheduler: Scheduler, specs, *, num_slots, num_pages,
              page_size, max_seq, max_ticks=5_000):
    pool = BlockPool(num_pages, page_size)
    mgr = PagedSlotManager(num_slots, max_seq, pool)
    states = [_state(i, p, m) for i, (p, m) in enumerate(specs)]
    waiting = list(states)
    by_slot: dict[int, RequestState] = {}
    admissions: list[int] = []               # rids in first-admission order
    ticks = 0

    def retire(idx, st):
        mgr.release(idx)
        del by_slot[idx]
        st.finish_reason = "done"
        st.phase = Phase.FINISHED

    def emit(idx, st, wrote_kv=True):
        st.tokens.append(0)
        mgr.tick(idx, wrote_kv=wrote_kv)
        if st.generated >= st.params.max_new_tokens:
            retire(idx, st)

    while (waiting or by_slot) and ticks < max_ticks:
        # admission (+"prefill": first token) in the policy's order
        for st in scheduler.admission_order(waiting):
            idx = mgr.try_assign(
                st.rid, len(st.prefill_tokens()),
                max(st.params.max_new_tokens - st.generated, 1))
            if idx is None:
                if not scheduler.allow_skip:
                    break
                continue
            if st.phase is Phase.WAITING:
                admissions.append(st.rid)
            st.phase = Phase.RUNNING
            st.slot = idx
            by_slot[idx] = st
            emit(idx, st, wrote_kv=False)
        waiting = [s for s in waiting
                   if s.slot is None and s.phase is not Phase.FINISHED]
        # decode tick: lazy growth, preempt on dry pool (victim may be the
        # growing sequence itself — mirrors Engine._grow_or_preempt, so
        # FCFS really evicts the newest arrival), one token each
        for idx, st in list(by_slot.items()):
            if by_slot.get(idx) is not st:
                continue
            while not mgr.ensure(idx, mgr.slots[idx].length + 1):
                victim = scheduler.pick_victim(list(by_slot.values()))
                assert victim is not None, "dry pool with no victim"
                assert not (victim is st and len(by_slot) == 1), \
                    "lone sequence unsatisfiable despite admission bound"
                vidx = victim.slot
                mgr.release(vidx)
                del by_slot[vidx]
                victim.phase = Phase.PREEMPTED
                victim.slot = None
                victim.preemptions += 1
                waiting.append(victim)
                if victim is st:
                    break
        for idx in sorted(by_slot):
            emit(idx, by_slot[idx])
        mgr.check()                          # cross-structure invariants
        ticks += 1
    return states, pool, admissions, ticks


def _random_workload(rng, num_pages, page_size, max_seq, n):
    specs = []
    for _ in range(n):
        p = int(rng.integers(1, max_seq // 2))
        m = int(rng.integers(1, max_seq - p + 1))
        if pages_for(p + m, page_size) > num_pages:
            m = max(num_pages * page_size - p, 1)   # keep it servable
        specs.append((p, m))
    return specs


@given(st.integers(0, 10_000))
def test_fcfs_no_starvation_and_order(seed):
    """Strict FCFS: every request finishes (bounded ticks even under an
    overcommitted pool), first admissions happen in arrival order, and
    the pool drains back to balance."""
    rng = np.random.default_rng(seed)
    page_size = int(rng.choice([4, 8]))
    num_pages = int(rng.integers(3, 10))
    max_seq = page_size * num_pages
    specs = _random_workload(rng, num_pages, page_size, max_seq,
                             n=int(rng.integers(2, 8)))
    states, pool, admissions, ticks = _simulate(
        FCFS(), specs, num_slots=int(rng.integers(1, 4)),
        num_pages=num_pages, page_size=page_size, max_seq=max_seq)
    assert all(s.phase is Phase.FINISHED for s in states), \
        f"starved after {ticks} ticks"
    assert admissions == sorted(admissions), \
        "FCFS let a later arrival overtake the queue head"
    assert pool.free_pages == pool.num_pages     # no page leaked


@pytest.mark.parametrize("policy", ["sjf", "pagefair"])
@given(seed=st.integers(0, 10_000))
def test_preempted_sequences_eventually_finish(policy, seed):
    """Under any policy, preemption is a detour, not an exit: preempted
    requests re-admit, re-prefill, and complete; lazy growth returns every
    page to the pool."""
    rng = np.random.default_rng(seed + sum(map(ord, policy)))
    page_size = 4
    num_pages = int(rng.integers(3, 8))
    max_seq = page_size * num_pages
    specs = _random_workload(rng, num_pages, page_size, max_seq,
                             n=int(rng.integers(3, 8)))
    states, pool, _admissions, ticks = _simulate(
        get_scheduler(policy), specs, num_slots=int(rng.integers(2, 4)),
        num_pages=num_pages, page_size=page_size, max_seq=max_seq)
    assert all(s.phase is Phase.FINISHED for s in states), \
        f"{policy}: unfinished after {ticks} ticks"
    assert all(s.generated == s.params.max_new_tokens for s in states)
    assert pool.free_pages == pool.num_pages


def test_sjf_admits_short_job_first_in_real_engine():
    """Wiring check on the jitted engine: with one slot, SJF runs the
    2-token job before the 30-token job that arrived first; FCFS does the
    opposite."""
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    long_p = rng.integers(1, cfg.vocab_size, 16).astype(np.int32)
    short_p = rng.integers(1, cfg.vocab_size, 8).astype(np.int32)

    def ticks(policy):
        eng = Engine(cfg, params, num_slots=1, max_seq=64,
                     scheduler=policy)
        eng.run([(long_p, SamplingParams(max_new_tokens=30)),
                 (short_p, SamplingParams(max_new_tokens=2))])
        return (eng.requests[0].first_token_tick,
                eng.requests[1].first_token_tick)

    f_long, f_short = ticks("fcfs")
    assert f_long < f_short                  # arrival order
    s_long, s_short = ticks("sjf")
    assert s_short < s_long                  # cost order


def test_scheduler_sweep_smoke(tmp_path, monkeypatch):
    """CI wiring: the policy x overcommit sweep runs at smoke sizes and
    emits a well-formed BENCH_sched.json row per cell."""
    from benchmarks import scheduler_sweep
    monkeypatch.setattr(scheduler_sweep, "OUT_PATH",
                        str(tmp_path / "BENCH_sched.json"))
    result = scheduler_sweep.run(quick=True)
    rows = result["rows"]
    assert {r["policy"] for r in rows} == {"fcfs", "sjf", "pagefair"}
    for r in rows:
        assert r["tokens"] > 0 and r["tok_s"] > 0
        assert r["ttft_p50_ms"] <= r["ttft_p99_ms"]
        assert 0 < r["page_utilization"] <= 1.0
    assert (tmp_path / "BENCH_sched.quick.json").exists()
    assert not (tmp_path / "BENCH_sched.json").exists()
    assert result["mode"] == "quick"
