"""DecodeFusionPlan: plan-selected decode-layer stage granularity.

Contract under test (ISSUE 9):
  * the ``decode_fusion`` knob validates, serializes, and survives a
    backend override; pre-fusion plan documents load with the split
    default;
  * the ``ref.py`` stage oracles are expression-for-expression copies of
    the split chain (``rmsnorm``/``rope`` bitwise), so on the XLA
    backend the fused stage dispatch is bit-identical to split;
  * ``split`` and ``looped`` produce **bitwise-identical** decode logits
    (same depth scan, same per-stage jaxpr). ``fused`` python-unrolls
    the L layer bodies, which lets XLA place bf16 rounding at different
    fusion boundaries than the scan body — the one documented
    reassociated seam, held to the scheme-swap dtype-eps bound instead;
  * the Pallas stage kernels match their oracles in interpret mode to
    rounding-unit tolerance (K-stream f32 accumulation);
  * ``stack.unstack``/``restack`` round-trip stacked params bitwise (the
    unrolled path must see exactly the scanned values);
  * the engine threads granularity through dense, paged, prefix-shared,
    preempting, and quantized-KV decode ticks with greedy-identical
    tokens, and caches the positions operand under the lengths-device
    dirty discipline.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import dispatch as dsp
from repro.core.plan import (
    DEFAULT_PLAN, FUSION_MODES, DecodeFusionPlan, ExecutionPlan, PlanError,
    make_plan, tune,
)
from repro.kernels import ref
from repro.kernels.decode_fuse import (
    decode_ingest_fused, ffn_norm_fused, oproj_residual_fused,
)
from repro.models import layers as L
from repro.models import stack
from repro.models.api import get_model
from repro.models.kvlayout import DenseLayout
from repro.models.layers import LayerCtx

CFG = configs.get("qwen2-0.5b")


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke(CFG)
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, api, params


# ---------------------------------------------------------------------------
# Plan knob
# ---------------------------------------------------------------------------


def test_fusion_knob_validates():
    for g in FUSION_MODES:
        assert DecodeFusionPlan(granularity=g).granularity == g
    with pytest.raises(PlanError, match="granularity"):
        DecodeFusionPlan(granularity="megakernel")
    with pytest.raises(PlanError, match="backend"):
        DecodeFusionPlan(backend="cuda")


def test_fusion_knob_round_trips_json():
    p = make_plan(decode_fusion="looped")
    q = ExecutionPlan.from_json(p.to_json())
    assert q.decode_fusion == p.decode_fusion
    assert "fusion[looped]" in p.describe()


def test_legacy_plan_without_fusion_key_loads_split():
    """Pre-fusion plan documents must keep loading (backward compat) and
    land on the split default — the semantics they were tuned under."""
    doc = json.loads(make_plan().to_json())
    del doc["ops"]["decode_fusion"]
    p = ExecutionPlan.from_json(json.dumps(doc))
    assert p.decode_fusion.granularity == "split"


def test_backend_override_keeps_granularity():
    """with_overrides(backend=...) maps the backend but never the tuned
    granularity: on XLA the fused stages dispatch their bit-identical
    jnp oracles, so the decision stays meaningful."""
    p = make_plan(backend="pallas", decode_fusion="looped")
    q = p.with_overrides(backend="xla")
    assert q.decode_fusion.backend == "xla"
    assert q.decode_fusion.granularity == "looped"


def test_tune_covers_fusion_knob():
    p = tune(CFG)
    assert p.decode_fusion.granularity in FUSION_MODES
    # full-depth llama-class config: the stage-dispatch roofline has the
    # looped dispatch strictly cheapest (fewest stages, one loop setup)
    assert p.decode_fusion.granularity == "looped"


def test_predict_fusion_time_roofline():
    t = {g: dsp.predict_fusion_time(CFG, g) for g in FUSION_MODES}
    assert all(v > 0 for v in t.values())
    assert t["fused"] < t["split"]      # fewer stage boundaries per layer
    assert t["looped"] < t["split"]
    with pytest.raises(ValueError, match="granularity"):
        dsp.predict_fusion_time(CFG, "megakernel")
    assert dsp.find_decode_fusion(CFG) in FUSION_MODES


# ---------------------------------------------------------------------------
# Oracles == split chain (bitwise)
# ---------------------------------------------------------------------------


def test_ref_norm_and_rope_are_bitwise_copies():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 1, 96)), jnp.bfloat16)
    scale = jnp.asarray(rng.standard_normal(96), jnp.bfloat16)
    assert np.array_equal(np.asarray(L.rmsnorm(x, scale)),
                          np.asarray(ref.rmsnorm_ref(x, scale)))
    h = x.reshape(2, 1, 3, 32)
    pos = jnp.array([[5], [170]], jnp.int32)
    assert np.array_equal(np.asarray(L.rope(h, pos, 1e4)),
                          np.asarray(ref.rope_ref(h, pos, 1e4)))


@pytest.mark.parametrize("granularity", ["fused", "looped"])
def test_stage_dispatch_bitwise_on_xla(smoke_model, granularity):
    """layers.decode_ingest / decode_epilogue on the XLA backend compose
    the exact split-chain expressions: per-stage outputs are bitwise."""
    cfg, _, _ = smoke_model
    key = jax.random.PRNGKey(3)
    p = L.attention_params(cfg, key)
    np_ = L.norm_params(cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, cfg.d_model),
                          jnp.dtype(cfg.activation_dtype))
    pos = jnp.array([4, 9], jnp.int32)
    ctx_s = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion="split"))
    ctx_g = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion=granularity))
    for a, b in zip(L.decode_ingest(ctx_s, np_, p, x, pos),
                    L.decode_ingest(ctx_g, np_, p, x, pos)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    o = jax.random.normal(jax.random.PRNGKey(2), (2, 1, cfg.q_dim),
                          jnp.dtype(cfg.activation_dtype))
    res = jax.random.normal(jax.random.PRNGKey(4), (2, 1, cfg.d_model),
                            jnp.dtype(cfg.activation_dtype))
    assert np.array_equal(
        np.asarray(L.decode_epilogue(ctx_s, p, o, res)),
        np.asarray(L.decode_epilogue(ctx_g, p, o, res)))


def test_split_and_looped_decode_logits_bitwise(smoke_model):
    """Same depth scan + bitwise stages -> bitwise logits and cache."""
    cfg, api, params = smoke_model
    cache = api.init_cache(DenseLayout(2, 64))
    toks = jnp.array([3, 5], jnp.int32)
    lens = jnp.array([4, 9], jnp.int32)
    outs = {}
    for g in ("split", "looped"):
        ctx = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion=g))
        logits, nc = api.decode_step(ctx, params, toks, cache, lens)
        outs[g] = (np.asarray(logits), nc)
    assert np.array_equal(outs["split"][0], outs["looped"][0])
    for a, b in zip(jax.tree.leaves(outs["split"][1]),
                    jax.tree.leaves(outs["looped"][1])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_fused_decode_logits_value_close(smoke_model):
    """The documented exclusion: ``fused`` unrolls the L layer bodies, so
    XLA may fuse (and round) across different boundaries than the scan
    body compiles to — same expressions, different bf16 rounding
    placement. Bound it with the scheme-swap dtype-eps pattern."""
    cfg, api, params = smoke_model
    cache = api.init_cache(DenseLayout(2, 64))
    toks = jnp.array([3, 5], jnp.int32)
    lens = jnp.array([4, 9], jnp.int32)
    outs = {}
    for g in ("split", "fused"):
        ctx = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion=g))
        logits, _ = api.decode_step(ctx, params, toks, cache, lens)
        outs[g] = np.asarray(logits, np.float32)
    eps = float(jnp.finfo(jnp.dtype(cfg.activation_dtype)).eps)
    scale = float(np.abs(outs["split"]).max())
    atol = 32 * eps * max(scale, 1.0)
    np.testing.assert_allclose(outs["fused"], outs["split"],
                               rtol=32 * eps, atol=atol)


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles (interpret mode)
# ---------------------------------------------------------------------------


INGEST_CASES = [
    # (num_heads, num_kv_heads, head_dim, d_model, bias, rope)
    (4, 2, 32, 128, False, True),      # GQA
    (8, 8, 64, 256, True, True),       # MHA + qkv bias
    (4, 1, 64, 192, True, False),      # MQA, no rope, K not 128-multiple
    (12, 4, 64, 384, False, True),     # wider GQA, K streams in blocks
]


@pytest.mark.parametrize("hq,hk,dh,d,bias,use_rope", INGEST_CASES)
def test_ingest_kernel_matches_oracle(hq, hk, dh, d, bias, use_rope):
    rng = np.random.default_rng(hq * 1000 + d)
    m = 3
    x = jnp.asarray(rng.standard_normal((m, 1, d)), jnp.bfloat16)
    scale = jnp.asarray(rng.standard_normal(d), jnp.bfloat16)
    wq = jnp.asarray(rng.standard_normal((d, hq * dh)), jnp.bfloat16)
    wk = jnp.asarray(rng.standard_normal((d, hk * dh)), jnp.bfloat16)
    wv = jnp.asarray(rng.standard_normal((d, hk * dh)), jnp.bfloat16)
    bq = jnp.asarray(rng.standard_normal(hq * dh), jnp.bfloat16) \
        if bias else None
    bk = jnp.asarray(rng.standard_normal(hk * dh), jnp.bfloat16) \
        if bias else None
    bv = jnp.asarray(rng.standard_normal(hk * dh), jnp.bfloat16) \
        if bias else None
    pos = jnp.array([4, 9, 170], jnp.int32)
    qo, ko, vo = ref.decode_ingest_ref(
        x, scale, wq, wk, wv, pos, num_heads=hq, num_kv_heads=hk,
        head_dim=dh, use_rope=use_rope, bq=bq, bk=bk, bv=bv)
    qf, kf, vf = decode_ingest_fused(
        x.reshape(m, d), scale, wq, wk, wv, pos, num_heads=hq,
        num_kv_heads=hk, head_dim=dh, use_rope=use_rope,
        bq=bq, bk_bias=bk, bv=bv, interpret=True)
    eps = float(jnp.finfo(jnp.bfloat16).eps)
    for a, b in ((qo.reshape(m, -1), qf), (ko.reshape(m, -1), kf),
                 (vo.reshape(m, -1), vf)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        atol = 32 * eps * max(float(np.abs(a).max()), 1.0)
        np.testing.assert_allclose(b, a, rtol=32 * eps, atol=atol)


@pytest.mark.parametrize("m,q_dim,d", [(1, 128, 128), (3, 256, 192),
                                       (8, 384, 512)])
def test_oproj_kernel_matches_oracle(m, q_dim, d):
    rng = np.random.default_rng(m * 100 + d)
    o = jnp.asarray(rng.standard_normal((m, 1, q_dim)), jnp.bfloat16)
    wo = jnp.asarray(rng.standard_normal((q_dim, d)), jnp.bfloat16)
    res = jnp.asarray(rng.standard_normal((m, 1, d)), jnp.bfloat16)
    want = ref.oproj_residual_ref(o, wo, res)
    got = oproj_residual_fused(
        o.reshape(m, q_dim), wo, res.reshape(m, d),
        interpret=True).reshape(want.shape)
    a = np.asarray(want, np.float32)
    b = np.asarray(got, np.float32)
    eps = float(jnp.finfo(jnp.bfloat16).eps)
    atol = 32 * eps * max(float(np.abs(a).max()), 1.0)
    np.testing.assert_allclose(b, a, rtol=32 * eps, atol=atol)


@pytest.mark.parametrize("m,d,f,act", [(1, 128, 256, "swiglu"),
                                       (4, 192, 384, "swiglu"),
                                       (8, 256, 512, "geglu"),
                                       (3, 384, 640, "swiglu")])
def test_ffn_norm_kernel_matches_oracle(m, d, f, act):
    rng = np.random.default_rng(m * 100 + f)
    x = jnp.asarray(rng.standard_normal((m, 1, d)), jnp.bfloat16)
    scale = jnp.asarray(rng.standard_normal(d), jnp.bfloat16)
    wg = jnp.asarray(rng.standard_normal((d, f)) / 8, jnp.bfloat16)
    wu = jnp.asarray(rng.standard_normal((d, f)) / 8, jnp.bfloat16)
    want = ref.ffn_norm_ref(x, scale, wg, wu, activation=act, fused=True)
    got = ffn_norm_fused(x.reshape(m, d), scale, wg, wu, activation=act,
                         interpret=True).reshape(want.shape)
    a = np.asarray(want, np.float32)
    b = np.asarray(got, np.float32)
    eps = float(jnp.finfo(jnp.bfloat16).eps)
    atol = 32 * eps * max(float(np.abs(a).max()), 1.0)
    np.testing.assert_allclose(b, a, rtol=32 * eps, atol=atol)


@pytest.mark.parametrize("fused_ffn", [False, True])
def test_decode_mlp_stage_bitwise_on_xla(smoke_model, fused_ffn):
    """layers.decode_mlp's fused seam composes whichever split chain the
    plan's fused_ffn knob selects — bitwise either way on XLA."""
    cfg, _, _ = smoke_model
    p = L.mlp_params(cfg, jax.random.PRNGKey(5))
    np_ = L.norm_params(cfg, cfg.d_model)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 1, cfg.d_model),
                          jnp.dtype(cfg.activation_dtype))
    ctx_s = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion="split",
                                             fused_ffn=fused_ffn))
    ctx_g = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion="looped",
                                             fused_ffn=fused_ffn))
    assert np.array_equal(
        np.asarray(L.decode_mlp(ctx_s, np_, p, x)),
        np.asarray(L.decode_mlp(ctx_g, np_, p, x)))


def test_ops_dispatch_routes_by_plan(smoke_model):
    """ops.decode_ingest/oproj_residual: pallas backend runs the fused
    kernels (interpret on CPU), xla backend runs the oracles — and the
    two agree to dtype-eps."""
    from repro.kernels import ops
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(5)
    d, hq, hk, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.head_dim)
    x = jnp.asarray(rng.standard_normal((2, 1, d)), jnp.bfloat16)
    scale = jnp.asarray(rng.standard_normal(d), jnp.bfloat16)
    wq = jnp.asarray(rng.standard_normal((d, hq * dh)), jnp.bfloat16)
    wk = jnp.asarray(rng.standard_normal((d, hk * dh)), jnp.bfloat16)
    wv = jnp.asarray(rng.standard_normal((d, hk * dh)), jnp.bfloat16)
    pos = jnp.array([4, 9], jnp.int32)
    kw = dict(num_heads=hq, num_kv_heads=hk, head_dim=dh)
    eps = float(jnp.finfo(jnp.bfloat16).eps)
    ref_out = ops.decode_ingest(
        x, scale, wq, wk, wv, pos,
        plan=make_plan(backend="xla", decode_fusion="fused"), **kw)
    pal_out = ops.decode_ingest(
        x, scale, wq, wk, wv, pos,
        plan=make_plan(backend="pallas", decode_fusion="fused"), **kw)
    for a, b in zip(ref_out, pal_out):
        assert a.shape == b.shape
        a = np.asarray(a, np.float32)
        atol = 32 * eps * max(float(np.abs(a).max()), 1.0)
        np.testing.assert_allclose(np.asarray(b, np.float32), a,
                                   rtol=32 * eps, atol=atol)


# ---------------------------------------------------------------------------
# stack restacking round-trip (the unrolled path's foundation)
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=4),
       st.sampled_from([jnp.float32, jnp.bfloat16, jnp.int32]))
@settings(max_examples=20, deadline=None)
def test_unstack_restack_round_trip_bitwise(layers, width, dtype):
    rng = np.random.default_rng(layers * 10 + width)
    tree = {
        "w": jnp.asarray(rng.standard_normal((layers, width, 8)), dtype),
        "sub": {"b": jnp.asarray(
            rng.standard_normal((layers, width)), dtype)},
    }
    per_layer = stack.unstack(tree)
    assert len(per_layer) == layers
    back = stack.restack(per_layer)
    assert jax.tree_util.tree_structure(back) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Engine: greedy identity across granularities
# ---------------------------------------------------------------------------


def _run_engine(cfg, params, prompts, max_new=5, **kw):
    from repro.serving.engine import Engine
    from repro.serving.request import SamplingParams
    eng = Engine(cfg, params, num_slots=2, max_seq=64, **kw)
    sp = SamplingParams(max_new_tokens=max_new, temperature=0.0)
    out = eng.run([(p, sp) for p in prompts])
    return eng, [out[k] for k in sorted(out)]


@pytest.fixture(scope="module")
def engine_prompts(smoke_model):
    cfg, _, _ = smoke_model
    rng = np.random.default_rng(7)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in (11, 26)]


def test_engine_greedy_identity_dense(smoke_model, engine_prompts):
    cfg, _, params = smoke_model
    outs = {g: _run_engine(cfg, params, engine_prompts,
                           decode_fusion=g)[1]
            for g in FUSION_MODES}
    assert outs["split"] == outs["fused"] == outs["looped"]


@pytest.mark.parametrize("sharing", [False, True])
def test_engine_greedy_identity_paged(smoke_model, engine_prompts,
                                      sharing):
    cfg, _, params = smoke_model
    kw = dict(cache_kind="paged", page_size=16, prefill_chunk=16,
              prefix_sharing=sharing)
    outs = {g: _run_engine(cfg, params, engine_prompts,
                           decode_fusion=g, **kw)[1]
            for g in FUSION_MODES}
    assert outs["split"] == outs["fused"] == outs["looped"]


def test_engine_greedy_identity_quantized_kv(smoke_model, engine_prompts):
    cfg, _, params = smoke_model
    kw = dict(cache_kind="paged", page_size=16, prefill_chunk=16,
              kv_dtype="int8")
    outs = {g: _run_engine(cfg, params, engine_prompts,
                           decode_fusion=g, **kw)[1]
            for g in FUSION_MODES}
    assert outs["split"] == outs["fused"] == outs["looped"]


def test_engine_greedy_identity_under_preemption(smoke_model):
    """Overcommitted pool forces mid-decode preemption (partial pages,
    re-prefill); granularity must not change a single token."""
    cfg, _, params = smoke_model
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 10)]
    kw = dict(cache_kind="paged", page_size=16, prefill_chunk=16,
              num_pages=4)
    outs = {}
    for g in FUSION_MODES:
        eng, toks = _run_engine(cfg, params, prompts, max_new=26,
                                decode_fusion=g, **kw)
        outs[g] = toks
        assert eng.stats.preemptions > 0, "pool was never under pressure"
    assert outs["split"] == outs["fused"] == outs["looped"]


def test_engine_fusion_arg_wins_over_plan(smoke_model):
    from repro.serving.engine import Engine
    cfg, _, params = smoke_model
    plan = make_plan(decode_fusion="split")
    eng = Engine(cfg, params, num_slots=2, max_seq=64, plan=plan,
                 decode_fusion="looped")
    assert eng.decode_fusion == "looped"
    assert eng.ctx.plan.decode_fusion.granularity == "looped"
    # plan knob adopted when the arg is absent
    eng2 = Engine(cfg, params, num_slots=2, max_seq=64,
                  plan=make_plan(decode_fusion="fused"))
    assert eng2.decode_fusion == "fused"
    with pytest.raises(ValueError, match="decode_fusion"):
        Engine(cfg, params, num_slots=2, max_seq=64,
               decode_fusion="megakernel")


# ---------------------------------------------------------------------------
# positions operand: device cache under the lengths dirty discipline
# ---------------------------------------------------------------------------


def test_positions_device_cached_and_dirty_tracked():
    from repro.serving.kvcache import SlotManager
    mgr = SlotManager(3, 64)
    p0 = mgr.positions_device()
    assert p0.dtype == jnp.int32 and p0.shape == (3,)
    assert mgr.positions_device() is p0          # clean -> same buffer
    idx = mgr.try_assign(0, 5, 4)
    assert idx is not None
    p1 = mgr.positions_device()
    assert p1 is not p0                          # assign dirtied it
    assert int(p1[idx]) == 5
    assert mgr.positions_device() is p1
    mgr.tick(idx, wrote_kv=False)                # prefill token: no KV
    assert mgr.positions_device() is p1          # ... so still clean
    mgr.tick(idx, wrote_kv=True)
    p2 = mgr.positions_device()
    assert p2 is not p1 and int(p2[idx]) == 6
    mgr.release(idx)
    p3 = mgr.positions_device()
    assert p3 is not p2 and int(p3[idx]) == 0
    # positions mirror lengths for every family today
    assert np.array_equal(np.asarray(p3), np.asarray(mgr.lengths_device()))


def test_decode_step_accepts_positions_operand(smoke_model):
    """positions=None defaults to lengths; passing the explicit operand
    with the same values is bitwise identical (the engine path)."""
    cfg, api, params = smoke_model
    cache = api.init_cache(DenseLayout(2, 64))
    toks = jnp.array([3, 5], jnp.int32)
    lens = jnp.array([4, 9], jnp.int32)
    ctx = LayerCtx(cfg=cfg, plan=make_plan(decode_fusion="looped"))
    a, _ = api.decode_step(ctx, params, toks, cache, lens)
    b, _ = api.decode_step(ctx, params, toks, cache, lens,
                           positions=jnp.asarray(lens))
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Bench artifact smoke (fast lane)
# ---------------------------------------------------------------------------


def test_fusion_bench_smoke(tmp_path, monkeypatch):
    """benchmarks.decode_fusion --quick emits a well-formed
    BENCH_fusion.json sidecar showing the headline result: the fused
    granularities cut the batch-1 decode-tick dispatch count >= 2x."""
    from benchmarks import decode_fusion
    monkeypatch.setattr(decode_fusion, "OUT_PATH",
                        str(tmp_path / "BENCH_fusion.json"))
    result = decode_fusion.run(quick=True)
    assert (tmp_path / "BENCH_fusion.quick.json").exists()
    assert not (tmp_path / "BENCH_fusion.json").exists()
    assert result["mode"] == "quick"
    counts = result["dispatches_per_tick"]
    assert set(counts) == {"split", "fused", "looped"}
    # the acceptance bar: >= 2x fewer dispatches per tick at batch 1
    assert counts["split"] >= 2 * counts["looped"]
    assert counts["split"] >= 2 * counts["fused"]
    # wall clock is noise-bounded on CPU (split and looped compile the
    # same XLA program) — just require sane, same-ballpark numbers
    for row in result["latency"]:
        assert row["split_us"] > 0
        assert row["looped_over_split"] < 1.5
