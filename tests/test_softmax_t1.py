"""T1 property tests (hypothesis): the math the paper's §3 rests on.

  * Eq. 3 — softmax is invariant to the scaling constant φ.
  * Eq. 4 — the async (num, den) combine is invariant to how the KV axis
    is split (order-independence = no synchronized update needed).
  * sync and async combines agree wherever both are numerically safe.
  * φ calibration disables T1 for wide-ranged models (the OPT case).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import SoftmaxPhiConfig
from repro.core import phi as phi_mod
from repro.core import softmax as smx
from repro.kernels import ref

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

floats = st.floats(min_value=-8.0, max_value=8.0)


@given(st.lists(floats, min_size=2, max_size=24),
       st.floats(min_value=-10, max_value=10))
def test_softmax_phi_invariance(xs, phi):
    x = jnp.asarray(xs, jnp.float32)
    a = ref.softmax_ref(x)
    b = ref.softmax_unified_max(x, phi)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@given(st.integers(min_value=2, max_value=6), st.integers(0, 10_000))
def test_async_combine_split_invariance(n_splits, seed):
    """Eq. 4: partial (num, den) sums are addable in any partition."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    kv, d = 24, 8
    s = jax.random.normal(k1, (kv,), jnp.float32)
    v = jax.random.normal(k2, (kv, d), jnp.float32)
    whole = smx.async_partial(s, v, phi=0.5)
    full_out = whole.num / whole.den

    bounds = sorted(
        set([0, kv] + list(
            np.random.default_rng(seed).integers(1, kv, n_splits - 1))))
    parts = [
        smx.async_partial(s[a:b], v[a:b], phi=0.5)
        for a, b in zip(bounds[:-1], bounds[1:]) if b > a
    ]
    out, mc = smx.combine_async(parts)
    np.testing.assert_allclose(out, full_out, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mc, whole.max_centered, rtol=1e-6)


@given(st.integers(0, 10_000))
def test_sync_and_async_combines_agree(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    kv, d, p = 32, 4, 4
    s = jax.random.normal(k1, (kv,), jnp.float32) * 3
    v = jax.random.normal(k2, (kv, d), jnp.float32)
    asy = [smx.async_partial(s[i::p], v[i::p], phi=0.0) for i in range(p)]
    syn = [smx.sync_partial(s[i::p], v[i::p]) for i in range(p)]
    a_out, _ = smx.combine_async(asy)
    s_out = smx.combine_sync(syn)
    np.testing.assert_allclose(a_out, s_out, rtol=1e-4, atol=1e-5)


def test_sync_combine_handles_fully_masked_partial():
    s = jnp.array([1.0, 2.0], jnp.float32)
    v = jnp.array([[1.0], [2.0]], jnp.float32)
    live = smx.sync_partial(s, v)
    dead = smx.sync_partial(s, v, valid=jnp.zeros(2, bool))
    out = smx.combine_sync([live, dead])
    want = smx.combine_sync([live])
    np.testing.assert_allclose(out, want, rtol=1e-6)


# ---------------------------------------------------------------------------
# φ calibration (paper Fig. 5 workflow)
# ---------------------------------------------------------------------------


def test_calibrate_narrow_band_enables_t1():
    stats = phi_mod.LogitStats()
    stats = stats.update(jnp.asarray(
        np.random.default_rng(0).normal(3.0, 1.5, size=4096)))
    cfg = phi_mod.calibrate(stats)
    assert cfg.active
    assert abs(cfg.phi - 3.0) < 0.5
    assert cfg.band[0] < -6 and cfg.band[1] > 6


def test_calibrate_wide_range_disables_t1_like_opt():
    stats = phi_mod.LogitStats()
    stats = stats.update(jnp.asarray([-300.0, 0.0, 250.0]))
    cfg = phi_mod.calibrate(stats)
    assert not cfg.active  # the paper's OPT-6.7B case


def test_logit_stats_merge_matches_batch():
    rng = np.random.default_rng(1)
    a, b = rng.normal(size=100), rng.normal(loc=2, size=300)
    s = phi_mod.LogitStats().update(jnp.asarray(a)).update(jnp.asarray(b))
    both = np.concatenate([a, b])
    assert s.count == 400
    np.testing.assert_allclose(s.mean, both.mean(), rtol=1e-5)
    np.testing.assert_allclose(s.std, both.std(), rtol=1e-4)
    np.testing.assert_allclose(s.minimum, both.min())
    np.testing.assert_allclose(s.maximum, both.max())
    s2 = phi_mod.LogitStats.from_json(s.to_json())
    assert s2.count == s.count and s2.mean == s.mean


def test_collect_attention_logit_stats_shapes():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 16, 4, 32))
    k = jax.random.normal(key, (2, 16, 4, 32))
    stats = phi_mod.collect_attention_logit_stats(q, k)
    assert stats.count == 2 * 4 * 16 * 16
    cfg = phi_mod.calibrate(stats)
    assert isinstance(cfg, SoftmaxPhiConfig)


# ---------------------------------------------------------------------------
# Overflow -> recomputation fallback (paper §3 "Recomputation")
# ---------------------------------------------------------------------------


def test_ops_decode_fallback_recovers_safe_result():
    from repro.kernels import ops
    b, hq, hk, d, s = 1, 2, 2, 16, 32
    q = 60.0 * jnp.ones((b, hq, d), jnp.float32)       # logits >> band
    kc = jnp.ones((b, s, hk, d), jnp.float32)
    vc = jax.random.normal(jax.random.PRNGKey(0), (b, s, hk, d))
    lengths = jnp.array([s], jnp.int32)
    phi_cfg = SoftmaxPhiConfig(phi=0.0, band=(-8.0, 8.0))
    out = ops.attention_decode(q, kc, vc, lengths, phi_cfg=phi_cfg)
    want = ref.attention_decode_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(jnp.isfinite(out)))
