"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every Pallas kernel is exercised over a shape x dtype grid and asserted
against ref.py — the contract required for real-TPU deployment confidence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TOL
from repro.kernels import ref
from repro.kernels.decode_attention import (
    decode_attention_sync,
    decode_attention_unified_max,
)
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.flat_gemm import flat_gemm, pick_bk, pick_bn
from repro.kernels.gemv import gemv


# ---------------------------------------------------------------------------
# T2: flat GEMM
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
@pytest.mark.parametrize("m,k,n", [
    (1, 256, 512), (3, 256, 512), (8, 512, 256), (13, 384, 640),
    (32, 1024, 256), (64, 256, 1024),
])
def test_flat_gemm_matches_oracle(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(m * 1000 + n))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), dtype)
    got = flat_gemm(x, w, interpret=True)
    want = ref.flat_gemm_ref(x, w)
    assert got.shape == (m, n) and got.dtype == x.dtype
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), **TOL[dtype])


def test_flat_gemm_block_pickers_respect_vmem():
    from repro import hardware
    spec = hardware.DEFAULT
    for m in (8, 16, 64):
        for n in (512, 4096, 16384):
            for k in (512, 4096):
                bn = pick_bn(m, n, k)
                bk = pick_bk(m, bn, k)
                assert n % bn == 0 or bn == n
                assert k % bk == 0 or bk == k
                vmem = 2 * (m * bk + bk * bn) * 2 + m * bn * 4
                assert vmem <= spec.vmem_bytes // 4 or (bn == 128 and bk == 128)


def test_flat_gemm_min_padding_is_8():
    """The T2 claim: M padded to 8, not 64/128."""
    x = jnp.ones((3, 128), jnp.float32)
    w = jnp.ones((128, 128), jnp.float32)
    out = flat_gemm(x, w, interpret=True)
    assert out.shape == (3, 128)  # sliced back from M_pad=8


# ---------------------------------------------------------------------------
# ImplA: GEMV
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
@pytest.mark.parametrize("m,k,n", [(1, 512, 768), (2, 300, 500), (4, 128, 128)])
def test_gemv_matches_oracle(m, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), dtype)
    got = gemv(x, w, interpret=True)
    np.testing.assert_allclose(
        got.astype(np.float32), ref.gemv_ref(x, w).astype(np.float32),
        **TOL[dtype])


# ---------------------------------------------------------------------------
# T1: decode attention (async unified-max + sync fallback)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
@pytest.mark.parametrize("b,hq,hk,d,s,block", [
    (2, 8, 2, 64, 256, 128),     # GQA 4:1
    (1, 4, 4, 128, 512, 256),    # MHA
    (3, 14, 2, 64, 384, 128),    # qwen2-style 7:1
])
def test_decode_attention_unified_max(b, hq, hk, d, s, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * 17 + s), 4)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, hk, s, d), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, size=b), jnp.int32)
    out, stat = decode_attention_unified_max(
        q, kc, vc, lengths, phi=0.0, block_k=block, interpret=True)
    want = ref.attention_decode_ref(
        q, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3), lengths)
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])
    assert stat.shape == (b, hk) and bool(jnp.all(jnp.isfinite(stat)))


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
def test_decode_attention_sync_matches(dtype):
    b, hq, hk, d, s = 2, 8, 2, 64, 320
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, hk, s, d), dtype)
    lengths = jnp.array([100, 320], jnp.int32)
    out = decode_attention_sync(q, kc, vc, lengths, block_k=128,
                                interpret=True)
    want = ref.attention_decode_ref(
        q, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3), lengths)
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])


def test_decode_attention_phi_invariance():
    """Output is independent of φ while inside the safe band (Eq. 3)."""
    b, hq, hk, d, s = 1, 4, 2, 32, 128
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hk, s, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hk, s, d), jnp.float32)
    lengths = jnp.array([s], jnp.int32)
    outs = [
        decode_attention_unified_max(
            q, kc, vc, lengths, phi=phi, block_k=64, interpret=True)[0]
        for phi in (-2.0, 0.0, 3.5)
    ]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-4, atol=1e-5)


def test_decode_attention_overflow_stat_reports():
    """Scaled-up logits must push the stat past a tight band -> fallback."""
    b, hq, hk, d, s = 1, 2, 2, 32, 64
    q = 50.0 * jnp.ones((b, hq, d), jnp.float32)
    kc = jnp.ones((b, hk, s, d), jnp.float32)
    vc = jnp.ones((b, hk, s, d), jnp.float32)
    lengths = jnp.array([s], jnp.int32)
    _, stat = decode_attention_unified_max(
        q, kc, vc, lengths, phi=0.0, block_k=32, interpret=True)
    assert float(stat.max()) > 16.0  # way outside a (-16, 16) band


# ---------------------------------------------------------------------------
# Prefill attention (fused kernel + chunked XLA path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hk", [(4, 4), (8, 2)])
def test_flash_prefill_matches_oracle(hq, hk, causal, dtype):
    b, s, d = 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    want = ref.attention_prefill_ref(q, k, v, causal=causal)
    res = flash_prefill(q, k, v, causal=causal, unified_max=True, phi=0.0,
                        interpret=True)
    out = res[0] if isinstance(res, tuple) else res
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])
    res = flash_prefill(q, k, v, causal=causal, unified_max=False,
                        interpret=True)
    out = res[0] if isinstance(res, tuple) else res
    np.testing.assert_allclose(
        out.astype(np.float32), want.astype(np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("phi", [0.0, None])
def test_chunked_prefill_ref(window, phi):
    b, s, hq, hk, d = 2, 300, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
    want = ref.attention_prefill_ref(q, k, v, causal=True,
                                     sliding_window=window)
    got = ref.attention_prefill_chunked(
        q, k, v, causal=True, sliding_window=window, phi=phi, block_q=128)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# T2 extension: fused flat-GEMM SwiGLU FFN-up
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
@pytest.mark.parametrize("activation", ["swiglu", "gelu"])
@pytest.mark.parametrize("m,k,n", [(3, 256, 512), (8, 512, 384),
                                   (17, 384, 256)])
def test_fused_ffn_up_matches_oracle(m, k, n, activation, dtype):
    from repro.kernels.fused_ffn import fused_ffn_up
    ks = jax.random.split(jax.random.PRNGKey(m * 31 + n), 3)
    x = jax.random.normal(ks[0], (m, k), dtype)
    wg = jax.random.normal(ks[1], (k, n), dtype) * 0.05
    wu = jax.random.normal(ks[2], (k, n), dtype) * 0.05
    got = fused_ffn_up(x, wg, wu, activation=activation, interpret=True)
    want = ref.fused_ffn_up_ref(x, wg, wu, activation=activation)
    assert got.shape == (m, n)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), **TOL[dtype])


def test_fused_ffn_traffic_accounting():
    """The fusion claim: activation HBM round-trips removed (2·M·N of
    gate/up tensors never leave VMEM; x read once, not twice)."""
    m, k, n = 8, 4096, 11008
    db = 2
    separate = (2 * m * k + 2 * k * n + 3 * m * n) * db
    fused = (m * k + 2 * k * n + m * n) * db
    assert fused < separate
    saved = separate - fused
    assert saved == (m * k + 2 * m * n) * db


# ---------------------------------------------------------------------------
# Paged (block-table) decode attention
# ---------------------------------------------------------------------------


def _paged_fixture(dtype, seed=0):
    """Random pool + disjoint per-row page assignment with sentinel tails."""
    from repro.kernels.ref import gather_paged_kv  # noqa: F401
    rng = np.random.default_rng(seed)
    b, hq, hk, d, ps, num_pages, nb = 3, 8, 2, 64, 32, 24, 8
    q = jnp.asarray(rng.normal(size=(b, hq, d)), dtype)
    kp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    vp = jnp.asarray(rng.normal(size=(num_pages, ps, hk, d)), dtype)
    perm = rng.permutation(num_pages)
    bt = np.full((b, nb), num_pages, np.int32)   # sentinel padding
    for i in range(b):
        bt[i] = perm[i * nb:(i + 1) * nb]
    bt[2, 5:] = num_pages                        # short row: fewer pages
    lengths = jnp.asarray([200, 37, 5 * ps], jnp.int32)
    return q, kp, vp, jnp.asarray(bt), lengths


@pytest.mark.parametrize(
    "dtype", ["float32",
              pytest.param("bfloat16", marks=pytest.mark.slow)])
def test_paged_decode_attention_matches_oracle(dtype):
    from repro.kernels.decode_attention import (
        paged_decode_attention_sync, paged_decode_attention_unified_max)
    q, kp, vp, bt, lengths = _paged_fixture(dtype)
    got, _ = paged_decode_attention_unified_max(
        q, kp, vp, bt, lengths, phi=0.0, interpret=True)
    want, _ = ref.attention_decode_paged_unified_max_ref(
        q, kp, vp, bt, lengths, phi=0.0)
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), **TOL[dtype])
    got_s = paged_decode_attention_sync(q, kp, vp, bt, lengths,
                                        interpret=True)
    want_s = ref.attention_decode_paged_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(
        got_s.astype(np.float32), want_s.astype(np.float32), **TOL[dtype])


def test_paged_oracle_equals_dense_on_gathered_view():
    """gather(pool, block_table) + dense decode == paged decode, bitwise —
    the identity the engine's dense/paged token-equality rests on."""
    q, kp, vp, bt, lengths = _paged_fixture("float32")
    k_dense = ref.gather_paged_kv(kp, bt)
    v_dense = ref.gather_paged_kv(vp, bt)
    dense = ref.attention_decode_ref(q, k_dense, v_dense, lengths)
    paged = ref.attention_decode_paged_ref(q, kp, vp, bt, lengths)
    assert bool(jnp.all(dense == paged))


def test_chunk_attention_overflow_falls_back_to_safe():
    """T1 chunk attention recomputes with the safe scheme when any centered
    logit leaves the band (paper's recomputation fallback, chunk path)."""
    from repro.config import SoftmaxPhiConfig
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    b, c, hq, hk, d, s = 2, 4, 4, 2, 32, 64
    kc = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    lens = jnp.asarray([10, 30], jnp.int32)
    q_big = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32) * 50
    out = ops.attention_chunk(
        q_big, kc, vc, lens,
        phi_cfg=SoftmaxPhiConfig(phi=0.0, band=(-1.0, 1.0)))
    safe = ref.attention_chunk_ref(q_big, kc, vc, lens, phi=None)
    # the T1 scheme overflows to inf/nan on these logits, so a finite
    # output close to the safe oracle proves the recompute branch ran
    # (cond-compiled vs eager fusion keeps this from being bitwise)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(safe),
                               rtol=1e-5, atol=1e-5)
    q_small = jnp.asarray(rng.normal(size=(b, c, hq, d)), jnp.float32) * 0.01
    out2 = ops.attention_chunk(
        q_small, kc, vc, lens,
        phi_cfg=SoftmaxPhiConfig(phi=0.0, band=(-40.0, 40.0)))
    t1 = ref.attention_chunk_ref(q_small, kc, vc, lens, phi=0.0)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(t1),
                               rtol=1e-5, atol=1e-5)  # T1 branch kept
