"""Quantized GEMM weight subsystem: per-output-channel round-trips,
in-kernel dequant parity for every GEMM path, the engine-level logits
guard across {split, fused, looped} x {dense, paged+sharing}, and the
bf16 bitwise-identity regression.

The plan's contract (the weight-side twin of test_kvquant.py):
``weight_dtype`` may change the bytes behind every GEMM weight read and
which kernel epilogue runs — never correctness beyond the dtype-derived
tolerance of :func:`repro.kernels.quant.logits_guard_tol`, and the bf16
path must stay bitwise untouched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.core import dispatch
from repro.core.plan import (DEFAULT_PLAN, WEIGHT_DTYPES, ExecutionPlan,
                             PlanError, make_plan)
from repro.kernels import quant, ref
from repro.kernels.flat_gemm import flat_gemm
from repro.kernels.fused_ffn import fused_ffn_up
from repro.kernels.gemv import gemv
from repro.models import wquant

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")

SPECS = [quant.INT8] + ([quant.FP8] if quant.fp8_supported() else [])
SPEC_IDS = [s.name for s in SPECS]


# ---------------------------------------------------------------------------
# quantize-at-load round-trips: per-output-channel algebra
# ---------------------------------------------------------------------------


def _roundtrip_ok(w, spec):
    """quantize_weight -> dequantize_weight within the analytic bound,
    checked per output channel (the step axis)."""
    w = jnp.asarray(w, jnp.float32)
    wq = wquant.quantize_weight(w, spec)
    y = wquant.dequantize_weight(wq)
    # roundtrip_bound works on the step-last layout the encode ran in
    wt = jnp.swapaxes(w, -1, -2)
    bound = quant.roundtrip_bound(wt, wq["scale"], spec)
    err = jnp.abs(jnp.swapaxes(y, -1, -2) - wt)
    assert bool(jnp.all(err <= bound * (1 + 1e-5) + 1e-30)), (
        spec.name, float(jnp.max(err - bound)))
    return y


@given(st.sampled_from(SPECS), st.integers(0, 2 ** 31 - 1))
def test_roundtrip_within_bound(spec, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(24, 16)) * rng.uniform(0.01, 10.0)
    _roundtrip_ok(w, spec)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_outlier_channel_does_not_poison_neighbors(spec):
    """One loud output channel must not inflate the error of the quiet
    ones — that is what per-channel (vs per-tensor) steps buy."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=(32, 8)).astype(np.float32) * 0.1
    w[:, 3] *= 1000.0                       # outlier output channel
    deq = np.asarray(_roundtrip_ok(w, spec))
    quiet = [n for n in range(8) if n != 3]
    err_quiet = np.abs(deq[:, quiet] - w[:, quiet]).max()
    # a per-tensor step would be ~1000x coarser on the quiet channels
    per_tensor_step = np.abs(w).max() / spec.qmax
    assert err_quiet < per_tensor_step / 10


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_all_zero_channel_roundtrips_exactly(spec):
    rng = np.random.default_rng(1)
    w = rng.normal(size=(16, 6)).astype(np.float32)
    w[:, 2] = 0.0
    wq = wquant.quantize_weight(jnp.asarray(w), spec)
    assert bool(jnp.all(wq["codes"][:, 2].astype(jnp.float32) == 0.0))
    deq = np.asarray(wquant.dequantize_weight(wq))
    assert np.all(deq[:, 2] == 0.0)
    assert np.all(np.isfinite(deq))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_stacked_leaves_quantize_per_layer(spec):
    """(L, K, N) leaves get independent per-(layer, channel) steps."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(3, 8, 4)).astype(np.float32)
    w[1] *= 100.0
    wq = wquant.quantize_weight(jnp.asarray(w), spec)
    assert wq["codes"].shape == (3, 8, 4)
    assert wq["scale"].shape == (3, 4)
    per_layer = [wquant.quantize_weight(jnp.asarray(w[i]), spec)
                 for i in range(3)]
    for i in range(3):
        assert bool(jnp.all(wq["codes"][i] == per_layer[i]["codes"]))
        assert bool(jnp.all(wq["scale"][i] == per_layer[i]["scale"]))


def test_quantize_params_touches_only_weight_keys():
    rng = np.random.default_rng(3)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    params = {
        "embedding": mk(32, 8),
        "layers": {"wq": mk(2, 8, 8), "bq": mk(2, 8),
                   "w_up": mk(2, 8, 16), "norm1": mk(2, 8)},
        "lm_head": mk(8, 32),
    }
    out = wquant.quantize_params(params, quant.INT8)
    assert wquant.is_quantized_leaf(out["layers"]["wq"])
    assert wquant.is_quantized_leaf(out["layers"]["w_up"])
    # everything else rides through by identity
    for key in ("embedding", "lm_head"):
        assert out[key] is params[key]
    for key in ("bq", "norm1"):
        assert out["layers"][key] is params["layers"][key]
    # byte accounting: codes + scales, weight keys only
    got = wquant.gemm_weight_bytes(out)
    want = sum(out["layers"][k]["codes"].nbytes
               + out["layers"][k]["scale"].nbytes for k in ("wq", "w_up"))
    assert got == want
    bf16_bytes = wquant.gemm_weight_bytes(params)
    assert bf16_bytes / got > 1.9           # f32 leaves vs int8 codes


# ---------------------------------------------------------------------------
# kernel-vs-oracle parity for every quantized GEMM path
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gemm_case():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    M, K, N = 4, 256, 384
    x = jax.random.normal(ks[0], (M, K), jnp.bfloat16)
    mkw = lambda k: (jax.random.normal(k, (K, N)) * 0.05).astype(jnp.bfloat16)
    return x, mkw(ks[1]), mkw(ks[2]), mkw(ks[3])


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_flat_gemm_quant_matches_oracle(gemm_case, spec):
    x, w, _, _ = gemm_case
    wq = wquant.quantize_weight(w, spec)
    want = ref.flat_gemm_ref(x, wq["codes"], w_scale=wq["scale"])
    got = flat_gemm(x, wq["codes"], w_scale=wq["scale"], interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_gemv_quant_matches_oracle(gemm_case, spec):
    x, w, _, _ = gemm_case
    wq = wquant.quantize_weight(w, spec)
    want = ref.gemv_ref(x[:1], wq["codes"], w_scale=wq["scale"])
    got = gemv(x[:1], wq["codes"], w_scale=wq["scale"], interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_fused_ffn_quant_matches_oracle(gemm_case, spec):
    x, _, wg, wu = gemm_case
    gq = wquant.quantize_weight(wg, spec)
    uq = wquant.quantize_weight(wu, spec)
    want = ref.fused_ffn_up_ref(x, gq["codes"], uq["codes"],
                                wg_scale=gq["scale"], wu_scale=uq["scale"])
    got = fused_ffn_up(x, gq["codes"], uq["codes"], wg_scale=gq["scale"],
                       wu_scale=uq["scale"], interpret=True)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.fixture(scope="module")
def seam_case():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 8)
    B, D, HQ, HK, Dh, F = 2, 128, 4, 2, 32, 256
    mkw = lambda k, *s: (jax.random.normal(k, s) * 0.05).astype(jnp.bfloat16)
    return dict(
        B=B, D=D, HQ=HQ, HK=HK, Dh=Dh, F=F,
        x=jax.random.normal(ks[0], (B, 1, D), jnp.bfloat16),
        ns=(1 + 0.1 * jax.random.normal(ks[1], (D,))).astype(jnp.bfloat16),
        wq=mkw(ks[2], D, HQ * Dh), wk=mkw(ks[3], D, HK * Dh),
        wv=mkw(ks[4], D, HK * Dh), wo=mkw(ks[5], HQ * Dh, D),
        wg=mkw(ks[6], D, F), wu=mkw(ks[7], D, F),
        o=jax.random.normal(ks[5], (B, 1, HQ * Dh), jnp.bfloat16),
        pos=jnp.arange(2, dtype=jnp.int32) + 3,
    )


def _plans():
    mk = lambda be: dataclasses.replace(
        DEFAULT_PLAN, decode_fusion=dataclasses.replace(
            DEFAULT_PLAN.decode_fusion, backend=be))
    return [("pallas", mk("pallas")), ("xla", mk("xla"))]


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_decode_ingest_quant_matches_oracle(seam_case, spec):
    from repro.kernels import ops
    c = seam_case
    Q = lambda w: wquant.quantize_weight(w, spec)
    qq, qk, qv = Q(c["wq"]), Q(c["wk"]), Q(c["wv"])
    want = ref.decode_ingest_ref(
        c["x"], c["ns"], qq["codes"], qk["codes"], qv["codes"], c["pos"],
        num_heads=c["HQ"], num_kv_heads=c["HK"], head_dim=c["Dh"],
        wq_scale=qq["scale"], wk_scale=qk["scale"], wv_scale=qv["scale"])
    for name, plan in _plans():
        got = ops.decode_ingest(
            c["x"], c["ns"], {"codes": qq["codes"], "scale": qq["scale"]},
            {"codes": qk["codes"], "scale": qk["scale"]},
            {"codes": qv["codes"], "scale": qv["scale"]}, c["pos"],
            num_heads=c["HQ"], num_kv_heads=c["HK"], head_dim=c["Dh"],
            plan=plan)
        for a, b in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32),
                                          err_msg=name)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_oproj_residual_quant_matches_oracle(seam_case, spec):
    from repro.kernels import ops
    c = seam_case
    oq = wquant.quantize_weight(c["wo"], spec)
    want = ref.oproj_residual_ref(c["o"], oq["codes"], c["x"],
                                  w_scale=oq["scale"])
    for name, plan in _plans():
        got = ops.oproj_residual(c["o"], oq, c["x"], plan=plan)
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32),
                                      err_msg=name)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_ffn_norm_quant_matches_oracle(seam_case, spec):
    from repro.kernels import ops
    c = seam_case
    gq = wquant.quantize_weight(c["wg"], spec)
    uq = wquant.quantize_weight(c["wu"], spec)
    # the xla path composes the plan's fused_ffn knob; compare per-plan
    for name, plan in _plans():
        want = ref.ffn_norm_ref(c["x"], c["ns"], gq["codes"], uq["codes"],
                                fused=plan.fused_ffn.fused,
                                wg_scale=gq["scale"], wu_scale=uq["scale"])
        got = ops.ffn_norm(c["x"], c["ns"], gq, uq, plan=plan)
        if name == "pallas":
            # fused kernel == fused oracle composition bitwise
            want = ref.ffn_norm_ref(
                c["x"], c["ns"], gq["codes"], uq["codes"], fused=True,
                wg_scale=gq["scale"], wu_scale=uq["scale"])
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32),
                                      err_msg=name)


@pytest.mark.parametrize("spec", SPECS, ids=SPEC_IDS)
def test_quant_gemm_error_vs_bf16_within_bound(gemm_case, spec):
    """The quantized GEMM vs the full-precision GEMM: error bounded by
    the K-summed per-channel round-trip bound (the algebra the epilogue
    scale distributes over the reduction)."""
    x, w, _, _ = gemm_case
    wq = wquant.quantize_weight(w, spec)
    got = np.asarray(
        ref.flat_gemm_ref(x, wq["codes"], w_scale=wq["scale"]), np.float32)
    want = np.asarray(
        jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)), np.float32)
    wt = jnp.swapaxes(w.astype(jnp.float32), -1, -2)
    per_elt = quant.roundtrip_bound(wt, wq["scale"], spec)  # (N, K)
    bound = np.asarray(jnp.abs(x.astype(jnp.float32)) @ per_elt.T)
    # bf16 output rounding of the quantized path adds half-ulp slack
    slack = np.abs(got) * 2.0 ** -8 + 1e-6
    assert np.all(np.abs(got - want) <= bound + slack)


# ---------------------------------------------------------------------------
# plan knob, decision flow, and byte model
# ---------------------------------------------------------------------------


def test_weight_dtype_knob_validates():
    with pytest.raises(PlanError, match="weight_dtype"):
        make_plan(weight_dtype="int3")
    for wd in WEIGHT_DTYPES:
        assert make_plan(weight_dtype=wd).matmul.weight_dtype == wd


def test_plan_json_roundtrip_and_backcompat():
    import json
    p = make_plan(weight_dtype="int8")
    doc = json.loads(p.to_json())
    assert doc["ops"]["matmul"]["weight_dtype"] == "int8"
    assert ExecutionPlan.from_json(p.to_json()).matmul.weight_dtype == "int8"
    # pre-wquant documents load with the bf16 default
    del doc["ops"]["matmul"]["weight_dtype"]
    assert (ExecutionPlan.from_json(json.dumps(doc)).matmul.weight_dtype
            == "bf16")


def test_guard_tol_mirror_matches_quant():
    """dispatch.py is jax-free, so it mirrors logits_guard_tol as plain
    numbers — the mirror must never drift from the kernel-side truth."""
    assert dispatch.WEIGHT_GUARD_TOL["bf16"] == 0.0
    assert (dispatch.WEIGHT_GUARD_TOL["int8"]
            == pytest.approx(quant.logits_guard_tol(quant.INT8)))
    assert (dispatch.WEIGHT_GUARD_TOL["fp8"]
            == pytest.approx(quant.logits_guard_tol(quant.FP8)))
    assert set(dispatch.WEIGHT_DTYPE_BYTES) == set(WEIGHT_DTYPES)


def test_param_bytes_model():
    cfg = configs.get("qwen2-0.5b")
    b = dispatch.param_bytes(cfg, "bf16")
    i = dispatch.param_bytes(cfg, "int8")
    assert b / i >= 1.9                     # codes halve, scales are +4/K
    assert dispatch.param_bytes(cfg, "fp8") == i
    with pytest.raises(KeyError):
        dispatch.param_bytes(cfg, "int3")


def test_find_weight_dtype_decision_flow():
    cfg = configs.get("qwen2-0.5b")
    # unconstrained: the smaller stream wins, int8 ahead of fp8 on ties
    assert dispatch.find_weight_dtype(cfg) == "int8"
    # a zero tolerance budget admits only the bitwise path
    assert dispatch.find_weight_dtype(cfg, tol_budget=0.0) == "bf16"
    # budget between fp8's and int8's guard picks the admissible one
    int8_tol = dispatch.WEIGHT_GUARD_TOL["int8"]
    fp8_tol = dispatch.WEIGHT_GUARD_TOL["fp8"]
    assert fp8_tol > int8_tol
    mid = (int8_tol + fp8_tol) / 2
    assert dispatch.find_weight_dtype(cfg, tol_budget=mid) == "int8"
    with pytest.raises(ValueError):
        dispatch.find_weight_dtype(cfg, candidates=("int3",))


def test_flat_gemm_roofline_shrinks_with_weight_dtype():
    t_bf = dispatch.predict_flat_gemm_time(1, 4096, 4096)
    t_i8 = dispatch.predict_flat_gemm_time(1, 4096, 4096,
                                           weight_dtype="int8")
    assert t_i8 < t_bf
    # bf16 path must equal the existing FLAT_GEMM roofline exactly
    assert t_bf == dispatch.predict_time(dispatch.Impl.FLAT_GEMM,
                                         1, 4096, 4096)


def test_tune_threads_weight_dtype():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    from repro.core import plan as plan_mod
    assert plan_mod.tune(cfg).matmul.weight_dtype == "bf16"  # default
    assert (plan_mod.tune(cfg, weight_dtype="int8").matmul.weight_dtype
            == "int8")
    assert (plan_mod.tune(cfg, weight_dtype=None).matmul.weight_dtype
            == dispatch.find_weight_dtype(cfg))


# ---------------------------------------------------------------------------
# engine-level guard + bf16 bitwise regression
# ---------------------------------------------------------------------------


_PAGE = 16


@pytest.fixture(scope="module")
def smoke_model():
    from repro.models.api import get_model
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, api, params


def _mk_engine(cfg, params, weight_dtype, *, fusion="split", kind="dense",
               sharing=False):
    from repro.serving.engine import Engine
    kw = {}
    if kind == "paged":
        kw.update(page_size=_PAGE, prefill_chunk=_PAGE,
                  prefix_sharing=sharing)
    return Engine(cfg, params, num_slots=3, max_seq=128, cache_kind=kind,
                  weight_dtype=weight_dtype, decode_fusion=fusion, seed=0,
                  **kw)


def _prompts(cfg, sharing):
    rng = np.random.default_rng(11)
    if sharing:
        head = rng.integers(1, cfg.vocab_size, size=2 * _PAGE).astype(
            np.int32)
        return [np.concatenate([head, rng.integers(
            1, cfg.vocab_size, size=_PAGE).astype(np.int32)])
            for _ in range(3)]
    return [rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
            for _ in range(3)]


def _probe_logits(eng, api, prompts):
    """Admit + prefill only, then one teacher-forced decode step through
    the engine's own plan — identical token stream across precisions."""
    from repro.models.layers import LayerCtx
    from repro.serving.request import SamplingParams
    sp = SamplingParams(max_new_tokens=4, temperature=0.0)
    for p in prompts:
        eng.submit(p.copy(), sp)
    eng._admit()
    assert len(eng.by_slot) == len(prompts)
    rows = sorted(eng.by_slot)
    ctx = LayerCtx(cfg=eng.cfg, plan=eng.plan)
    toks = jnp.arange(1, eng.num_slots + 1, dtype=jnp.int32)
    logits, _ = api.decode_step(
        ctx, eng.params, toks, eng.cache,
        jnp.asarray(eng.slots.lengths(), jnp.int32),
        block_tables=(eng.slots.block_tables() if eng.pool is not None
                      else None))
    return np.asarray(logits, np.float32)[rows]


@pytest.mark.parametrize("kind,sharing",
                         [("dense", False), ("paged", True)],
                         ids=["dense", "paged+shared"])
@pytest.mark.parametrize("fusion", ["split", "fused", "looped"])
def test_quant_logits_within_guard(smoke_model, fusion, kind, sharing):
    """Teacher-forced decode logits under weight_dtype=int8 (and fp8
    where supported) stay within the dtype-derived guard vs the bf16
    baseline, across the full granularity x cache matrix."""
    cfg, api, params = smoke_model
    prompts = _prompts(cfg, sharing)
    out = {}
    for wd in ["bf16"] + SPEC_IDS:
        eng = _mk_engine(cfg, params, wd, fusion=fusion, kind=kind,
                         sharing=sharing)
        out[wd] = _probe_logits(eng, api, prompts)
    scale = max(float(np.abs(out["bf16"]).max()), 1.0)
    for s in SPECS:
        atol = quant.logits_guard_tol(s) * scale
        np.testing.assert_allclose(out[s.name], out["bf16"], atol=atol,
                                   rtol=0)


@pytest.mark.parametrize("kind", ["dense", "paged"])
@pytest.mark.parametrize("fusion", ["split", "fused", "looped"])
def test_bf16_greedy_bitwise_unchanged(smoke_model, fusion, kind):
    """weight_dtype='bf16' must be a no-op: greedy tokens identical to
    an engine that never heard of the knob (weight_dtype=None with a
    default-plan bf16 knob) for every granularity and cache kind."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    prompts = _prompts(cfg, False)
    sp = SamplingParams(max_new_tokens=5, temperature=0.0)
    reqs = [(p.copy(), sp) for p in prompts]
    explicit = _mk_engine(cfg, params, "bf16", fusion=fusion, kind=kind)
    implicit = _mk_engine(cfg, params, None, fusion=fusion, kind=kind)
    assert implicit.weight_dtype == "bf16"
    assert explicit.run(reqs) == implicit.run(reqs)


def test_quant_greedy_runs_to_length(smoke_model):
    """int8 engines decode to full length on every granularity (the
    looped scan-body traces over (codes, scale) dict leaves). Bitwise
    identity across granularities is a bf16-only contract — quantized
    granularities are only held to the shared logits guard, which
    test_quant_logits_within_guard covers."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    sp = SamplingParams(max_new_tokens=5, temperature=0.0)
    reqs = [(p.copy(), sp) for p in _prompts(cfg, False)]
    for fusion in ("split", "fused", "looped"):
        eng = _mk_engine(cfg, params, "int8", fusion=fusion)
        outs = eng.run(reqs)
        assert all(len(v) == 5 for v in outs.values()), fusion


def test_engine_weight_byte_accounting(smoke_model):
    """weight_bytes_decode_read counts true scale-inclusive stored bytes
    per tick; int8 shrinks the stream >= 1.9x vs bf16."""
    from repro.serving.request import SamplingParams
    cfg, api, params = smoke_model
    sp = SamplingParams(max_new_tokens=4, temperature=0.0)
    reqs = [(p.copy(), sp) for p in _prompts(cfg, False)]
    per_tick, read = {}, {}
    for wd in ("bf16", "int8"):
        eng = _mk_engine(cfg, params, wd)
        eng.run(reqs)
        per_tick[wd] = eng._weight_bytes_per_tick
        read[wd] = eng.stats.weight_bytes_decode_read
        assert wquant.gemm_weight_bytes(eng.params) == per_tick[wd]
        assert read[wd] == per_tick[wd] * eng.ticks
    assert per_tick["bf16"] / per_tick["int8"] >= 1.9
    assert read["bf16"] / read["int8"] >= 1.9


def test_engine_rejects_bad_weight_dtype(smoke_model):
    cfg, api, params = smoke_model
    with pytest.raises(ValueError, match="weight_dtype"):
        _mk_engine(cfg, params, "int3")


def test_engine_fp8_gate(smoke_model, monkeypatch):
    cfg, api, params = smoke_model
    monkeypatch.setattr(quant, "fp8_supported", lambda: False)
    with pytest.raises(ValueError, match="fp8"):
        _mk_engine(cfg, params, "fp8")


def test_engine_adopts_plan_weight_dtype(smoke_model):
    """No explicit arg: the plan's tuned matmul.weight_dtype rides in,
    and the resolved value lands back in eng.plan."""
    from repro.serving.engine import Engine
    cfg, api, params = smoke_model
    plan = make_plan(weight_dtype="int8")
    eng = Engine(cfg, params, num_slots=2, max_seq=64, plan=plan)
    assert eng.weight_dtype == "int8"
    assert eng.plan.matmul.weight_dtype == "int8"
    assert wquant.is_quantized_leaf(eng.params["layers"]["attn"]["wq"])
    assert wquant.is_quantized_leaf(eng.params["layers"]["mlp"]["w_up"])
    # explicit override beats the plan
    eng2 = Engine(cfg, params, num_slots=2, max_seq=64, plan=plan,
                  weight_dtype="bf16")
    assert eng2.weight_dtype == "bf16"
    assert eng2.plan.matmul.weight_dtype == "bf16"


def test_describe_mentions_weight_dtype():
    assert "w=int8" in make_plan(weight_dtype="int8").describe()
    assert "w=" not in make_plan().describe()


# ---------------------------------------------------------------------------
# benchmark smoke
# ---------------------------------------------------------------------------


def test_weight_quant_bench_smoke(tmp_path, monkeypatch):
    from benchmarks import weight_quant
    monkeypatch.setattr(weight_quant, "OUT_PATH",
                        str(tmp_path / "BENCH_wquant.json"))
    result = weight_quant.run(quick=True)
    assert result["weight_bytes_per_tick"]["bf16"] > 0
    assert result["byte_reduction"]["int8"] >= 1.9
    assert result["footprint_reduction"]["int8"] >= 1.9
    assert (result["max_abs_dlogits"]["int8"]
            <= result["guard_atol"]["int8"])
