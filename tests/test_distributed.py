"""Distribution layer: sharding rules (pure), int8-EF quantizer math
(hypothesis), and subprocess tests that claim 8 placeholder devices for the
real collective/pipeline/sharded-train paths (device count is locked at
first jax init, so multi-device coverage runs in child processes)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.config import MULTI_POD, SINGLE_POD, MeshConfig
from repro.distributed import collectives as C
from repro.distributed import shardmap_compat
from repro.distributed.sharding import make_rules
from repro.models.api import get_model

# The old-jax (0.4.x) XLA SPMD partitioner dies in a CHECK
# (IsManualSubgroup) on partial-manual shard_map — a process ABORT that
# would kill the whole pytest run, so the tests whose collectives need
# partial-manual mode are version-gated rather than allowed to fail.
needs_partial_manual = pytest.mark.skipif(
    not shardmap_compat.PARTIAL_MANUAL_OK,
    reason=shardmap_compat.PARTIAL_MANUAL_REASON)

settings.register_profile("fast", max_examples=20, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# Sharding rules (pure functions of shapes — no devices needed)
# ---------------------------------------------------------------------------


def test_param_specs_megatron_orientation():
    rules = make_rules(SINGLE_POD)
    # col: output over model, input over data-FSDP
    assert rules.param_spec(("layers", "attn", "wq"), (24, 896, 896)) == \
        P(None, ("data",), "model")
    # row: input over model
    assert rules.param_spec(("layers", "attn", "wo"), (24, 896, 896)) == \
        P(None, "model", ("data",))
    # rwkv channel-mix down-proj is context-sensitive (row)
    assert rules.param_spec(("layers", "cm", "w_v"), (24, 7168, 2048)) == \
        P(None, "model", ("data",))
    # norm scales replicated
    assert rules.param_spec(("layers", "attn_norm", "scale"),
                            (24, 896)) == P(None, None)


def test_param_specs_drop_nondivisible_axes():
    rules = make_rules(MULTI_POD)
    # hymba w_dt: hm=50 not divisible by 16 -> replicated output
    spec = rules.param_spec(("layers", "ssm", "w_dt"), (32, 1600, 50))
    assert spec == P(None, ("pod", "data"), None)


def test_every_assigned_arch_params_get_specs():
    """param_spec_tree covers every leaf of every architecture."""
    for mesh_cfg in (SINGLE_POD, MULTI_POD):
        rules = make_rules(mesh_cfg)
        sizes = rules.axis_sizes
        for arch in configs.ASSIGNED:
            cfg = configs.get(arch)
            api = get_model(cfg)
            params = jax.eval_shape(
                lambda api=api: api.init_params(jax.random.PRNGKey(0)))
            specs = rules.param_spec_tree(params)
            for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda s: isinstance(s, P)),
            ):
                assert len(spec) <= len(leaf.shape), (arch, path)
                for dim, entry in zip(leaf.shape, spec):
                    if entry is None:
                        continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    nshards = int(np.prod([sizes[a] for a in axes]))
                    assert dim % nshards == 0, (arch, path, spec)


def test_tp_sharded_fraction_is_high_for_big_archs():
    """The FSDP+TP rules must actually shard the big models' bytes —
    grok-1 at (2,16,16) must fit 16 GB/chip with headroom."""
    rules = make_rules(MULTI_POD)
    sizes = rules.axis_sizes
    for arch in ("grok-1-314b", "deepseek-67b", "internvl2-76b"):
        cfg = configs.get(arch)
        api = get_model(cfg)
        params = jax.eval_shape(
            lambda api=api: api.init_params(jax.random.PRNGKey(0)))
        specs = rules.param_spec_tree(params)
        per_dev = 0
        for (_, leaf), (_, spec) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, P)),
        ):
            n = 1
            for entry in spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                n *= int(np.prod([sizes[a] for a in axes]))
            per_dev += leaf.size * 2 // n   # bf16
        assert per_dev < 4 * 2**30, (arch, per_dev / 2**30)


def test_act_specs_adapt_to_rank_and_divisibility():
    rules = make_rules(SINGLE_POD)
    assert rules.act_spec("act_ffn", (256, 128, 4864)) == \
        P(("data",), None, "model")
    assert rules.act_spec("act_resid", (1, 64, 896)) == P(None, None, None)
    assert rules.act_spec("act_scores_decode", (128, 14, 32768)) == \
        P(("data",), None, "model")
    assert rules.act_spec("act_cache_slice", (128, 32768, 2, 64)) == \
        P(("data",), "model", None, None)


def test_cache_spec_seq_sharding():
    rules = make_rules(SINGLE_POD)
    # dense KV cache (L, B, S, H, Dh): batch over data, seq over model
    assert rules.cache_spec((24, 128, 32768, 2, 64)) == \
        P(None, ("data",), "model", None, None)
    # rwkv state (L, B, H, N, N): H=32 divisible -> over model at dim 2
    assert rules.cache_spec((24, 128, 32, 64, 64)) == \
        P(None, ("data",), "model", None, None)
    # disabled seq sharding
    rules2 = make_rules(SINGLE_POD, seq_shard_kv=False)
    assert rules2.cache_spec((24, 128, 32768, 2, 64)) == \
        P(None, ("data",), None, None, None)


# ---------------------------------------------------------------------------
# int8 + error-feedback quantizer (pure math)
# ---------------------------------------------------------------------------


@given(st.integers(0, 1000))
def test_quantize_roundtrip_bounded_error(seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=64) * 10,
                    jnp.float32)
    q, scale = C.quantize_int8(x)
    err = np.abs(np.asarray(C.dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    g = jnp.full((128,), 0.004567, jnp.float32)
    e = jnp.zeros_like(g)
    tot = 0.0
    for _ in range(100):
        q, s, e = C.ef_quantize_leaf(g, e)
        tot += float(C.dequantize_int8(q, s).sum())
    exact = 100 * float(g.sum())
    assert abs(tot - exact) / abs(exact) < 1e-3


def test_pack_unpack_i8_roundtrip():
    for n in (4, 7, 64, 129):
        q = jnp.asarray(
            np.random.default_rng(n).integers(-127, 128, size=n), jnp.int8)
        words, pad = C._pack_i8(q)
        assert words.dtype == jnp.int32
        back = C._unpack_i8(words, q.shape, pad)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


# ---------------------------------------------------------------------------
# Multi-device paths (subprocess: 8 placeholder devices)
# ---------------------------------------------------------------------------

_SUB_ENV = dict(
    os.environ,
    XLA_FLAGS="--xla_force_host_platform_device_count=8 "
              "--xla_disable_hlo_passes=all-reduce-promotion",
    PYTHONPATH=os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + os.environ.get("PYTHONPATH", "").split(os.pathsep)),
)


def _run_sub(code: str) -> str:
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=_SUB_ENV, capture_output=True, text=True, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
@needs_partial_manual
def test_crosspod_allreduce_int8_multidevice():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import collectives as C
        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        grads = {"a": jnp.stack([jnp.full((4,8), 1.0), jnp.full((4,8), 2.0)])}
        err = C.zeros_error_state({"a": grads["a"][0]}, npods=2)
        out, new_err = C.crosspod_allreduce_int8(mesh, grads, err)
        np.testing.assert_allclose(out["a"][0], 1.5, rtol=2e-2)
        np.testing.assert_allclose(out["a"][1], 1.5, rtol=2e-2)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
@needs_partial_manual
def test_pipeline_forward_and_grad_multidevice():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import pipeline as PP
        mesh = jax.make_mesh((2,2,2), ("pod","data","model"))
        lp = {"w": jnp.arange(4, dtype=jnp.float32).reshape(4, 1) + 1.0}
        staged = PP.split_stages(lp, 2)
        def stage_fn(p, x):
            for i in range(p["w"].shape[0]):
                x = x + p["w"][i]
            return x
        xs = jnp.zeros((4, 2, 1))
        out = PP.pipeline_forward(mesh, staged, xs, stage_fn)
        np.testing.assert_allclose(out, 10.0)
        g = jax.grad(lambda sp: PP.pipeline_forward(
            mesh, sp, xs, stage_fn).sum())(staged)
        np.testing.assert_allclose(np.asarray(g["w"]).ravel(), 8.0)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_sharded_train_step_multidevice_matches_single():
    """The 4x2-sharded train step must produce the same loss trajectory as
    the single-device step (SPMD is semantics-preserving)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.config import MeshConfig, RunConfig, ShapeConfig
        from repro.distributed.sharding import make_rules, make_shard_fn, named
        from repro.launch.mesh import make_mesh_from_config
        from repro.models.api import get_model, make_synthetic_batch, train_input_specs
        from repro.models.layers import LayerCtx
        from repro.training.train_state import TrainState, make_train_step
        from jax.sharding import PartitionSpec as P

        cfg = configs.smoke(configs.get("qwen2-0.5b"))
        shape = ShapeConfig("t", 32, 8, "train")
        run = RunConfig(learning_rate=1e-3, warmup_steps=1)
        api = get_model(cfg)
        batch = make_synthetic_batch(cfg, shape, jax.random.PRNGKey(1))
        params = api.init_params(jax.random.PRNGKey(0))

        # single-device reference
        ctx0 = LayerCtx(cfg=cfg)
        step0 = jax.jit(make_train_step(api, ctx0, run))
        s0 = TrainState.create(params)
        losses0 = []
        for _ in range(3):
            s0, m = step0(s0, batch)
            losses0.append(float(m["loss"]))

        mesh_cfg = MeshConfig((4, 2), ("data", "model"))
        mesh = make_mesh_from_config(mesh_cfg)
        rules = make_rules(mesh_cfg)
        ctx = LayerCtx(cfg=cfg, shard=make_shard_fn(mesh, rules))
        step = make_train_step(api, ctx, run, mesh=mesh)
        state = TrainState.create(params)
        pspec = rules.param_spec_tree(state.params)
        sspec = TrainState(step=P(), params=pspec, m=pspec, v=pspec,
                           ef_err=None)
        bspec = rules.input_specs_tree(train_input_specs(cfg, shape))
        fn = jax.jit(step, in_shardings=(named(mesh, sspec),
                                         named(mesh, bspec)),
                     out_shardings=(named(mesh, sspec), None))
        losses = []
        for _ in range(3):
            state, m = fn(state, batch)
            losses.append(float(m["loss"]))
        np.testing.assert_allclose(losses, losses0, rtol=2e-3)
        print("PASS", losses)
    """)
    assert "PASS" in out


@pytest.mark.slow
def test_seq_sharded_decode_matches_single():
    """Split-KV decode (cache sequence over `model`) must equal the
    unsharded decode — T1's additive combine is exact."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.config import MeshConfig
        from repro.distributed.sharding import make_rules, make_shard_fn, named
        from repro.launch.mesh import make_mesh_from_config
        from repro.models.api import get_model
        from repro.models.kvlayout import DenseLayout
        from repro.models.layers import LayerCtx

        cfg = configs.smoke(configs.get("qwen2-0.5b"))
        api = get_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        cache = api.init_cache(DenseLayout(4, 128))
        toks = jnp.array([1, 2, 3, 4], jnp.int32)
        lens = jnp.array([7, 60, 100, 13], jnp.int32)
        # warm the cache with junk KV so attention reads something real
        cache = jax.tree.map(
            lambda c: c + 0.01 * jax.random.normal(
                jax.random.PRNGKey(9), c.shape, c.dtype), cache)

        ctx0 = LayerCtx(cfg=cfg)
        logits0, _ = api.decode_step(ctx0, params, toks, cache, lens)

        mesh_cfg = MeshConfig((2, 4), ("data", "model"))
        mesh = make_mesh_from_config(mesh_cfg)
        rules = make_rules(mesh_cfg)  # seq_shard_kv=True
        ctx = LayerCtx(cfg=cfg, shard=make_shard_fn(mesh, rules))
        cspec = jax.tree.map(lambda c: rules.cache_spec(c.shape), cache)
        fn = jax.jit(lambda p, t, c, l: api.decode_step(ctx, p, t, c, l),
                     in_shardings=(None, None, named(mesh, cspec), None))
        logits1, _ = fn(params, toks, cache, lens)
        np.testing.assert_allclose(
            np.asarray(logits0, np.float32), np.asarray(logits1, np.float32),
            rtol=3e-2, atol=3e-2)
        print("PASS")
    """)
    assert "PASS" in out


@pytest.mark.slow
@needs_partial_manual
def test_split_kv_decode_attention_collective_claim():
    """The paper's T1 claim at pod scale: the async (unified-max) combine
    needs exactly ONE all-reduce per decode-attention call; the
    synchronized (online-max) combine needs TWO (max exchange + rescaled
    num/den). Verified on the compiled HLO of the explicit shard_map
    artifact, plus exactness of both against the unsharded oracle."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import SoftmaxPhiConfig
        from repro.core.attention import decode_attention_sharded
        from repro.kernels import ref
        from repro.analysis import hlo as H

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        b, hq, hk, d, s = 4, 8, 2, 64, 512
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
        kc = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
        vc = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
        lengths = jnp.array([100, 512, 7, 300], jnp.int32)
        want = ref.attention_decode_ref(q, kc, vc, lengths)

        counts = {}
        for name, cfgp in [("async", SoftmaxPhiConfig(phi=0.0)),
                           ("sync", SoftmaxPhiConfig(enabled=False))]:
            f = jax.jit(lambda q_, k_, v_, l_: decode_attention_sharded(
                mesh, q_, k_, v_, l_, phi_cfg=cfgp))
            np.testing.assert_allclose(f(q, kc, vc, lengths), want,
                                       rtol=1e-4, atol=1e-5)
            comp = f.lower(q, kc, vc, lengths).compile()
            counts[name] = H.parse_collectives(comp.as_text()).counts
        assert counts["async"].get("all-reduce", 0) == 1, counts
        assert counts["sync"].get("all-reduce", 0) == 2, counts
        print("PASS", counts)
    """)
    assert "PASS" in out


@pytest.mark.slow
@needs_partial_manual
def test_manual_moe_dispatch_matches_gspmd():
    """_moe_block_manual (dispatch locality by construction) must equal
    the plain GSPMD path in loss AND gradients."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import configs
        from repro.config import MeshConfig, ShapeConfig
        from repro.distributed.sharding import make_rules, make_shard_fn
        from repro.launch.mesh import make_mesh_from_config
        from repro.models.api import get_model, make_synthetic_batch
        from repro.models.layers import LayerCtx

        cfg = configs.smoke(configs.get("dbrx-132b"))
        api = get_model(cfg)
        params = api.init_params(jax.random.PRNGKey(0))
        batch = make_synthetic_batch(cfg, ShapeConfig("t", 64, 4, "train"),
                                     jax.random.PRNGKey(1))
        # same group count on both sides: routing/capacity are per-group
        ctx0 = LayerCtx(cfg=cfg, moe_groups=2)
        l0, g0 = jax.value_and_grad(
            lambda p: api.train_loss(ctx0, p, batch))(params)

        mesh_cfg = MeshConfig((2, 4), ("data", "model"))
        mesh = make_mesh_from_config(mesh_cfg)
        rules = make_rules(mesh_cfg)
        ctx1 = LayerCtx(cfg=cfg, shard=make_shard_fn(mesh, rules),
                        mesh=mesh, rules=rules, moe_groups=2)
        l1, g1 = jax.jit(jax.value_and_grad(
            lambda p: api.train_loss(ctx1, p, batch)))(params)
        np.testing.assert_allclose(float(l0), float(l1), rtol=2e-3)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=5e-2, atol=5e-3)
        print("PASS")
    """)
    assert "PASS" in out
