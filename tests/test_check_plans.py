"""tools/check_plans.py wired into tier-1: the committed plans must lint
clean, and the linter must actually catch the staleness classes it
advertises (a linter that passes everything protects nothing)."""
import dataclasses
import json
import os
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "tools"))

import check_plans  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import plan as plan_mod  # noqa: E402

PLANS = sorted(
    os.path.join(_ROOT, "plans", p)
    for p in os.listdir(os.path.join(_ROOT, "plans"))
    if p.endswith(".json")
)


def test_committed_plans_exist():
    assert PLANS, "plans/ must ship tuned artifacts"


@pytest.mark.parametrize("path", PLANS, ids=os.path.basename)
def test_committed_plan_lints_clean(path):
    assert check_plans.check_plan(path) == []


def test_cli_green_on_committed_plans(capsys):
    assert check_plans.main([]) == 0
    assert "ok" in capsys.readouterr().out


def _mutate(tmp_path, mutate, name=None):
    """Copy the first committed plan, apply ``mutate`` to its dict."""
    doc = json.load(open(PLANS[0]))
    mutate(doc)
    p = tmp_path / (name or os.path.basename(PLANS[0]))
    p.write_text(json.dumps(doc))
    return str(p)


def test_catches_wrong_version(tmp_path):
    def m(doc):
        doc["version"] = plan_mod.PLAN_VERSION + 1
        doc["provenance"]["version"] = plan_mod.PLAN_VERSION + 1
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("version" in f for f in findings)


def test_catches_stale_config_hash(tmp_path):
    def m(doc):
        doc["provenance"]["config"] = "0" * 12
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("stale config hash" in f for f in findings)


def test_catches_stale_hardware_hash(tmp_path):
    def m(doc):
        doc["provenance"]["hardware"] = "0" * 12
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("stale hardware hash" in f for f in findings)


def test_catches_unknown_hardware(tmp_path):
    def m(doc):
        doc["provenance"]["hardware_name"] = "tpu-v9"
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("not a known HardwareSpec" in f for f in findings)


def test_catches_missing_kv_dtype(tmp_path):
    def m(doc):
        del doc["ops"]["paged"]["kv_dtype"]
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("kv_dtype" in f for f in findings)


def test_catches_invalid_knob_value(tmp_path):
    def m(doc):
        doc["ops"]["paged"]["kv_dtype"] = "int3"
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert findings and any("schema" in f for f in findings)


def test_catches_missing_weight_dtype(tmp_path):
    def m(doc):
        del doc["ops"]["matmul"]["weight_dtype"]
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("weight_dtype" in f for f in findings)


def test_catches_invalid_weight_dtype(tmp_path):
    def m(doc):
        doc["ops"]["matmul"]["weight_dtype"] = "int3"
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert findings and any("schema" in f for f in findings)


def test_catches_missing_decode_fusion(tmp_path):
    def m(doc):
        del doc["ops"]["decode_fusion"]
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("decode_fusion" in f for f in findings)


def test_catches_invalid_fusion_granularity(tmp_path):
    def m(doc):
        doc["ops"]["decode_fusion"]["granularity"] = "megakernel"
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert findings and any("schema" in f for f in findings)


def test_catches_missing_provenance(tmp_path):
    def m(doc):
        del doc["provenance"]
    findings = check_plans.check_plan(_mutate(tmp_path, m))
    assert any("provenance" in f for f in findings)


def test_catches_wrong_filename(tmp_path):
    findings = check_plans.check_plan(
        _mutate(tmp_path, lambda doc: None, name="renamed.json"))
    assert any("filename" in f for f in findings)


def test_current_registry_is_consistent():
    """The linter's own premise: every named spec hashes to itself and
    every committed provenance names a real config."""
    specs = check_plans._hardware_registry()
    assert "tpu-v5e" in specs
    for path in PLANS:
        doc = json.load(open(path))
        prov = doc["provenance"]
        assert prov["hardware_name"] in specs
        configs.get(prov["config_name"])   # must not raise
