"""Training substrate: optimizer math, grad accumulation, checkpointing
(torn-write safety, bf16 roundtrip), loop restart + preemption."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.models.api import get_model, make_synthetic_batch
from repro.models.layers import LayerCtx
from repro.training import optimizer as opt
from repro.training.checkpoint import CheckpointManager
from repro.training.loop import train_loop
from repro.training.train_state import TrainState, make_train_step

settings.register_profile("fast", max_examples=15, deadline=None)
settings.load_profile("fast")


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_closed_form():
    cfg = opt.AdamWConfig(learning_rate=0.1, beta1=0.9, beta2=0.99,
                          eps=1e-8, weight_decay=0.0, clip_norm=0.0,
                          warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.array([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.array([[0.5, 0.25]], jnp.float32)}
    m, v = opt.adamw_init(p)
    new_p, new_m, new_v, _ = opt.adamw_update(
        cfg, p, g, m, v, jnp.zeros((), jnp.int32))
    gm = np.asarray(g["w"])
    want_m = 0.1 * gm
    want_v = 0.01 * gm * gm
    mhat = want_m / (1 - 0.9)
    vhat = want_v / (1 - 0.99)
    want_p = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want_p, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_m["w"]), want_m, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new_v["w"]), want_v, rtol=1e-6)


@given(st.floats(min_value=0.1, max_value=10.0))
def test_clip_by_global_norm(scale):
    g = {"a": jnp.full((4,), scale, jnp.float32),
         "b": jnp.full((4,), -scale, jnp.float32)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    total = float(opt.global_norm(clipped))
    np.testing.assert_allclose(float(gn), scale * np.sqrt(8), rtol=1e-5)
    assert total <= 1.0 + 1e-5


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                          total_steps=100, min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # warmup done
    assert 0.1 < lrs[3] < 1.0                # cosine decaying
    assert abs(lrs[4] - 0.1) < 1e-6          # floor
    assert abs(lrs[5] - 0.1) < 1e-6          # clamped past total


def test_weight_decay_applies_to_matrices_only():
    cfg = opt.AdamWConfig(learning_rate=1.0, weight_decay=0.5,
                          clip_norm=0.0, warmup_steps=0, total_steps=10**9)
    p = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "scale": jnp.zeros((2,))}
    m, v = opt.adamw_init(p)
    new_p, *_ = opt.adamw_update(cfg, p, g, m, v, jnp.zeros((), jnp.int32))
    assert float(new_p["w"][0, 0]) < 1.0     # decayed
    assert float(new_p["scale"][0]) == 1.0   # norm gains never decayed


# ---------------------------------------------------------------------------
# Gradient accumulation
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_microbatch_accumulation_matches_full_batch():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    ctx = LayerCtx(cfg=cfg)
    shape = ShapeConfig("t", 32, 4, "train")
    batch = make_synthetic_batch(cfg, shape, jax.random.PRNGKey(1))
    params = api.init_params(jax.random.PRNGKey(0))
    state = TrainState.create(params)

    run_full = RunConfig(microbatch=0, learning_rate=0.0, warmup_steps=0)
    run_mb = RunConfig(microbatch=4, learning_rate=0.0, warmup_steps=0)
    s1, m1 = jax.jit(make_train_step(api, ctx, run_full))(state, batch)
    s2, m2 = jax.jit(make_train_step(api, ctx, run_mb))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-4)   # accumulation-order noise
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=1e-3)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _toy_state():
    return TrainState.create({
        "w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "b": jnp.ones((3,), jnp.float32),
    })


def test_checkpoint_roundtrip_including_bf16():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        state = _toy_state()
        mgr.save(7, state, blocking=True)
        assert mgr.latest_step() == 7
        restored = mgr.load_state(7, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_torn_checkpoint_invisible():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        mgr.save(5, _toy_state(), blocking=True)
        # simulate a crash mid-write: step dir without COMMIT
        os.makedirs(os.path.join(d, "step_000009"))
        assert mgr.latest_step() == 5


def test_checkpoint_gc_keeps_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _toy_state(), blocking=True)
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                       if n.startswith("step_"))
        assert steps == [3, 4]


def test_async_save_overlaps_and_waits():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(1, _toy_state())     # async
        mgr.save(2, _toy_state())     # waits for 1, then async
        mgr.wait()
        assert mgr.latest_step() == 2


# ---------------------------------------------------------------------------
# Loop: restart + preemption + determinism
# ---------------------------------------------------------------------------


def _loop_fixture(tmp, total):
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    shape = ShapeConfig("t", 32, 2, "train")
    run = RunConfig(total_steps=total, checkpoint_every=4,
                    learning_rate=1e-3, checkpoint_dir=tmp, warmup_steps=2)
    api = get_model(cfg)
    ctx = LayerCtx(cfg=cfg)
    step = jax.jit(make_train_step(api, ctx, run))

    def init():
        return TrainState.create(api.init_params(jax.random.PRNGKey(0)))

    return cfg, shape, run, step, init


@pytest.mark.slow
def test_loop_restart_resumes_and_matches_uninterrupted():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        cfg, shape, run1, step, init = _loop_fixture(d1, total=8)
        # interrupted at 4 then resumed
        r1 = train_loop(model_cfg=cfg, shape=shape, run=run1,
                        train_step=step, init_state=init, max_steps=4,
                        log_every=0, install_signals=False)
        r2 = train_loop(model_cfg=cfg, shape=shape, run=run1,
                        train_step=step, init_state=init, max_steps=8,
                        log_every=0, install_signals=False)
        assert r2.restored_from == 4 and r2.final_step == 8
        # uninterrupted reference
        cfg, shape, run2, step2, init2 = _loop_fixture(d2, total=8)
        r3 = train_loop(model_cfg=cfg, shape=shape, run=run2,
                        train_step=step2, init_state=init2, max_steps=8,
                        log_every=0, install_signals=False)
        # deterministic data + deterministic math: identical loss trajectory
        np.testing.assert_allclose(r1.losses + r2.losses, r3.losses,
                                   rtol=1e-5)


@pytest.mark.slow
def test_loop_preemption_checkpoints_and_exits():
    with tempfile.TemporaryDirectory() as d:
        cfg, shape, run, step, init = _loop_fixture(d, total=100)
        res = train_loop(model_cfg=cfg, shape=shape, run=run,
                         train_step=step, init_state=init,
                         log_every=0, install_signals=False,
                         preempt_after=3)
        assert res.preempted
        mgr = CheckpointManager(d)
        assert mgr.latest_step() == res.final_step == 3


def test_data_pipeline_determinism_and_host_sharding():
    from repro.training.data import SyntheticTokens
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    shape = ShapeConfig("t", 16, 8, "train")
    a = SyntheticTokens(cfg, shape, seed=3).batch_at(11)
    b = SyntheticTokens(cfg, shape, seed=3).batch_at(11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = SyntheticTokens(cfg, shape, seed=4).batch_at(11)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # label stream is the shifted token stream
    h0 = SyntheticTokens(cfg, shape, seed=3, host_index=0, host_count=2)
    h1 = SyntheticTokens(cfg, shape, seed=3, host_index=1, host_count=2)
    b0, b1 = h0.batch_at(5), h1.batch_at(5)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_prefetcher_orders_and_closes():
    from repro.training.data import Prefetcher, SyntheticTokens
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    shape = ShapeConfig("t", 16, 2, "train")
    pf = Prefetcher(SyntheticTokens(cfg, shape), start_step=3)
    try:
        for want in (3, 4, 5):
            step, batch = pf.next()
            assert step == want
            assert batch["tokens"].shape == (2, 16)
    finally:
        pf.close()
