"""Tiered KV hierarchy: TieredPool store semantics (capacity, LRU spill,
disk round-trip, true eviction), cross-tier PrefixIndex lifecycle
(demoted entries stay matchable, only bottom-tier eviction purges),
session-cache manager dataflow (retain -> reclaim/demote -> promote at
re-admission, swap-threshold truncation), and the engine-level acceptance
bar — greedy outputs bit-identical for resumed-from-demoted vs
re-prefilled vs never-preempted sequences (dense vs paged too), through
host and disk tiers and through the eviction fallback."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models.api import get_model
from repro.serving.blockpool import BlockPool, PagedSlotManager
from repro.serving.engine import Engine
from repro.serving.prefix import PrefixIndex
from repro.serving.request import SamplingParams
from repro.serving.tiers import TieredPool


# ---------------------------------------------------------------------------
# TieredPool store semantics (no jax, dummy slabs)
# ---------------------------------------------------------------------------


def _slab(tag):
    """A dummy page slab: content identity matters, structure does not."""
    return (np.full((2, 4), tag, np.float32), np.full((2, 4), -tag, np.int32))


def test_tiered_pool_rejects_bad_capacities(tmp_path):
    with pytest.raises(ValueError, match=">= 0"):
        TieredPool(-1)
    with pytest.raises(ValueError, match="disk_dir"):
        TieredPool(4, disk_pages=2)          # disk capacity without a dir
    # a disk_dir without disk_pages is simply an unused tier
    tp = TieredPool(2, disk_dir=str(tmp_path))
    assert tp.disk_pages == 0


def test_host_tier_lru_spill_evicts_oldest():
    tp = TieredPool(2)
    a, b, c = tp.demote(_slab(1)), tp.demote(_slab(2)), tp.demote(_slab(3))
    # no disk behind the host tier: the LRU slab fell off the bottom
    assert tp.host_used == 2 and len(tp) == 2
    assert tp.ids() == {b, c}
    assert tp.stats.demoted == 3 and tp.stats.evicted == 1
    with pytest.raises(KeyError):
        tp.tier_of(a)
    tp.check()


def test_touch_refreshes_lru_recency():
    tp = TieredPool(2)
    a, _b = tp.demote(_slab(1)), tp.demote(_slab(2))
    tp.touch(a)                              # a becomes most-recently-used
    c = tp.demote(_slab(3))                  # spills b, not a
    assert tp.ids() == {a, c}
    tp.check()


def test_zero_capacity_hierarchy_rejects_demotion():
    tp = TieredPool(0)
    assert tp.demote(_slab(1)) is None       # caller treats as true eviction
    assert tp.stats.demoted == 0
    tp.check()


def test_disk_tier_round_trips_exact_bytes(tmp_path):
    tp = TieredPool(1, disk_dir=str(tmp_path), disk_pages=2)
    a = tp.demote(_slab(7))
    b = tp.demote(_slab(8))                  # spills a host -> disk
    assert tp.tier_of(a) == 2 and tp.tier_of(b) == 1
    assert tp.stats.disk_demotions == 1 and tp.stats.evicted == 0
    tp.check()
    slab = tp.pop(a)                         # promote off disk
    for got, want in zip(slab, _slab(7)):
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)
    assert tp.disk_used == 0 and not list(tmp_path.iterdir())
    assert tp.stats.promoted == 1
    tp.check()


def test_quantized_slab_disk_round_trip_bitwise(tmp_path):
    """Quantized pages demote as (codes, codes, scales, scales) tuples;
    the disk tier must return every leaf bit-exact with dtypes intact —
    int8 codes may not silently widen, f32 scale rows may not re-round."""
    def qslab(seed):
        r = np.random.default_rng(seed)
        return (r.integers(-127, 128, size=(2, 4, 2, 8)).astype(np.int8),
                r.integers(-127, 128, size=(2, 4, 2, 8)).astype(np.int8),
                r.random((2, 2)).astype(np.float32),
                r.random((2, 2)).astype(np.float32))
    tp = TieredPool(1, disk_dir=str(tmp_path), disk_pages=2)
    a = tp.demote(qslab(7))
    tp.demote(qslab(8))                      # spills a host -> disk
    assert tp.tier_of(a) == 2
    got = tp.pop(a)                          # promote off disk
    for g, w in zip(got, qslab(7)):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(g, w)
    tp.check()


def test_disk_tier_full_evicts_oldest_file(tmp_path):
    tp = TieredPool(1, disk_dir=str(tmp_path), disk_pages=1)
    a = tp.demote(_slab(1))
    b = tp.demote(_slab(2))                  # a -> disk
    c = tp.demote(_slab(3))                  # b -> disk, a falls off
    assert tp.ids() == {b, c}
    assert tp.stats.evicted == 1
    assert len(list(tmp_path.iterdir())) == 1    # one slab file on disk
    tp.check()


def test_pop_and_drop_from_either_tier(tmp_path):
    tp = TieredPool(1, disk_dir=str(tmp_path), disk_pages=4)
    a = tp.demote(_slab(1))
    b = tp.demote(_slab(2))                  # a spilled to disk
    assert tp.pop(b) is not None             # pop from host
    tp.drop(a)                               # drop from disk: file removed
    assert len(tp) == 0 and not list(tmp_path.iterdir())
    assert tp.stats.promoted == 1            # drop is not a promotion
    with pytest.raises(KeyError):
        tp.pop(a)
    tp.check()


# ---------------------------------------------------------------------------
# Cross-tier PrefixIndex lifecycle
# ---------------------------------------------------------------------------


def test_index_entry_survives_demotion_and_promotes_back():
    ix = PrefixIndex(page_size=2)
    ix.register([1, 2, 3, 4], pages=[5, 6])
    ix.commit([1, 2, 3, 4])
    assert ix.demote_page(6, hid=0)          # page freed, slab lives on
    m = ix.match([1, 2, 3, 4])
    assert m.pages == [5, -1]                # demoted placeholder
    assert m.tiers == [0, 1] and m.hids == [None, 0]
    ix.check(live_pages={5}, live_hids={0})
    ix.promote_hid(0, page=9)                # fresh tier-0 page uploaded
    m = ix.match([1, 2, 3, 4])
    assert m.pages == [5, 9] and m.tiers == [0, 0]
    ix.check(live_pages={5, 9})


def test_index_demote_unindexed_page_is_noop():
    ix = PrefixIndex(page_size=2)
    assert not ix.demote_page(3, hid=0)
    assert ix.demoted_ids() == set()


def test_index_set_tier_and_rebind_track_store_moves():
    ix = PrefixIndex(page_size=2)
    ix.register([1, 2], pages=[4])
    ix.commit([1, 2])
    ix.demote_page(4, hid=0)
    ix.set_tier(0, 2)                        # host -> disk spill
    assert ix.match([1, 2]).tiers == [2]
    ix.rebind_hid(0, 5)                      # aborted promotion, new handle
    assert ix.match([1, 2]).hids == [5]
    ix.check(live_pages=set(), live_hids={5})


def test_index_purges_only_on_true_eviction():
    ix = PrefixIndex(page_size=2)
    ix.register([1, 2], pages=[4])
    ix.commit([1, 2])
    ix.demote_page(4, hid=0)
    assert len(ix.match([1, 2])) == 1        # demotion alone keeps the key
    ix.purge_hid(0)                          # slab fell off the bottom
    assert ix.match([1, 2]).pages == []
    assert len(ix) == 0
    ix.check(live_pages=set())


# ---------------------------------------------------------------------------
# Session-cache manager dataflow (dummy gather, no jax)
# ---------------------------------------------------------------------------


def _gather(pages):
    return {p: ("slab", p) for p in pages}


def _tiered_mgr(num_pages=8, page_size=4, host_pages=8):
    pool = BlockPool(num_pages, page_size)
    ix = PrefixIndex(page_size)
    tiers = TieredPool(host_pages, index=ix)
    mgr = PagedSlotManager(3, 32, pool, prefix_index=ix, tiers=tiers)
    return mgr, pool, tiers


def test_tiers_require_prefix_index():
    pool = BlockPool(8, 4)
    with pytest.raises(ValueError, match="prefix index"):
        PagedSlotManager(2, 32, pool, tiers=TieredPool(4))


def test_retain_session_transfers_refs_instead_of_freeing():
    mgr, pool, _ = _tiered_mgr()
    toks = np.arange(100, 109, dtype=np.int32)          # 2 full pages
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    full = mgr.slots[idx].pages[:2]
    assert mgr.retain_session(idx, toks) == 2
    assert mgr.slots[idx].free                          # slot released...
    assert all(pool.refcount(p) == 1 for p in full)     # ...pages retained
    assert mgr.session_pages() == 2
    assert mgr.prefix.match(toks).pages == full         # still matchable
    mgr.check()


def test_session_rehit_maps_pages_without_copies():
    mgr, pool, tiers = _tiered_mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    mgr.retain_session(idx, toks)
    idx2 = mgr.try_assign(1, 9, 4, tokens=toks)         # returning session
    s = mgr.slots[idx2]
    assert s.shared_len == 8 and s.session_mapped == 2
    assert not s.pending_promotions                     # tier-0 rehit: no copy
    assert tiers.stats.demoted == 0
    mgr.check()


def test_reclaim_session_demotes_dying_pages_and_keeps_index():
    mgr, pool, tiers = _tiered_mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    mgr.retain_session(idx, toks)
    freed = mgr.reclaim_session(1, _gather)             # LRU-first, 1 page
    assert freed == 1 and mgr.session_pages() == 1
    assert tiers.stats.demoted == 1
    m = mgr.prefix.match(toks)
    assert m.tiers == [1, 0]                            # first chunk demoted
    mgr.check()
    freed = mgr.reclaim_session(10, _gather)            # drain the rest
    assert freed == 1 and mgr.session_pages() == 0
    assert pool.used_pages == 0
    assert mgr.prefix.match(toks).tiers == [1, 1]       # both still matchable
    mgr.check()


def test_reclaim_spares_pages_shared_with_live_slots():
    mgr, pool, _ = _tiered_mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    a = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(a, toks)
    b = mgr.try_assign(1, 9, 4, tokens=toks)            # shares both pages
    mgr.retain_session(a, toks)                         # refcount 2 each
    shared = mgr.slots[b].pages[:2]
    mgr.reclaim_session(10, _gather)
    # session refs dropped, but b keeps the pages alive — no demotion
    assert all(pool.refcount(p) == 1 for p in shared)
    assert mgr.prefix.match(toks).tiers == [0, 0]
    mgr.check()


def test_returning_admission_promotes_demoted_span():
    mgr, pool, tiers = _tiered_mgr()
    toks = np.arange(100, 109, dtype=np.int32)
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    mgr.retain_session(idx, toks)
    mgr.reclaim_session(10, _gather)                    # both pages host-side
    idx2 = mgr.try_assign(1, 9, 4, tokens=toks)
    s = mgr.slots[idx2]
    assert s.shared_len == 8 and len(s.pending_promotions) == 2
    assert tiers.stats.promoted == 2 and len(tiers) == 0
    # the index is rebound onto the fresh tier-0 destinations
    m = mgr.prefix.match(toks)
    assert m.tiers == [0, 0]
    assert m.pages == [dst for _slab, dst in s.pending_promotions]
    mgr.check()


def test_swap_threshold_truncates_match_at_first_demoted_entry():
    mgr, pool, tiers = _tiered_mgr()
    mgr.swap_threshold = 64                             # promotion never wins
    toks = np.arange(100, 109, dtype=np.int32)
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    mgr.retain_session(idx, toks)
    mgr.reclaim_session(1, _gather)                     # first chunk demoted
    idx2 = mgr.try_assign(1, 9, 4, tokens=toks)
    s = mgr.slots[idx2]
    # tier-0 match truncates at the demoted first chunk: nothing shared,
    # nothing promoted — those positions re-prefill
    assert s.shared_len == 0 and not s.pending_promotions
    assert tiers.stats.promoted == 0
    mgr.check()


def test_dry_admission_reclaims_session_via_callback():
    mgr, pool, tiers = _tiered_mgr(num_pages=4)
    mgr.reclaim_cb = lambda need: mgr.reclaim_session(need, _gather) >= need
    toks = np.arange(100, 109, dtype=np.int32)          # 3 pages w/ headroom
    idx = mgr.try_assign(0, 9, 4, tokens=toks)
    mgr.commit_prefix(idx, toks)
    mgr.retain_session(idx, toks)                       # 2 pages cached
    other = np.arange(200, 212, dtype=np.int32)         # needs 4 fresh pages
    idx2 = mgr.try_assign(1, 12, 4, tokens=other)
    assert idx2 is not None                             # cache lost the fight
    assert tiers.stats.demoted == 2                     # demoted, not lost
    assert mgr.session_pages() == 0
    mgr.check()


# ---------------------------------------------------------------------------
# Engine: resume/returning bit-identity through every tier
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_seq", 256)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("page_size", 16)
    kw.setdefault("cache_kind", "paged")
    kw.setdefault("prefix_sharing", True)
    return Engine(cfg, params, **kw)


def _reqs(cfg, n=3, plen=40, max_new=6, seed=17):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, cfg.vocab_size, size=plen).astype(np.int32),
             SamplingParams(max_new_tokens=max_new)) for _ in range(n)]


def _rerun(reqs):
    return [(p.copy(), s) for p, s in reqs]


def _toks(out):
    """Outputs in submission order — rids auto-increment across runs on
    one engine, so dicts from different runs never key-compare equal."""
    return [out[k] for k in sorted(out)]


def test_engine_rejects_tiered_misconfig(smoke_model):
    cfg, params = smoke_model
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, cache_kind="dense", host_pages=8)
    with pytest.raises(ValueError, match="prefix_sharing"):
        Engine(cfg, params, cache_kind="paged", host_pages=8,
               prefix_sharing=False)


def test_returning_conversation_promotes_and_matches(smoke_model):
    """The tentpole invariant, host tier: flush the session cache
    host-ward, resubmit the same prompts — the rerun promotes the
    demoted pages and produces bit-identical greedy tokens vs an engine
    that discarded everything (and vs dense)."""
    cfg, params = smoke_model
    reqs = _reqs(cfg)

    base = _engine(cfg, params)
    out_base = _toks(base.run(_rerun(reqs)))

    dense = Engine(cfg, params, cache_kind="dense", num_slots=4,
                   max_seq=256, prefill_chunk=16)
    assert _toks(dense.run(_rerun(reqs))) == out_base

    eng = _engine(cfg, params, host_pages=64)
    assert _toks(eng.run(_rerun(reqs))) == out_base
    eng.evict_finished(flush=True)                      # force off-device
    assert eng.tiers.host_used > 0
    assert eng.pool.used_pages == 0
    assert _toks(eng.run(_rerun(reqs))) == out_base            # returning turn
    assert eng.stats.promoted_pages > 0
    assert eng.stats.demoted_pages > 0
    assert eng.stats.saved_prefill_tokens > 0
    eng.slots.check()


def test_tier0_session_rehit_skips_prefill_without_copies(smoke_model):
    """Retire without flushing: the rerun re-maps resident tier-0 pages
    by refcount bump (session hit) — no promotion traffic at all."""
    cfg, params = smoke_model
    reqs = _reqs(cfg, seed=19)
    base = _engine(cfg, params)
    out = _toks(base.run(_rerun(reqs)))
    eng = _engine(cfg, params, host_pages=64)
    eng.run(_rerun(reqs))
    eng.evict_finished()                                # keep KV on device
    assert eng.slots.session_pages() > 0
    assert _toks(eng.run(_rerun(reqs))) == out
    assert eng.stats.session_hits > 0
    assert eng.stats.promoted_pages == 0
    eng.slots.check()


def test_preempted_resume_identical_through_tiers(smoke_model):
    """Mid-decode preemption under a tight pool with tiers attached:
    victims demote instead of freeing, resumption promotes (or rehits),
    and outputs match a pool that never preempts, a tight pool that
    re-prefills, and the dense engine bit-exactly."""
    cfg, params = smoke_model
    rng = np.random.default_rng(23)
    sp = SamplingParams(max_new_tokens=40)              # forces lazy growth
    reqs = [(rng.integers(1, cfg.vocab_size, size=40).astype(np.int32), sp)
            for _ in range(4)]

    big = _engine(cfg, params, num_pages=64)
    out_big = _toks(big.run(_rerun(reqs), max_ticks=3000))

    tight = _engine(cfg, params, num_pages=9)
    assert _toks(tight.run(_rerun(reqs), max_ticks=3000)) == out_big
    assert tight.stats.preemptions > 0, "pool was never under pressure"

    tiers = _engine(cfg, params, num_pages=9, host_pages=64)
    assert _toks(tiers.run(_rerun(reqs), max_ticks=3000)) == out_big
    assert tiers.stats.preemptions > 0
    assert tiers.stats.demoted_pages > 0, "preemption never demoted"
    tiers.slots.check()


def test_disk_tier_resume_identical(smoke_model, tmp_path):
    """A host tier too small for the flushed sessions spills to disk;
    the returning turn reads the slabs back bit-exactly."""
    cfg, params = smoke_model
    reqs = _reqs(cfg, n=2, seed=29)
    base = _engine(cfg, params)
    out = _toks(base.run(_rerun(reqs)))
    eng = _engine(cfg, params, host_pages=2, disk_dir=str(tmp_path),
                  disk_pages=16)
    eng.run(_rerun(reqs))
    eng.evict_finished(flush=True)
    assert eng.tiers.stats.disk_demotions > 0
    assert _toks(eng.run(_rerun(reqs))) == out
    eng.slots.check()


def test_quantized_pages_demote_promote_bitwise(smoke_model, tmp_path):
    """int8 KV pages flushed through the host+disk tiers come back with
    the exact quantized representation: the tiered store moves the codes
    (int8 slabs) and their f32 scale rows as opaque bytes, so after
    promotion every prefix page is bitwise identical to its pre-demotion
    self — and greedy outputs match a never-demoted int8 engine."""
    cfg, params = smoke_model
    reqs = _reqs(cfg, n=2, seed=43)
    base = _engine(cfg, params, kv_dtype="int8")
    out = _toks(base.run(_rerun(reqs)))

    eng = _engine(cfg, params, kv_dtype="int8", host_pages=2,
                  disk_dir=str(tmp_path), disk_pages=16)
    assert _toks(eng.run(_rerun(reqs))) == out

    def snapshot():
        """Resident prefix pages' slabs keyed by their token chunk."""
        ent = eng.prefix._entries
        keys = sorted(k for k in ent if ent[k].page is not None)
        pages = [ent[k].page for k in keys]
        slabs = eng._gather_pages(pages)
        return {k: slabs[p] for k, p in zip(keys, pages)}

    before = snapshot()
    assert before, "run registered no prefix pages"
    leaves = next(iter(before.values()))
    assert any(a.dtype == np.int8 for a in leaves), "no quantized codes"
    assert any(a.dtype == np.float32 for a in leaves), "no scale rows"

    eng.evict_finished(flush=True)
    assert eng.tiers.stats.disk_demotions > 0

    assert _toks(eng.run(_rerun(reqs))) == out   # promotes the span back
    assert eng.stats.promoted_pages > 0
    after = snapshot()
    assert sorted(after) == sorted(before)
    for k in before:
        for g, w in zip(after[k], before[k]):
            assert g.dtype == w.dtype
            np.testing.assert_array_equal(g, w)
    eng.slots.check()


def test_eviction_fallback_reprefills_identically(smoke_model):
    """A hierarchy with almost no capacity truly evicts: the purged keys
    stop matching and the rerun silently pays full re-prefill — same
    tokens, just no savings."""
    cfg, params = smoke_model
    reqs = _reqs(cfg, n=2, seed=31)
    base = _engine(cfg, params)
    out = _toks(base.run(_rerun(reqs)))
    eng = _engine(cfg, params, host_pages=1)
    eng.run(_rerun(reqs))
    eng.evict_finished(flush=True)
    assert eng.stats.host_evicted_pages > 0
    assert _toks(eng.run(_rerun(reqs))) == out
    eng.slots.check()


def test_session_cache_off_frees_on_retire(smoke_model):
    """session_cache=False keeps demotion for preemption only: retire
    frees pages as before and the rerun re-prefills from scratch."""
    cfg, params = smoke_model
    reqs = _reqs(cfg, n=2, seed=37)
    base = _engine(cfg, params)
    out = _toks(base.run(_rerun(reqs)))
    eng = _engine(cfg, params, host_pages=64, session_cache=False)
    eng.run(_rerun(reqs))
    eng.evict_finished()
    assert eng.slots.session_pages() == 0
    assert eng.pool.used_pages == 0
    assert _toks(eng.run(_rerun(reqs))) == out
    assert eng.stats.session_hits == 0


def test_flush_sessions_accounts_stats(smoke_model):
    cfg, params = smoke_model
    eng = _engine(cfg, params, host_pages=64)
    eng.run(_rerun(_reqs(cfg, n=2, seed=41)))
    cached = eng.slots.session_pages()
    assert cached > 0
    assert eng.flush_sessions() == cached
    assert eng.stats.demoted_pages == cached
    assert eng.slots.session_pages() == 0
    assert eng.pool.used_pages == 0
    eng.slots.check()


def test_tiers_bench_smoke(tmp_path, monkeypatch):
    """CI wiring: the tiers benchmark runs at smoke sizes, emits a
    well-formed BENCH_tiers.json, and shows the two headline results —
    a warm-session TTFT win and a sane swap-vs-re-prefill crossover."""
    from benchmarks import kv_tiers
    monkeypatch.setattr(kv_tiers, "OUT_PATH",
                        str(tmp_path / "BENCH_tiers.json"))
    result = kv_tiers.run(quick=True)
    assert (tmp_path / "BENCH_tiers.quick.json").exists()
    assert not (tmp_path / "BENCH_tiers.json").exists()
    for row in result["ttft"]:
        assert row["speedup"] > 1.0, "session cache must beat re-prefill"
        assert row["promoted_pages"] > 0
        assert row["saved_prefill_tokens"] > 0
    assert result["identity"]["identical"]
    for arch in result["crossover"]:
        assert arch["swap_threshold"] >= 1
        for pt in arch["curve"]:
            assert pt["swap_s"] > 0 and pt["reprefill_s"] > 0
