"""End-to-end system test: train a tiny model with the full substrate,
checkpoint, restore, and serve it through the continuous-batching engine.
The whole paper pipeline (T1 softmax in attention, T3-dispatchable
matmuls, fault-tolerant loop, engine) in one flow."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.core.plan import tune
from repro.models.api import get_model
from repro.models.layers import LayerCtx
from repro.serving.engine import Engine
from repro.serving.request import SamplingParams
from repro.training.checkpoint import CheckpointManager
from repro.training.loop import train_loop
from repro.training.train_state import TrainState, make_train_step


@pytest.mark.slow
def test_train_checkpoint_serve_roundtrip():
    cfg = configs.smoke(configs.get("qwen2-0.5b"))
    api = get_model(cfg)
    shape = ShapeConfig("sys", 32, 4, "train")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        run = RunConfig(total_steps=6, checkpoint_every=3,
                        learning_rate=1e-3, warmup_steps=1,
                        checkpoint_dir=ckpt_dir)
        ctx = LayerCtx(cfg=cfg)
        step = jax.jit(make_train_step(api, ctx, run))

        res = train_loop(
            model_cfg=cfg, shape=shape, run=run, train_step=step,
            init_state=lambda: TrainState.create(
                api.init_params(jax.random.PRNGKey(0))),
            log_every=0, install_signals=False,
        )
        assert res.final_step == 6
        assert res.losses[-1] < res.losses[0]

        # restore the trained params and serve them
        mgr = CheckpointManager(ckpt_dir)
        latest = mgr.latest_step()
        assert latest == 6
        like = jax.eval_shape(
            lambda: TrainState.create(api.init_params(jax.random.PRNGKey(0))))
        state = mgr.load_state(latest, like)

        plan = tune(cfg)   # T3 wired into the engine: one tuned surface
        eng = Engine(cfg, state.params, num_slots=2, max_seq=128,
                     plan=plan)
        rng = np.random.default_rng(0)
        out = eng.run([
            (rng.integers(1, cfg.vocab_size, 9 + i).astype(np.int32),
             SamplingParams(max_new_tokens=4))
            for i in range(3)
        ])
        assert set(out) == {0, 1, 2}
        assert all(len(v) == 4 for v in out.values())
        assert all(0 <= t < cfg.vocab_size for v in out.values() for t in v)
